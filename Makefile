# pathsig build helpers. The Rust side needs nothing beyond cargo;
# `artifacts` requires a Python environment with jax installed (see
# DESIGN.md — the AOT artifacts are optional, the crate runs without them).

.PHONY: build test doc bench bench-json bench-smoke bench-record bench-compare artifacts clean

build:
	cargo build --release

test:
	cargo test -q

doc:
	cargo doc --no-deps

bench:
	cargo bench

# Emit the repo-root perf-trajectory artifacts: mean/median/min per
# case, peak bytes, the lane-major-vs-scalar forward AND backward
# speedups, the per-ISA/per-precision SIMD kernel rows, the
# streaming-vs-recompute sliding-window rows, the long-path
# tree-vs-sequential rows, the zero-alloc steady-state counts (batch
# forward, train step, stream push, tree fwd+bwd, journal append, warm
# Gram, per-SIMD-row), the sharded coordinator's p50/p99 latency under
# thousands of live sessions, the durability tax + recovery-time
# curve, and the batched-Gram-vs-naive + random-feature error/time
# rows. The bench → artifact table lives in scripts/bench_manifest.txt
# (the canonical manifest — CI and bench_compare consume the same
# file).
bench-json:
	@set -eu; grep -Ev '^[[:space:]]*([#]|$$)' scripts/bench_manifest.txt | \
	while read -r bench artifact; do \
		echo "== $$bench -> $$artifact"; \
		cargo bench --bench "$$bench" -- --json || exit 1; \
	done

# CI-sized variant of bench-json: tiny cases, 1 warmup / 2 runs —
# exercises the artifact pipeline, not a measurement.
bench-smoke:
	@set -eu; grep -Ev '^[[:space:]]*([#]|$$)' scripts/bench_manifest.txt | \
	while read -r bench artifact; do \
		echo "== $$bench -> $$artifact"; \
		cargo bench --bench "$$bench" -- --json --smoke || exit 1; \
	done

# Run the JSON bench suite and stage the BENCH_*.json artifacts for
# commit — the perf trajectory is tracked in-repo, one snapshot per
# perf PR (see README "Perf trajectory"). Pass SMOKE=1 for the CI-sized
# run when a full measurement is not wanted.
bench-record:
	./scripts/bench_record.sh $(if $(SMOKE),--smoke,)

# Perf-regression gate: compare the working-tree BENCH_*.json artifacts
# against the last recorded snapshot (REF=..., default HEAD) and fail
# on a >15% regression in any headline metric. SMOKE=1 relaxes to
# shape checks (CI); RUN=1 runs the bench suite first.
bench-compare:
	./scripts/bench_compare.sh $(if $(SMOKE),--smoke,) $(if $(RUN),--run,) $(if $(REF),--ref $(REF),)

# Emit the AOT/PJRT artifacts (HLO text + manifest.json) into ./artifacts.
artifacts:
	python3 python/compile/aot.py --out-dir artifacts

clean:
	cargo clean
	rm -rf artifacts
