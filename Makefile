# pathsig build helpers. The Rust side needs nothing beyond cargo;
# `artifacts` requires a Python environment with jax installed (see
# DESIGN.md — the AOT artifacts are optional, the crate runs without them).

.PHONY: build test doc bench artifacts clean

build:
	cargo build --release

test:
	cargo test -q

doc:
	cargo doc --no-deps

bench:
	cargo bench

# Emit the AOT/PJRT artifacts (HLO text + manifest.json) into ./artifacts.
artifacts:
	python3 python/compile/aot.py --out-dir artifacts

clean:
	cargo clean
	rm -rf artifacts
