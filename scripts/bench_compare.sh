#!/usr/bin/env bash
# Perf-regression gate over the repo-root BENCH_*.json trajectory
# artifacts (see README "Perf trajectory"). Compares the working-tree
# artifacts — typically just produced by `make bench-json` /
# `make bench-smoke` — against the last recorded snapshot in git, and
# fails on a >15% regression in any headline metric:
#
#   BENCH_fig1.json    lane_vs_scalar.speedup        (forward kernel)
#                      simd_rows[*]                  (per-ISA / per-precision:
#                      speedup gated like-for-like — keys embed (kernel,
#                      isa, precision), so a row measured on different
#                      hardware simply has no baseline key to compare
#                      against — and warm allocs must be exactly 0)
#   BENCH_table1.json  lane_vs_scalar.speedup        (backward kernel)
#                      simd_rows[*]                  (per-ISA backward rows)
#   BENCH_stream.json  stream_vs_recompute.speedup   (O(1) window push)
#   BENCH_tree.json    tree_vs_sequential.speedup,
#                      backward.speedup              (time-parallel tree)
#   BENCH_coord.json   rows[*].p99_us                (coordinator latency)
#   BENCH_durability.json
#                      push.rows[journal].p99_us,
#                      recovery.rows[*].recover_ms   (durability tax)
#   BENCH_kernels.json gram_vs_naive.speedup         (batched Gram)
#   + every steady_state_allocs_* counter must not increase (and the
#     warm-Gram counter must be exactly 0).
#
# Usage:
#   scripts/bench_compare.sh [--smoke] [--ref REF] [--run]
#
#   --smoke   smoke artifacts are shape checks, not measurements: verify
#             the headline metrics exist and are positive, skip the 15%
#             thresholds (CI wires this into the bench-smoke job).
#   --ref R   baseline git ref (default HEAD). A ref that predates an
#             artifact skips that file with a note — the first
#             `make bench-record` commit seeds the baseline.
#   --run     run the matching bench suite first (bench-json, or
#             bench-smoke with --smoke).
set -euo pipefail
cd "$(dirname "$0")/.."

smoke=0 ref=HEAD run=0
while [[ $# -gt 0 ]]; do
    case "$1" in
        --smoke) smoke=1 ;;
        --ref) ref="$2"; shift ;;
        --run) run=1 ;;
        *) echo "unknown flag $1" >&2; exit 2 ;;
    esac
    shift
done

if [[ "$run" == 1 ]]; then
    if [[ "$smoke" == 1 ]]; then make bench-smoke; else make bench-json; fi
fi

baseline_dir=$(mktemp -d)
trap 'rm -rf "$baseline_dir"' EXIT

# The artifact list comes from the canonical bench manifest — the same
# file `make bench-json` / `make bench-smoke` and CI iterate over.
manifest="scripts/bench_manifest.txt"
artifacts=$(grep -Ev '^[[:space:]]*([#]|$)' "$manifest" | awk '{print $2}')
if [[ -z "$artifacts" ]]; then
    echo "error: no artifacts listed in $manifest" >&2
    exit 2
fi

have_baseline=0
for f in $artifacts; do
    if git show "$ref:$f" > "$baseline_dir/$f" 2>/dev/null; then
        have_baseline=1
    else
        rm -f "$baseline_dir/$f"
        echo "note: no baseline $f at $ref — skipping (first recording seeds it)"
    fi
done

SMOKE="$smoke" BASELINE_DIR="$baseline_dir" HAVE_BASELINE="$have_baseline" \
ARTIFACTS="$artifacts" python3 - <<'EOF'
import json, os, sys

smoke = os.environ["SMOKE"] == "1"
bdir = os.environ["BASELINE_DIR"]
artifacts = os.environ["ARTIFACTS"].split()
TOL = 0.15  # >15% regression fails
failures, checked = [], 0


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def headline(doc, name):
    """(metric-name, value, kind) triples; kind: 'hi' = higher is
    better, 'lo' = lower is better, 'alloc' = must not increase."""
    if doc is None:
        return []
    out = []

    def simd_rows(prefix):
        # Per-ISA / per-precision kernel rows. The key embeds
        # (kernel, isa, precision), so the 15% gate only ever compares
        # like ISA against like ISA: a row whose ISA the baseline
        # machine lacked is simply a new key (`k not in base`) and is
        # skipped. Warm allocs per row must be exactly 0, on every ISA
        # and at both precisions.
        for row in doc.get("simd_rows", []):
            key = f"{prefix}.simd.{row['kernel']}.{row['isa']}.{row['precision']}"
            out.append((f"{key}.speedup_vs_scalar_f64", row["speedup_vs_scalar_f64"], "hi"))
            out.append((f"{key}.allocs_per_call", row["allocs_per_call"], "zero"))

    if name == "BENCH_fig1.json":
        out.append(("fig1.lane_vs_scalar.speedup", doc["lane_vs_scalar"]["speedup"], "hi"))
        out.append(("fig1.steady_state_allocs_per_call", doc["steady_state_allocs_per_call"], "alloc"))
        simd_rows("fig1")
    elif name == "BENCH_table1.json":
        out.append(("table1.lane_vs_scalar.speedup", doc["lane_vs_scalar"]["speedup"], "hi"))
        out.append(("table1.steady_state_allocs_per_call", doc["steady_state_allocs_per_call"], "alloc"))
        simd_rows("table1")
    elif name == "BENCH_stream.json":
        out.append(("stream.stream_vs_recompute.speedup", doc["stream_vs_recompute"]["speedup"], "hi"))
        out.append(("stream.steady_state_allocs_per_push", doc["steady_state_allocs_per_push"], "alloc"))
    elif name == "BENCH_tree.json":
        out.append(("tree.tree_vs_sequential.speedup", doc["tree_vs_sequential"]["speedup"], "hi"))
        out.append(("tree.backward.speedup", doc["backward"]["speedup"], "hi"))
        out.append(("tree.steady_state_allocs_per_call", doc["steady_state_allocs_per_call"], "alloc"))
    elif name == "BENCH_coord.json":
        for row in doc["rows"]:
            out.append((f"coord.shards{row['shards']}.p99_us", row["p99_us"], "lo"))
            out.append((f"coord.shards{row['shards']}.lost_sessions", row["lost_sessions"], "alloc"))
    elif name == "BENCH_durability.json":
        for row in doc["push"]["rows"]:
            if row["mode"] == "journal":
                out.append(("durability.push_journal.p99_us", row["p99_us"], "lo"))
        for row in doc["recovery"]["rows"]:
            out.append((f"durability.recover{row['sessions']}.ms", row["recover_ms"], "lo"))
        out.append(("durability.steady_state_allocs_per_append",
                    doc["steady_state_allocs_per_append"], "alloc"))
    elif name == "BENCH_kernels.json":
        out.append(("kernels.gram_vs_naive.speedup", doc["gram_vs_naive"]["speedup"], "hi"))
        out.append(("kernels.gram_rows", len(doc["gram_vs_naive"]["rows"]), "hi"))
        out.append(("kernels.random_feature_rows", len(doc["random_features"]["rows"]), "hi"))
        # Warm Gram calls must be allocation-free, not just non-increasing.
        out.append(("kernels.steady_state_allocs_per_call",
                    doc["steady_state_allocs_per_call"], "zero"))
    return out


for name in artifacts:
    cur_doc = load(name)
    base_doc = load(os.path.join(bdir, name))
    cur = dict((k, (v, kind)) for k, v, kind in headline(cur_doc, name))
    base = dict((k, (v, kind)) for k, v, kind in headline(base_doc, name))
    if cur_doc is None:
        if base_doc is not None:
            failures.append(f"{name}: baseline exists but working tree lost the artifact")
        continue
    # The artifact itself must carry sane headline values regardless of
    # baseline availability (this is the whole check in smoke mode).
    for k, (v, kind) in cur.items():
        checked += 1
        if kind == "hi" and not v > 0:
            failures.append(f"{k}: headline metric {v} is not positive")
        if kind == "lo" and not v > 0:
            failures.append(f"{k}: latency {v} is not positive")
        if kind == "alloc" and v < 0:
            failures.append(f"{k}: negative counter {v}")
        if kind == "zero" and v != 0:
            failures.append(f"{k}: expected exactly 0, got {v}")
    if smoke or base_doc is None:
        continue
    for k, (v, kind) in cur.items():
        if k not in base:
            continue  # new metric this PR: no baseline yet
        b = base[k][0]
        if kind == "hi" and v < b * (1 - TOL):
            failures.append(f"{k}: {v:.3f} vs baseline {b:.3f} (> {TOL:.0%} regression)")
        elif kind == "lo" and b > 0 and v > b * (1 + TOL):
            failures.append(f"{k}: {v:.1f} vs baseline {b:.1f} (> {TOL:.0%} regression)")
        elif kind == "alloc" and v > b:
            failures.append(f"{k}: {v} vs baseline {b} (counter increased)")

mode = "smoke (shape checks only)" if smoke else f"full (±{TOL:.0%} thresholds)"
print(f"bench_compare: {checked} headline metrics checked, mode {mode}")
if failures:
    print("PERF REGRESSION GATE FAILED:", file=sys.stderr)
    for f in failures:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print("bench_compare: OK")
EOF
