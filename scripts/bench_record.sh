#!/usr/bin/env bash
# Record a perf-trajectory snapshot: run the JSON bench suite and stage
# the repo-root BENCH_*.json artifacts so the next commit carries them.
#
# Usage:
#   scripts/bench_record.sh          # full measurement (bench-json)
#   scripts/bench_record.sh --smoke  # CI-sized smoke run (bench-smoke)
#
# The driver commits the staged artifacts with each perf PR, so the
# repo's history doubles as the perf trajectory — `git log -p -- \
# 'BENCH_*.json'` shows every speedup headline over time.
set -euo pipefail
cd "$(dirname "$0")/.."

target=bench-json
if [[ "${1:-}" == "--smoke" ]]; then
    target=bench-smoke
fi

make "$target"

artifacts=(BENCH_*.json)
if [[ ! -e "${artifacts[0]}" ]]; then
    echo "error: no BENCH_*.json artifacts were produced" >&2
    exit 1
fi

git add -- "${artifacts[@]}"
echo "staged perf artifacts: ${artifacts[*]}"
git status --short -- 'BENCH_*.json'
