#!/usr/bin/env python3
"""Regenerate the wire-protocol golden files in rust/tests/golden/.

This is an independent mirror of three codecs:

* v2 binary frames (rust/src/coordinator/wire.rs): 6-byte header
  (0x02, verb/status, u32 LE payload length) + little-endian payload;
* v1 JSON-lines responses (rust/src/coordinator/protocol.rs): compact
  JSON with alphabetically sorted keys (the Rust Json::Obj is a
  BTreeMap) and integers printed without a decimal point;
* persist records (rust/src/persist/codec.rs): the on-disk journal /
  checkpoint framing `[u32 LE len][u8 kind · u64 LE seq · body]
  [u32 LE crc]` with a zlib CRC-32 over the payload.

The Rust tests rust/tests/wire_golden.rs and
rust/tests/persist_golden.rs build the same frames with the real codecs
and compare byte-for-byte, so any drift between the two implementations
— or any accidental change to a wire or disk format — fails CI. Run
from the repo root:

    python3 scripts/gen_goldens.py
"""
import json
import os
import struct
import zlib

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(ROOT, "rust", "tests", "golden")

WIRE_V2 = 0x02
VERB = {
    "ping": 0x01,
    "stats": 0x02,
    "signature": 0x03,
    "stats2": 0x04,
    "gram": 0x05,
    "health": 0x06,
    "stream_open": 0x10,
    "stream_push": 0x11,
    "stream_window": 0x12,
    "stream_close": 0x13,
}
STATUS = {"ok": 0, "err": 1, "shed": 2}


def u8(v):
    return struct.pack("<B", v)


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f64(v):
    return struct.pack("<d", v)


def f64s(vs):
    return u32(len(vs)) + b"".join(f64(v) for v in vs)


def u16s(vs):
    return u32(len(vs)) + b"".join(u16(v) for v in vs)


def frame(kind, payload):
    return u8(WIRE_V2) + u8(kind) + u32(len(payload)) + payload


def spec_truncated():
    return u8(0)


def spec_lyndon():
    return u8(1)


def spec_anisotropic(gamma, cutoff):
    return u8(2) + f64s(gamma) + f64(cutoff)


def spec_dag(edges):
    return u8(3) + u32(len(edges)) + b"".join(u16s(row) for row in edges)


def spec_words(words):
    return u8(4) + u32(len(words)) + b"".join(u16s(w) for w in words)


def spec_sparse_leadlag(base_dim):
    return u8(5) + u32(base_dim)


def string(s):
    b = s.encode("utf-8")
    return u32(len(b)) + b


def v2_frames():
    """(name, frame bytes) for every request verb, every projection
    tag, and every response status/body shape."""
    rows = []
    # Requests — all 7 verbs.
    rows.append(("req_ping", frame(VERB["ping"], b"")))
    rows.append(("req_stats", frame(VERB["stats"], b"")))
    rows.append(("req_stats2", frame(VERB["stats2"], b"")))
    rows.append(("req_health", frame(VERB["health"], b"")))
    rows.append((
        "req_signature_truncated",
        frame(VERB["signature"],
              u32(2) + u32(2) + spec_truncated()
              + f64s([0.0, 0.0, 1.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_signature_lyndon",
        frame(VERB["signature"],
              u32(2) + u32(3) + spec_lyndon() + f64s([0.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_signature_anisotropic",
        frame(VERB["signature"],
              u32(2) + u32(4) + spec_anisotropic([1.0, 2.0], 2.5)
              + f64s([0.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_signature_dag",
        frame(VERB["signature"],
              u32(2) + u32(2) + spec_dag([[0, 1], [1]])
              + f64s([0.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_signature_words",
        frame(VERB["signature"],
              u32(2) + u32(2) + spec_words([[0, 1], [1]])
              + f64s([0.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_signature_sparse_leadlag",
        frame(VERB["signature"],
              u32(4) + u32(2) + spec_sparse_leadlag(2) + f64s([0.0] * 8)),
    ))
    # Gram is its OWN verb (0x05) with its own layout — the signature
    # frame body is frozen, so the batched request never extends it:
    # dim · depth · spec · path count · per-path f64 runs.
    rows.append((
        "req_gram_truncated",
        frame(VERB["gram"],
              u32(2) + u32(2) + spec_truncated() + u32(2)
              + f64s([0.0, 0.0, 1.0, 0.0]) + f64s([0.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_gram_anisotropic",
        frame(VERB["gram"],
              u32(2) + u32(3) + spec_anisotropic([1.0, 1.5], 3.0) + u32(1)
              + f64s([0.0, 0.0, 1.0, 1.0])),
    ))
    rows.append((
        "req_stream_open",
        frame(VERB["stream_open"], u32(1) + u32(2) + u32(4) + spec_truncated()),
    ))
    rows.append((
        "req_stream_push",
        frame(VERB["stream_push"], u64(7) + f64s([0.5, 1.5])),
    ))
    rows.append((
        "req_stream_window_full",
        frame(VERB["stream_window"], u64(7) + u8(1)),
    ))
    rows.append((
        "req_stream_close",
        frame(VERB["stream_close"], u64(7)),
    ))
    # Responses — every status, every ok-body shape.
    rows.append(("resp_ok_ping", frame(STATUS["ok"], u8(VERB["ping"]))))
    rows.append((
        "resp_ok_stats",
        # The ORIGINAL stats layout, frozen: one shard row of exactly
        # (shard, sessions, mailbox_depth, sheds, pushes) and nothing
        # after the rows. Deployed decoders reject trailing bytes, so
        # new fields go in stats2, never here.
        frame(STATUS["ok"],
              u8(VERB["stats"]) + u32(1)
              + u32(0) + u64(3) + u64(1) + u64(0) + u64(42)),
    ))
    rows.append((
        "resp_ok_stats2",
        # Extended row (base + journal_lag) followed by the
        # signature-cache counters (hits, misses, evictions).
        frame(STATUS["ok"],
              u8(VERB["stats2"]) + u32(1)
              + u32(0) + u64(3) + u64(1) + u64(0) + u64(42) + u64(5)
              + u64(7) + u64(2) + u64(1)),
    ))
    rows.append((
        # Durability health body: policy byte (0 = degraded, 1 =
        # strict), sticky degraded bit, then the journal-error and
        # strict-reject counters.
        "resp_ok_health",
        frame(STATUS["ok"],
              u8(VERB["health"]) + u8(1) + u8(0) + u64(3) + u64(2)),
    ))
    rows.append((
        "resp_ok_values",
        frame(STATUS["ok"],
              u8(VERB["stream_window"]) + u32(1) + u32(2) + f64s([5.0, 12.5])),
    ))
    rows.append((
        # A Gram reply is the standard Values body under the new verb:
        # the 2×2 matrix from the kernel doctest case.
        "resp_ok_gram_values",
        frame(STATUS["ok"],
              u8(VERB["gram"]) + u32(2) + u32(2) + u32(2)
              + f64s([1.25, 0.0, 0.0, 8.0])),
    ))
    rows.append((
        "resp_ok_opened",
        frame(STATUS["ok"], u8(VERB["stream_open"]) + u64(9) + u32(6)),
    ))
    rows.append((
        "resp_ok_pushed",
        frame(STATUS["ok"], u8(VERB["stream_push"]) + u64(4) + u64(8)),
    ))
    rows.append(("resp_ok_closed", frame(STATUS["ok"], u8(VERB["stream_close"]))))
    rows.append((
        "resp_err_unknown_session",
        frame(STATUS["err"],
              u8(VERB["stream_push"]) + u8(3)
              + string("unknown session 's9' (already closed or evicted)")),
    ))
    rows.append((
        # The non-finite rejection both protocol boundaries must emit
        # byte-identically (code 2 = bad_request).
        "resp_err_non_finite",
        frame(STATUS["err"],
              u8(VERB["signature"]) + u8(2)
              + string("non-finite value (NaN or Inf) at index 2 of 'path'")),
    ))
    rows.append((
        "resp_shed",
        frame(STATUS["shed"],
              u8(VERB["stream_push"]) + u32(25)
              + string("overloaded; retry after 25 ms")),
    ))
    return rows


def jline(obj):
    """Compact JSON with sorted keys — byte-identical to the Rust
    Json writer for the integer/float values used here."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def v1_responses():
    """Expected byte-exact Response::to_line outputs."""
    return [
        jline({"backend": "native", "id": "r1", "latency_us": 42, "ok": True,
               "result": [1, 2.5], "shape": [2]}),
        jline({"backend": "native", "id": "gr1", "latency_us": 7, "ok": True,
               "result": [1.25, 0, 0, 8], "shape": [2, 2]}),
        jline({"body": {"out_dim": 6, "session": "s1"}, "id": "o1", "ok": True}),
        jline({"body": {"pushed": 4, "seen": 8}, "id": "p1", "ok": True}),
        jline({"body": {"closed": True}, "id": "c1", "ok": True}),
        jline({"error": "unknown session 's9' (already closed or evicted)",
               "id": "e1", "ok": False}),
        jline({"error": "overloaded; retry after 25 ms", "id": "sh1",
               "ok": False, "retry_after_ms": 25, "status": "shed"}),
        jline({"error": "non-finite value (NaN or Inf) at index 2 of 'path'",
               "id": "nf1", "ok": False}),
    ]


def v1_requests():
    """One valid v1 request line per op (parse-checked by the test)."""
    return [
        '{"op":"ping","id":"g1"}',
        '{"op":"stats","id":"g2"}',
        '{"op":"metrics","id":"g3"}',
        '{"op":"signature","id":"g4","dim":2,"depth":2,"path":[0,0,1,0,1,1]}',
        '{"op":"logsig","id":"g5","dim":2,"depth":2,"path":[0,0,1,1]}',
        '{"op":"windowed","id":"g6","dim":1,"depth":2,"windows":[[0,2]],"path":[0,1,2]}',
        '{"op":"stream_open","id":"g7","dim":1,"depth":2,"window":4}',
        '{"op":"stream_push","id":"g8","session":"s1","samples":[0.5,1.5]}',
        '{"op":"stream_window","id":"g9","session":"s1","mode":"full"}',
        '{"op":"stream_close","id":"g10","session":"s1"}',
        '{"op":"gram","id":"g11","dim":2,"depth":2,"paths":[[0,0,1,0],[0,0,1,1]]}',
    ]


# ---------------------------------------------------------------------
# Persist records (rust/src/persist/codec.rs)
# ---------------------------------------------------------------------

K_OPEN, K_PUSH, K_CLOSE, K_EVICT, K_SNAP, K_CKPT_HEAD = 1, 2, 3, 4, 5, 6


def record(kind, seq, body):
    """[u32 LE len][payload = kind · seq · body][u32 LE crc]."""
    payload = u8(kind) + u64(seq) + body
    return u32(len(payload)) + payload + u32(zlib.crc32(payload))


def pspec_truncated(depth):
    return u8(0) + u32(depth)


def pspec_lyndon(depth):
    return u8(1) + u32(depth)


def pspec_anisotropic(gamma, cutoff):
    return u8(2) + f64s(gamma) + f64(cutoff)


def pspec_dag(depth, edges):
    return u8(3) + u32(depth) + u32(len(edges)) + b"".join(u16s(r) for r in edges)


def pspec_concat(depth, gens):
    return u8(4) + u32(depth) + u32(len(gens)) + b"".join(u16s(w) for w in gens)


def pspec_custom(words):
    return u8(5) + u32(len(words)) + b"".join(u16s(w) for w in words)


def persist_records():
    """(name, record bytes) covering every record kind and every
    word-spec tag, with the exact values rust/tests/persist_golden.rs
    rebuilds through the Rust codec."""
    rows = []
    rows.append(("open_truncated",
                 record(K_OPEN, 1, u64(7) + u32(2) + u32(8) + pspec_truncated(3))))
    rows.append(("open_lyndon",
                 record(K_OPEN, 2, u64(8) + u32(3) + u32(16) + pspec_lyndon(4))))
    rows.append(("open_anisotropic",
                 record(K_OPEN, 3, u64(9) + u32(2) + u32(4)
                        + pspec_anisotropic([1.0, 2.5], 3.75))))
    rows.append(("open_dag",
                 record(K_OPEN, 4, u64(10) + u32(2) + u32(4)
                        + pspec_dag(2, [[1], [0, 1]]))))
    rows.append(("open_concat",
                 record(K_OPEN, 5, u64(11) + u32(2) + u32(4)
                        + pspec_concat(4, [[0, 1], [1]]))))
    rows.append(("open_custom",
                 record(K_OPEN, 6, u64(12) + u32(2) + u32(4)
                        + pspec_custom([[0], [1, 0, 1]]))))
    rows.append(("push", record(K_PUSH, 7, u64(7) + f64s([0.5, 1.5, 2.5]))))
    rows.append(("close", record(K_CLOSE, 8, u64(7))))
    rows.append(("evict", record(K_EVICT, 9, u64(8))))
    # SNAP: id, dim, spec, then the stream checkpoint — window u32,
    # n_seen u64, back_len u32, front_len u32, and the five f64 buffers
    # last/total/back_agg/back_dx/front.
    rows.append(("snap",
                 record(K_SNAP, 9, u64(7) + u32(2) + pspec_truncated(2)
                        + u32(3) + u64(5) + u32(1) + u32(2)
                        + f64s([0.5, -1.0])
                        + f64s([1.0, 2.0, 3.0])
                        + f64s([1.0, 0.0, 0.25])
                        + f64s([0.125, -0.5])
                        + f64s([1.0, 1.5, 2.5, 1.0, 0.5, 0.75]))))
    rows.append(("ckpt_head", record(K_CKPT_HEAD, 9, u32(2))))
    return rows


def main():
    os.makedirs(GOLDEN, exist_ok=True)
    with open(os.path.join(GOLDEN, "v2_frames.hex"), "w") as f:
        f.write("# name hex — one golden v2 frame per line; regenerate with\n")
        f.write("# python3 scripts/gen_goldens.py\n")
        for name, b in v2_frames():
            f.write(f"{name} {b.hex()}\n")
    with open(os.path.join(GOLDEN, "v1_responses.jsonl"), "w") as f:
        for line in v1_responses():
            f.write(line + "\n")
    with open(os.path.join(GOLDEN, "v1_requests.jsonl"), "w") as f:
        for line in v1_requests():
            f.write(line + "\n")
    with open(os.path.join(GOLDEN, "persist_records.hex"), "w") as f:
        f.write("# name hex — one golden persist record per line; regenerate with\n")
        f.write("# python3 scripts/gen_goldens.py\n")
        for name, b in persist_records():
            f.write(f"{name} {b.hex()}\n")
    print(f"wrote goldens under {GOLDEN}")


if __name__ == "__main__":
    main()
