#!/usr/bin/env bash
# Crash-recovery acceptance drill against the real binary (ISSUE 7).
#
# Starts `pathsig serve --journal-dir ... --fsync`, drives live v1
# streaming sessions over TCP, records every session's window
# signature, then SIGKILLs the server mid-stream — no shutdown hooks,
# no final checkpoint — restarts it on the same journal directory, and
# requires:
#
#   * every session's next stream_window to match the pre-kill value
#     to 1e-12 (nothing acked may be lost);
#   * the per-session `seen` counter to keep counting from where it
#     was (state resumed, not rebuilt from zero);
#   * the sessions to keep streaming normally afterwards.
#
# The kill/restart cycle runs CYCLES times (default 3) with fresh
# pushes in between, so recovery is exercised on recovered state too.
# CI wires this into the crash-recovery job; run locally with:
#
#   ./scripts/crash_recovery.sh
#
# CHAOS=1 adds the failpoint arm (ISSUE 10): a rebuild with
# --features failpoints, then a strict-durability server run under
# PATHSIG_FAILPOINTS probabilistic journal-append faults — the same
# write failure a full disk produces. Ops the server *acked* under
# fault must all survive a kill -9 and a clean restart exactly; ops it
# rejected must leave no trace.
set -euo pipefail
cd "$(dirname "$0")/.."

CYCLES="${CYCLES:-3}"
SESSIONS="${SESSIONS:-6}"

if [[ -z "${SKIP_BUILD:-}" ]]; then
    cargo build --release --bin pathsig
fi
BIN=target/release/pathsig
[[ -x "$BIN" ]] || { echo "missing $BIN (set SKIP_BUILD= to build)" >&2; exit 2; }

JDIR=$(mktemp -d)
trap 'rm -rf "$JDIR"' EXIT

BIN="$BIN" JDIR="$JDIR" CYCLES="$CYCLES" SESSIONS="$SESSIONS" python3 - <<'EOF'
import json
import os
import signal
import socket
import subprocess
import sys

BIN, JDIR = os.environ["BIN"], os.environ["JDIR"]
CYCLES, SESSIONS = int(os.environ["CYCLES"]), int(os.environ["SESSIONS"])


def start_server():
    p = subprocess.Popen(
        [BIN, "serve", "--addr", "127.0.0.1:0", "--journal-dir", JDIR,
         "--fsync", "--checkpoint-every", "5", "--shards", "2"],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True)
    for line in p.stdout:
        if "listening on" in line:
            return p, line.strip().rsplit(" ", 1)[1]
    raise SystemExit("server exited before announcing its address")


class V1Client:
    """Minimal v1 JSON-lines client over a raw socket."""

    def __init__(self, addr):
        host, port = addr.rsplit(":", 1)
        self.sock = socket.create_connection((host, int(port)), timeout=30)
        self.buf = b""

    def call(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode())
        while b"\n" not in self.buf:
            chunk = self.sock.recv(65536)
            if not chunk:
                raise SystemExit("server closed the connection mid-call")
            self.buf += chunk
        line, self.buf = self.buf.split(b"\n", 1)
        resp = json.loads(line)
        if not resp.get("ok", False):
            raise SystemExit(f"server error for {obj}: {resp}")
        return resp


def push(c, sid, samples):
    return c.call({"op": "stream_push", "session": sid, "samples": samples})


def window(c, sid):
    return c.call({"op": "stream_window", "session": sid})["result"]


server, addr = start_server()
try:
    c = V1Client(addr)
    sids, seen = [], {}
    for k in range(SESSIONS):
        r = c.call({"op": "stream_open", "dim": 1, "depth": 2, "window": 4})
        sid = r["body"]["session"]
        sids.append(sid)
        resp = push(c, sid, [0.5 * j + k for j in range(3 + k % 3)])
        seen[sid] = resp["body"]["seen"]

    for cycle in range(1, CYCLES + 1):
        expect = {sid: window(c, sid) for sid in sids}
        server.send_signal(signal.SIGKILL)
        server.wait()
        server, addr = start_server()
        c = V1Client(addr)
        for sid in sids:
            got = window(c, sid)
            if len(got) != len(expect[sid]) or any(
                    abs(a - b) > 1e-12 for a, b in zip(got, expect[sid])):
                raise SystemExit(
                    f"cycle {cycle}: session {sid} diverged after kill -9:\n"
                    f"  before {expect[sid]}\n  after  {got}")
            resp = push(c, sid, [float(cycle), float(cycle) + 0.5])
            if resp["body"]["seen"] != seen[sid] + 2:
                raise SystemExit(
                    f"cycle {cycle}: session {sid} seen counter reset: "
                    f"{resp['body']['seen']} != {seen[sid] + 2}")
            seen[sid] = resp["body"]["seen"]
        print(f"cycle {cycle}/{CYCLES}: {len(sids)} sessions recovered bit-for-bit")

    for sid in sids:
        c.call({"op": "stream_close", "session": sid})
    print(f"crash_recovery: OK ({CYCLES} kill -9 cycles, {len(sids)} sessions)")
finally:
    server.send_signal(signal.SIGKILL)
    server.wait()
EOF

# ---------------------------------------------------------------------
# Chaos arm (ISSUE 10): strict durability under injected journal
# faults. Only meaningful with the failpoints feature compiled in.
# ---------------------------------------------------------------------
if [[ -n "${CHAOS:-}" ]]; then
    if [[ -z "${SKIP_BUILD:-}" ]]; then
        cargo build --release --bin pathsig --features failpoints
    fi
    CJDIR=$(mktemp -d)
    trap 'rm -rf "$JDIR" "$CJDIR"' EXIT

    BIN="$BIN" JDIR="$CJDIR" python3 - <<'EOF'
import json
import os
import signal
import socket
import subprocess

BIN, JDIR = os.environ["BIN"], os.environ["JDIR"]
# Journal appends fail ~20% of the time — a seeded stand-in for a disk
# that intermittently returns ENOSPC. Strict mode must reject those
# ops instead of acking them.
FAULTS = "journal.append=err@p0.2/seed11"


def start_server(faults):
    env = dict(os.environ)
    if faults:
        env["PATHSIG_FAILPOINTS"] = faults
    else:
        env.pop("PATHSIG_FAILPOINTS", None)
    p = subprocess.Popen(
        [BIN, "serve", "--addr", "127.0.0.1:0", "--journal-dir", JDIR,
         "--fsync", "--checkpoint-every", "5", "--shards", "2",
         "--durability", "strict"],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True, env=env)
    for line in p.stdout:
        if "listening on" in line:
            return p, line.strip().rsplit(" ", 1)[1]
    raise SystemExit("server exited before announcing its address")


class V1Client:
    """Raw v1 client that hands back error responses instead of dying —
    strict-mode rejections are expected here."""

    def __init__(self, addr):
        host, port = addr.rsplit(":", 1)
        self.sock = socket.create_connection((host, int(port)), timeout=30)
        self.buf = b""

    def call(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode())
        while b"\n" not in self.buf:
            chunk = self.sock.recv(65536)
            if not chunk:
                raise SystemExit("server closed the connection mid-call")
            self.buf += chunk
        line, self.buf = self.buf.split(b"\n", 1)
        return json.loads(line)


server, addr = start_server(FAULTS)
try:
    c = V1Client(addr)
    # Opens journal too and may be strict-rejected; retry until 4 live.
    sids, acked = [], {}
    attempts = 0
    while len(sids) < 4:
        attempts += 1
        if attempts > 200:
            raise SystemExit("could not open 4 sessions under 20% faults")
        r = c.call({"op": "stream_open", "dim": 1, "depth": 2, "window": 4})
        if r.get("ok"):
            sid = r["body"]["session"]
            sids.append(sid)
            acked[sid] = 0
    rejected = 0
    for i in range(48):
        sid = sids[i % len(sids)]
        r = c.call({"op": "stream_push", "session": sid,
                    "samples": [0.25 * i]})
        if r.get("ok"):
            acked[sid] += 1
            if r["body"]["seen"] != acked[sid]:
                raise SystemExit(
                    f"{sid}: acked seen drifted mid-run: {r}")
        else:
            rejected += 1
            if "strict durability" not in r.get("error", ""):
                raise SystemExit(f"unexpected rejection for {sid}: {r}")
    if rejected == 0:
        raise SystemExit("fault schedule never fired; chaos arm is vacuous")

    # kill -9 under fault, restart CLEAN: every ack must have survived,
    # every rejection must have left no trace.
    server.send_signal(signal.SIGKILL)
    server.wait()
    server, addr = start_server(None)
    c = V1Client(addr)
    for sid in sids:
        r = c.call({"op": "stream_push", "session": sid, "samples": [9.5]})
        if not r.get("ok"):
            raise SystemExit(f"{sid} lost after crash: {r}")
        if r["body"]["seen"] != acked[sid] + 1:
            raise SystemExit(
                f"{sid}: acked {acked[sid]} pushes but recovered "
                f"seen {r['body']['seen'] - 1}")
        w = c.call({"op": "stream_window", "session": sid})
        if not w.get("ok"):
            raise SystemExit(f"{sid}: window failed after recovery: {w}")
    print(f"crash_recovery chaos arm: OK (4 sessions, "
          f"{sum(acked.values())} acked, {rejected} strict-rejected, "
          f"0 lost)")
finally:
    server.send_signal(signal.SIGKILL)
    server.wait()
EOF
fi
