//! Adaptive batch×time scheduler — which parallelism axis a batch call
//! should spend the machine on.
//!
//! The paper's kernels parallelize over **words × batch**; every entry
//! point in this crate additionally cuts the batch into lane blocks so
//! the Horner inner loop is SIMD over paths. That leaves one regime on
//! the table: **long paths with small batches** (large `M`, `B < L` or
//! `B/L < threads`), where the strictly sequential walk down the time
//! axis uses one lane of one core no matter how much hardware is
//! available. Chen's identity is associative, so the time axis can be
//! chunked and reduced — see [`crate::sig::tree`] — at the price of a
//! reassociated summation order (results match the sequential kernels
//! to ~1e-12, not bitwise).
//!
//! This module decides *when* that trade is worth it and *how long* the
//! chunks should be:
//!
//! * **batch-parallel** (the classic path) whenever the batch alone
//!   already fills both the SIMD lanes and the thread pool;
//! * **time-parallel** when `B < L`: lanes are packed over
//!   (path × chunk) units, so even a single path sweeps `L` chunks per
//!   instruction;
//! * **hybrid** when `B ≥ L` but there are fewer lane blocks than
//!   worker threads: lanes stay packed over paths and the spare threads
//!   sweep different chunks of the time axis.
//!
//! The knob: `PATHSIG_TIME_CHUNK` = `auto` (default — the heuristic
//! below), an explicit chunk length in increments (forces that chunk
//! whenever time-parallelism is engaged), or `off`/`0` (always the
//! classic sequential-time path). Paths shorter than
//! [`MIN_TIME_STEPS`] increments never engage time-parallelism, so
//! short-path calls keep their bitwise-stable fast path regardless of
//! the knob.

use super::windows::Window;
use super::SigEngine;

/// Minimum number of increments before a batch call may be routed to
/// the time-parallel tree. Below this the chunking overhead (boundary
/// products, reduction) outweighs any parallel win, and short-path
/// callers keep bitwise-identical results under every knob setting.
pub const MIN_TIME_STEPS: usize = 64;

/// Smallest chunk the `auto` policy will pick (an explicit
/// `PATHSIG_TIME_CHUNK=<C>` may go lower). Keeps the sequential
/// boundary scans a small fraction of the parallel chunk work.
pub(crate) const MIN_CHUNK: usize = 16;

/// Largest chunk the `auto` policy will pick — bounds the per-chunk
/// sequential tail so very long paths still spread over all units.
pub(crate) const MAX_CHUNK: usize = 4096;

/// Parsed `PATHSIG_TIME_CHUNK` policy (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// Pick the chunk length from `(B, M, threads, L)` — the default.
    Auto,
    /// Force this chunk length (in increments) whenever the
    /// time-parallel path engages. The engagement gates (path length,
    /// batch saturation) still apply.
    Fixed(usize),
    /// Never use the time-parallel path.
    Off,
}

/// Parse a raw `PATHSIG_TIME_CHUNK` value (unset ⇒ [`ChunkPolicy::Auto`];
/// unparsable values fall back to `Auto` — with a warning message for
/// the engine to surface, matching the other env knobs). Pure —
/// unit-testable per rejection path without touching the environment.
pub(crate) fn chunk_policy_from_checked(env: Option<&str>) -> (ChunkPolicy, Option<String>) {
    let Some(raw) = env else {
        return (ChunkPolicy::Auto, None);
    };
    let s = raw.trim();
    if s.is_empty() || s.eq_ignore_ascii_case("auto") {
        return (ChunkPolicy::Auto, None);
    }
    if s.eq_ignore_ascii_case("off") {
        return (ChunkPolicy::Off, None);
    }
    match s.parse::<usize>() {
        Ok(0) => (ChunkPolicy::Off, None),
        Ok(c) => (ChunkPolicy::Fixed(c), None),
        Err(_) => (
            ChunkPolicy::Auto,
            Some(format!(
                "ignoring invalid PATHSIG_TIME_CHUNK={raw:?} \
                 (expected auto, off, or a chunk length); using auto"
            )),
        ),
    }
}

/// [`chunk_policy_from_checked`] without the warning channel.
#[cfg(test)]
pub(crate) fn chunk_policy_from(env: Option<&str>) -> ChunkPolicy {
    chunk_policy_from_checked(env).0
}

/// The execution mode the scheduler chose for one batch call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeMode {
    /// Classic path: parallel over paths, lanes over paths (or the
    /// scalar per-path fallback for `B < L`). Sequential over time.
    BatchParallel,
    /// Chunked time-parallel path ([`crate::sig::signature_batch_tree_into`]
    /// and friends): the time axis is split into `ceil(M/chunk)`
    /// chunks swept concurrently, then reduced with the Chen combine.
    TimeParallel {
        /// Chunk length in increments.
        chunk: usize,
    },
}

/// Decide the execution mode for a batch call of `batch` paths with
/// `steps` increments each, from the engine's configuration
/// (`threads`, lane width, `PATHSIG_TIME_CHUNK`).
pub fn plan(eng: &SigEngine, batch: usize, steps: usize) -> TimeMode {
    plan_with(eng.time_chunk, batch, steps, eng.threads, eng.lanes())
}

/// Pure core of [`plan`] (unit-testable without touching the process
/// environment or building engines).
pub(crate) fn plan_with(
    policy: ChunkPolicy,
    batch: usize,
    steps: usize,
    threads: usize,
    lanes: usize,
) -> TimeMode {
    if batch == 0 || steps < MIN_TIME_STEPS {
        return TimeMode::BatchParallel;
    }
    let blocks = batch.div_ceil(lanes);
    if batch >= lanes && blocks >= threads {
        // The batch alone fills the SIMD lanes and the thread pool —
        // chunking the time axis could only add overhead.
        return TimeMode::BatchParallel;
    }
    let chunk = match policy {
        ChunkPolicy::Off => return TimeMode::BatchParallel,
        ChunkPolicy::Fixed(c) => c.max(1),
        ChunkPolicy::Auto => {
            // Target enough (path × chunk) units to fill the idle axis
            // twice over (slack for load balancing): lanes when B < L,
            // threads when only the thread pool is starved.
            let k_target = if batch < lanes {
                (2 * threads * lanes).div_ceil(batch)
            } else {
                (2 * threads).div_ceil(blocks)
            };
            steps.div_ceil(k_target.max(2)).clamp(MIN_CHUNK, MAX_CHUNK)
        }
    };
    let chunk = chunk.min(steps);
    if steps.div_ceil(chunk) < 2 {
        return TimeMode::BatchParallel;
    }
    TimeMode::TimeParallel { chunk }
}

/// Snap a window-call chunk length onto the windows' start grid: if
/// every window start is a multiple of some `g ≥ 4`, pick a divisor of
/// `g` near `chunk` so window left edges coincide with chunk
/// boundaries and the per-window head sweep vanishes (sliding windows
/// with stride `s` get this for free whenever the chunk divides `s`).
/// Falls back to `chunk` unchanged when the starts share no useful
/// grid — unaligned heads are still handled correctly, just without
/// the reuse.
pub(crate) fn snap_chunk(chunk: usize, windows: &[Window]) -> usize {
    fn gcd(mut a: usize, mut b: usize) -> usize {
        while b != 0 {
            let t = a % b;
            a = b;
            b = t;
        }
        a
    }
    let g = windows.iter().fold(0usize, |acc, w| gcd(acc, w.l));
    if g == 0 {
        // Every window starts at 0 — aligned for any chunk.
        return chunk;
    }
    if g < 4 {
        return chunk;
    }
    if g <= 2 * chunk {
        // Snap only when the grid stays comparable to the suggested
        // chunk — a tiny gcd would explode the chunk count (memory and
        // scan cost scale with it) for a minor head-sweep saving.
        return if 4 * g >= chunk { g } else { chunk };
    }
    // Largest divisor of g not exceeding the suggested chunk.
    let mut best = 1;
    for c in (1..=chunk.min(g)).rev() {
        if g % c == 0 {
            best = c;
            break;
        }
    }
    if best >= 4 {
        best
    } else {
        chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing() {
        assert_eq!(chunk_policy_from(None), ChunkPolicy::Auto);
        assert_eq!(chunk_policy_from(Some("auto")), ChunkPolicy::Auto);
        assert_eq!(chunk_policy_from(Some(" AUTO ")), ChunkPolicy::Auto);
        assert_eq!(chunk_policy_from(Some("")), ChunkPolicy::Auto);
        assert_eq!(chunk_policy_from(Some("off")), ChunkPolicy::Off);
        assert_eq!(chunk_policy_from(Some("0")), ChunkPolicy::Off);
        assert_eq!(chunk_policy_from(Some("64")), ChunkPolicy::Fixed(64));
        assert_eq!(chunk_policy_from(Some(" 4 ")), ChunkPolicy::Fixed(4));
        assert_eq!(chunk_policy_from(Some("garbage")), ChunkPolicy::Auto);
    }

    #[test]
    fn policy_rejections_warn_with_value_and_default() {
        // Valid spellings stay warning-free…
        for ok in [None, Some("auto"), Some("off"), Some("0"), Some("64"), Some("")] {
            assert!(chunk_policy_from_checked(ok).1.is_none(), "{ok:?}");
        }
        // …every rejection path names the rejected value and the
        // default (`auto`) actually used.
        for bad in ["garbage", "-3", "4x", "1.5", "off please"] {
            let (p, warn) = chunk_policy_from_checked(Some(bad));
            assert_eq!(p, ChunkPolicy::Auto, "{bad}");
            let msg = warn.expect("rejected PATHSIG_TIME_CHUNK must warn");
            assert!(
                msg.contains("PATHSIG_TIME_CHUNK") && msg.contains(bad) && msg.contains("auto"),
                "{msg}"
            );
        }
    }

    #[test]
    fn short_paths_never_engage() {
        for policy in [ChunkPolicy::Auto, ChunkPolicy::Fixed(4)] {
            assert_eq!(
                plan_with(policy, 1, MIN_TIME_STEPS - 1, 8, 8),
                TimeMode::BatchParallel
            );
        }
    }

    #[test]
    fn saturated_batches_stay_batch_parallel() {
        // B ≥ L and enough lane blocks to fill every thread.
        assert_eq!(plan_with(ChunkPolicy::Auto, 64, 4096, 4, 8), TimeMode::BatchParallel);
        assert_eq!(plan_with(ChunkPolicy::Fixed(32), 64, 4096, 4, 8), TimeMode::BatchParallel);
    }

    #[test]
    fn long_single_path_goes_time_parallel() {
        // B = 1, M = 4096, 4 threads × 8 lanes: target 64 chunks.
        match plan_with(ChunkPolicy::Auto, 1, 4096, 4, 8) {
            TimeMode::TimeParallel { chunk } => {
                assert_eq!(chunk, 64);
            }
            other => panic!("expected time-parallel, got {other:?}"),
        }
        // Single-threaded still engages: the win is SIMD lanes over
        // chunks.
        assert!(matches!(
            plan_with(ChunkPolicy::Auto, 1, 4096, 1, 8),
            TimeMode::TimeParallel { .. }
        ));
    }

    #[test]
    fn hybrid_regime_engages_when_blocks_underfill_threads() {
        // B = 16 = 2 lane blocks < 16 threads: spare threads sweep the
        // time axis.
        assert!(matches!(
            plan_with(ChunkPolicy::Auto, 16, 10_000, 16, 8),
            TimeMode::TimeParallel { .. }
        ));
    }

    #[test]
    fn fixed_policy_forces_chunk_length() {
        match plan_with(ChunkPolicy::Fixed(4), 1, 256, 4, 8) {
            TimeMode::TimeParallel { chunk } => assert_eq!(chunk, 4),
            other => panic!("expected forced chunk, got {other:?}"),
        }
        // Off always wins.
        assert_eq!(plan_with(ChunkPolicy::Off, 1, 1 << 20, 8, 8), TimeMode::BatchParallel);
    }

    #[test]
    fn single_chunk_falls_back() {
        // Forced chunk covering the whole path ⇒ no parallelism to win.
        assert_eq!(
            plan_with(ChunkPolicy::Fixed(100_000), 1, 4096, 4, 8),
            TimeMode::BatchParallel
        );
    }

    #[test]
    fn auto_clamps_chunk_range() {
        // Tiny path just over the gate: chunk clamps to MIN_CHUNK.
        match plan_with(ChunkPolicy::Auto, 1, MIN_TIME_STEPS, 8, 32) {
            TimeMode::TimeParallel { chunk } => assert_eq!(chunk, MIN_CHUNK),
            other => panic!("{other:?}"),
        }
        // Huge path: chunk clamps to MAX_CHUNK.
        match plan_with(ChunkPolicy::Auto, 1, 1 << 24, 2, 4) {
            TimeMode::TimeParallel { chunk } => assert_eq!(chunk, MAX_CHUNK),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn snapping_aligns_to_window_starts() {
        let wins: Vec<Window> = (0..4).map(|i| Window::new(i * 24, i * 24 + 48)).collect();
        // gcd of starts is 24 ≤ 2·chunk ⇒ snap to 24.
        assert_eq!(snap_chunk(32, &wins), 24);
        // All-zero starts: nothing to snap.
        let expanding: Vec<Window> = (1..5).map(|r| Window::new(0, r * 10)).collect();
        assert_eq!(snap_chunk(32, &expanding), 32);
        // Large gcd: pick its largest divisor ≤ chunk.
        let wide: Vec<Window> = (0..3).map(|i| Window::new(i * 96, i * 96 + 100)).collect();
        assert_eq!(snap_chunk(32, &wide), 32); // 96 % 32 == 0
        let wide2: Vec<Window> = (0..3).map(|i| Window::new(i * 90, i * 90 + 100)).collect();
        assert_eq!(snap_chunk(32, &wide2), 30); // largest divisor of 90 ≤ 32
        // No useful grid (gcd 1): unchanged.
        let ragged = vec![Window::new(3, 40), Window::new(7, 50)];
        assert_eq!(snap_chunk(32, &ragged), 32);
        // A tiny gcd must not explode the chunk count.
        let fine: Vec<Window> = (0..4).map(|i| Window::new(i * 4, i * 4 + 200)).collect();
        assert_eq!(snap_chunk(64, &fine), 64);
    }
}
