//! Backward pass — §4 of the paper, memory-minimal form.
//!
//! Given output cotangents `∂L/∂S_{0,T}(X,w)` for `w ∈ I`, produce
//! `∂L/∂X_j^{(i)}` while storing **only the terminal signature** from the
//! forward pass. Intermediate signatures are reconstructed backward in
//! time with the group inverse (Prop 4.6: `S_{0,t_{j-1}} = S_{0,t_j} ⊗
//! exp(-ΔX_j)`), and the cotangent state `λ` is propagated by the exact
//! transpose of the forward Chen update.
//!
//! Derivation used here (equivalent to Prop 4.1/4.2; see DESIGN.md):
//! the forward step `S_j(w) = Σ_{w=p∘s} S_{j-1}(p)·exp(ΔX_j, s)` is
//! *linear* in `S_{j-1}`, so reverse-mode gives
//!
//! ```text
//! λ_{j-1}(p)      = Σ_{w=p∘s ∈ C} λ_j(w)·exp(ΔX_j, s)        (transpose)
//! ∂L/∂ΔX_j^{(i)}  = Σ_w λ_j(w) Σ_{w=p∘s} S_{j-1}(p)·∂exp(ΔX_j, s)/∂ΔX^{(i)}
//! ```
//!
//! Both sums run over prefix decompositions of words in the closure `C`,
//! so the prefix tables of [`crate::words::WordTable`] suffice — no
//! suffix indices are needed. Per word of length `n`, the ΔX-gradient is
//! accumulated in `O(n)` via a left-to-right Horner sweep (`A_p`) against
//! precomputed right suffix products (`R_p`):
//! `∂/∂ΔX^{(i_p)} += λ(w)·A_p·R_p` with
//! `A_{p+1} = A_p·ΔX^{(i_p)} + S_{j-1}(w_[p])/(n-p)!`.
//!
//! **Batching.** [`sig_backward_batch_into`] cuts the batch into blocks
//! of [`SigEngine::lanes`] paths and runs the whole reverse sweep —
//! inverse reconstruction, cotangent transpose, ΔX-gradient — in the
//! lane-major SoA layout of [`crate::sig::lanes`], amortizing the CSR
//! word walk across `L` paths exactly as the forward kernel does. The
//! scalar per-path kernel remains the `B < L` fallback and the
//! differential-testing oracle ([`sig_backward_batch_scalar`]).
//! [`signature_and_backward_batch_into`] fuses forward and backward,
//! reusing the terminal state of the forward sweep instead of
//! recomputing it — one forward pass per training step, not two.

use super::forward::forward_sweep_range;
use super::lanes::{
    backward_step_lanes, chen_update_lanes, lane_dispatch, lane_forward, project_block,
    ForwardWorkspace,
};
use super::schedule::{self, TimeMode};
use super::{chen_update, SigEngine};
use crate::util::threadpool::{parallel_for_into, parallel_map, SendPtr};

/// Reusable buffers for the backward pass (scalar and lane-major).
#[derive(Debug, Default)]
pub struct BackwardWorkspace {
    /// Embedded forward scratch: scalar `state`/`dx` plus the
    /// lane-major `lane_state`/`dx_lanes` matrices — the backward pass
    /// reconstructs signatures in the same buffers the forward sweep
    /// fills, which is what makes the fused entry points reuse the
    /// terminal state for free.
    fwd: ForwardWorkspace,
    lambda: Vec<f64>,
    neg_dx: Vec<f64>,
    right_prod: Vec<f64>,
    grad_dx: Vec<f64>,
    /// Lane-major cotangent state, `state_len × L`.
    lane_lambda: Vec<f64>,
    /// Lane-major negated increments, `d × L`.
    neg_dx_lanes: Vec<f64>,
    /// Lane-major right suffix products, `(max_level + 1) × L`.
    right_prod_lanes: Vec<f64>,
    /// Lane-major per-step increment gradient, `d × L`.
    gdx_lanes: Vec<f64>,
}

impl BackwardWorkspace {
    /// Size the lane-major buffers for `eng` (idempotent; free in
    /// steady state — a bare `resize` within capacity neither
    /// allocates nor writes, and every buffer is fully re-initialized
    /// by the kernels before being read: `lane_lambda` and the dx
    /// buffers are `fill`ed per block, `gdx_lanes` per step, and
    /// `right_prod_lanes` rows are written before use per word).
    fn ensure_lanes(&mut self, eng: &SigEngine) {
        let l = eng.lanes();
        self.fwd.ensure_lanes(eng);
        self.lane_lambda.resize(eng.table.state_len * l, 0.0);
        self.neg_dx_lanes.resize(eng.table.d * l, 0.0);
        self.right_prod_lanes.resize((eng.table.max_level + 1) * l, 0.0);
        self.gdx_lanes.resize(eng.table.d * l, 0.0);
    }
}

/// Gradient of `L` with respect to the path points, for a single path.
///
/// * `path` — row-major `(M+1, d)`.
/// * `grad_out` — `∂L/∂(projected signature)`, length `|I|`.
///
/// Returns `∂L/∂X` as row-major `(M+1, d)`. Memory: `O(|C|)` plus the
/// path itself — the paper's `O(B·D_sig)` claim (Table 2) with `B = 1`.
pub fn sig_backward(eng: &SigEngine, path: &[f64], grad_out: &[f64]) -> Vec<f64> {
    let mut ws = BackwardWorkspace::default();
    sig_backward_ws(eng, path, grad_out, &mut ws)
}

/// [`sig_backward`] with caller-provided workspace.
pub fn sig_backward_ws(
    eng: &SigEngine,
    path: &[f64],
    grad_out: &[f64],
    ws: &mut BackwardWorkspace,
) -> Vec<f64> {
    let mut out = vec![0.0; path.len()];
    sig_backward_into(eng, path, grad_out, ws, &mut out);
    out
}

/// [`sig_backward`] with caller-provided workspace **and** output
/// buffer (`out.len() == path.len()`) — the zero-allocation hot path.
pub fn sig_backward_into(
    eng: &SigEngine,
    path: &[f64],
    grad_out: &[f64],
    ws: &mut BackwardWorkspace,
    out: &mut [f64],
) {
    let t = &eng.table;
    let d = t.d;
    assert_eq!(path.len() % d, 0);
    let m1 = path.len() / d;
    let steps = m1 - 1;
    assert_eq!(grad_out.len(), t.out_dim());
    assert_eq!(out.len(), path.len(), "gradient buffer has wrong size");

    // Forward pass to the terminal signature (the only stored state).
    forward_sweep_range(eng, path, 0, steps, &mut ws.fwd.state, &mut ws.fwd.dx);
    scalar_backward_from_state(eng, path, grad_out, ws, out);
}

/// The reverse sweep of [`sig_backward_into`], assuming `ws.fwd.state`
/// already holds the terminal closure state for `path` (how the fused
/// entry points avoid the second forward pass).
fn scalar_backward_from_state(
    eng: &SigEngine,
    path: &[f64],
    grad_out: &[f64],
    ws: &mut BackwardWorkspace,
    out: &mut [f64],
) {
    let t = &eng.table;
    let d = t.d;
    let m1 = path.len() / d;
    let steps = m1 - 1;

    // Seed λ_M: scatter the output cotangents onto the closure.
    ws.lambda.clear();
    ws.lambda.resize(t.state_len, 0.0);
    t.scatter_grad(grad_out, &mut ws.lambda);

    ws.fwd.dx.resize(d, 0.0);
    ws.neg_dx.clear();
    ws.neg_dx.resize(d, 0.0);
    ws.right_prod.clear();
    ws.right_prod.resize(t.max_level + 1, 0.0);
    ws.grad_dx.clear();
    ws.grad_dx.resize(steps * d, 0.0);

    for j in (1..=steps).rev() {
        for i in 0..d {
            ws.fwd.dx[i] = path[j * d + i] - path[(j - 1) * d + i];
            ws.neg_dx[i] = -ws.fwd.dx[i];
        }
        // Reconstruct S_{j-1} (Prop 4.6): S ← S ⊗ exp(-ΔX_j).
        chen_update(eng, &mut ws.fwd.state, &ws.neg_dx);

        // λ transpose + ΔX gradient, one in-place sweep over the
        // closure. The transpose sends contributions strictly from a
        // word to its *shorter* prefixes, so processing levels in
        // ASCENDING order reads every λ(w) before any contribution to
        // it lands — no double buffer needed (mirror of the forward's
        // descending in-place trick; the s = ε split is the identity
        // term λ(w) += λ(w)·1, a no-op in place).
        let gdx = &mut ws.grad_dx[(j - 1) * d..j * d];
        let lambda = ws.lambda.as_mut_slice();
        let state = ws.fwd.state.as_slice();
        let right_prod = ws.right_prod.as_mut_slice();
        let dx = ws.fwd.dx.as_slice();
        for n in 1..=t.max_level {
            let inv_fact_n = eng.inv_fact[n];
            let level_base = t.level_csr_base(n);
            let level = t.level_range(n);
            for (off, w) in level.enumerate() {
                // SAFETY: all indices below come from the validated
                // WordTable (letters < d, prefix indices < state_len,
                // CSR rows within bounds) — checked by
                // `WordTable::check_invariants` in tests.
                unsafe {
                    let lam = *lambda.get_unchecked(w);
                    if lam == 0.0 {
                        continue;
                    }
                    let base = level_base + off * n;
                    let letters = t.csr_letters.get_unchecked(base..base + n);
                    let prefixes = t.csr_prefix.get_unchecked(base..base + n);
                    // Right suffix products R_p = Π_{q=p+1..n} dx_{i_q}.
                    *right_prod.get_unchecked_mut(n) = 1.0;
                    for p in (1..n).rev() {
                        let letter = *letters.get_unchecked(p) as usize; // i_{p+1}
                        *right_prod.get_unchecked_mut(p) =
                            right_prod.get_unchecked(p + 1) * dx.get_unchecked(letter);
                    }
                    // Fused sweep over positions p = 1..=n:
                    //   gdx[i_p]    += λ·A_p·R_p       (A_1 = 1/n!)
                    //   λ(w_[p-1])  += λ·e_{p-1}, e_k = dx_{i_{k+1}}·R_{k+1}/(n-k)!
                    //   A_{p+1}      = A_p·dx_{i_p} + S(w_[p])/(n-p)!
                    let mut a = inv_fact_n;
                    for p in 1..=n {
                        let letter = *letters.get_unchecked(p - 1) as usize; // i_p
                        let dxl = *dx.get_unchecked(letter);
                        let rp = *right_prod.get_unchecked(p);
                        *gdx.get_unchecked_mut(letter) += lam * a * rp;
                        let e_k = dxl * rp * eng.inv_fact.get_unchecked(n - p + 1);
                        *lambda.get_unchecked_mut(*prefixes.get_unchecked(p - 1) as usize) +=
                            lam * e_k;
                        if p < n {
                            let s_pref =
                                *state.get_unchecked(*prefixes.get_unchecked(p) as usize);
                            a = a * dxl + s_pref * eng.inv_fact.get_unchecked(n - p);
                        }
                    }
                }
            }
        }
    }

    // Chain rule from increments to points:
    // ∂L/∂X_0 = -g_1, ∂L/∂X_j = g_j - g_{j+1}, ∂L/∂X_M = g_M.
    out.fill(0.0);
    for i in 0..d {
        if steps > 0 {
            out[i] = -ws.grad_dx[i];
            out[steps * d + i] = ws.grad_dx[(steps - 1) * d + i];
        }
    }
    for j in 1..steps {
        for i in 0..d {
            out[j * d + i] = ws.grad_dx[(j - 1) * d + i] - ws.grad_dx[j * d + i];
        }
    }
}

/// Lane-major reverse sweep over one block of `nb ≤ L` paths,
/// mirroring [`scalar_backward_from_state`] with the lane axis
/// contiguous. If `reuse_terminal` is set, `ws.fwd.lane_state` must
/// already hold the block's terminal lane state (fused path);
/// otherwise the forward sweep runs first. `grads` holds `nb`
/// consecutive cotangent rows (`|I|` each); `out` is the block's
/// `nb · per_path` gradient rows, written in place.
#[allow(clippy::too_many_arguments)]
fn lane_backward<const L: usize>(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    grads: &[f64],
    ws: &mut BackwardWorkspace,
    out: &mut [f64],
    reuse_terminal: bool,
) {
    let t = &eng.table;
    let d = t.d;
    let sl = t.state_len;
    let odim = t.out_dim();
    let m1 = per_path / d;
    let steps = m1 - 1;
    debug_assert!(nb >= 1 && nb <= L);
    debug_assert_eq!(block.len(), nb * per_path);
    debug_assert_eq!(grads.len(), nb * odim);
    debug_assert_eq!(out.len(), nb * per_path);
    if !reuse_terminal {
        lane_forward::<L>(eng, block, nb, per_path, 0, steps, &mut ws.fwd);
    }
    let lane_state = &mut ws.fwd.lane_state[..sl * L];
    let dx_lanes = &mut ws.fwd.dx_lanes[..d * L];
    let lane_lambda = &mut ws.lane_lambda[..sl * L];
    let neg_dx = &mut ws.neg_dx_lanes[..d * L];
    let right_prod = &mut ws.right_prod_lanes[..(t.max_level + 1) * L];
    let gdx = &mut ws.gdx_lanes[..d * L];

    // Seed λ_M per lane: scatter each path's output cotangents onto
    // the closure (accumulating on duplicate requests, like
    // `WordTable::scatter_grad`).
    lane_lambda.fill(0.0);
    for (l, grow) in grads.chunks_exact(odim).enumerate() {
        for (g, &idx) in grow.iter().zip(&t.output_map) {
            lane_lambda[idx as usize * L + l] += *g;
        }
    }
    // Inactive lanes (nb < L) keep Δx = 0 and λ = 0 throughout: the
    // reconstruction leaves them at the trivial signature and every
    // gradient contribution is an exact zero.
    dx_lanes.fill(0.0);
    neg_dx.fill(0.0);
    out.fill(0.0);

    for j in (1..=steps).rev() {
        // Transpose this step's increments into lane-major layout.
        for (l, p) in block.chunks_exact(per_path).enumerate() {
            for i in 0..d {
                let v = p[j * d + i] - p[(j - 1) * d + i];
                dx_lanes[i * L + l] = v;
                neg_dx[i * L + l] = -v;
            }
        }
        // Reconstruct S_{j-1} for all lanes (Prop 4.6).
        chen_update_lanes::<L>(eng, lane_state, neg_dx);
        gdx.fill(0.0);
        backward_step_lanes::<L>(eng, lane_state, lane_lambda, dx_lanes, right_prod, gdx);
        // De-transpose g_j into each path's point-j slot (converted to
        // point gradients below).
        for (l, row) in out.chunks_exact_mut(per_path).enumerate() {
            for i in 0..d {
                row[j * d + i] = gdx[i * L + l];
            }
        }
    }

    // Chain rule from increments to points, in place per path:
    // ∂L/∂X_0 = -g_1, ∂L/∂X_j = g_j - g_{j+1}, ∂L/∂X_M = g_M.
    // Ascending j reads slot j+1 before it is rewritten.
    for row in out.chunks_exact_mut(per_path) {
        if steps == 0 {
            continue; // already zero
        }
        for i in 0..d {
            row[i] = -row[d + i];
        }
        for j in 1..steps {
            for i in 0..d {
                row[j * d + i] -= row[(j + 1) * d + i];
            }
        }
    }
}

/// Monomorphization dispatch for [`lane_backward`] on the engine's
/// lane width.
#[allow(clippy::too_many_arguments)]
fn lane_backward_dispatch(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    grads: &[f64],
    ws: &mut BackwardWorkspace,
    out: &mut [f64],
    reuse_terminal: bool,
) {
    lane_dispatch!(
        eng.lanes(),
        lane_backward(eng, block, nb, per_path, grads, ws, out, reuse_terminal)
    );
}

/// Batched backward: `paths` `(B, M+1, d)`, `grads_out` `(B, |I|)` →
/// `(B, M+1, d)`. Blocks of [`SigEngine::lanes`] paths run the
/// lane-major SIMD kernel; `B < L` falls back to the scalar per-path
/// kernel.
pub fn sig_backward_batch(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
) -> Vec<f64> {
    let mut out = vec![0.0; paths.len()];
    sig_backward_batch_into(eng, paths, grads_out, batch, &mut out);
    out
}

/// [`sig_backward_batch`] writing into a caller-provided `(B, M+1, d)`
/// buffer: each lane block's gradient rows are written in place by a
/// pooled per-worker workspace — no per-row allocation, no post-join
/// copy.
pub fn sig_backward_batch_into(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
    out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(grads_out.len(), batch * odim);
    assert_eq!(out.len(), paths.len(), "gradient buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 1, "bad path shape");
    // Long paths with small batches route to the checkpointed
    // time-parallel backward (see `schedule` / `tree`).
    if let TimeMode::TimeParallel { chunk } = schedule::plan(eng, batch, per_path / d - 1) {
        return super::tree::sig_backward_batch_tree_into(eng, paths, grads_out, batch, chunk, out);
    }
    let lanes = eng.lanes();

    if batch < lanes {
        // Scalar per-path fallback, rows still written in place.
        let nw = eng.threads.min(batch).max(1);
        let mut workers = eng.bwd_pool.take_at_least(nw);
        parallel_for_into(out, per_path, &mut workers[..nw], |b, row, ws| {
            sig_backward_into(
                eng,
                &paths[b * per_path..(b + 1) * per_path],
                &grads_out[b * odim..(b + 1) * odim],
                ws,
                row,
            );
        });
        eng.bwd_pool.put(workers);
        return;
    }

    // Lane-major path: each unit is a block of `lanes` paths (last
    // block may be partial — padded lanes stay inert).
    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks).max(1);
    let mut workers = eng.bwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes(eng);
    }
    parallel_for_into(out, lanes * per_path, &mut workers[..nw], |blk, out_rows, ws| {
        let b0 = blk * lanes;
        let nb = (batch - b0).min(lanes);
        lane_backward_dispatch(
            eng,
            &paths[b0 * per_path..(b0 + nb) * per_path],
            nb,
            per_path,
            &grads_out[b0 * odim..(b0 + nb) * odim],
            ws,
            out_rows,
            false,
        );
    });
    eng.bwd_pool.put(workers);
}

/// The pre-lane scalar batch path: one allocation-per-row
/// `parallel_map` over paths. Kept verbatim as (a) the baseline the
/// Table-1 bench measures the lane backward against and (b) the
/// differential-testing oracle for [`sig_backward_batch`].
pub fn sig_backward_batch_scalar(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
) -> Vec<f64> {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(grads_out.len(), batch * odim);
    let rows = parallel_map(batch, eng.threads, |b| {
        sig_backward(
            eng,
            &paths[b * per_path..(b + 1) * per_path],
            &grads_out[b * odim..(b + 1) * odim],
        )
    });
    let mut out = Vec::with_capacity(paths.len());
    for row in rows {
        out.extend(row);
    }
    out
}

/// Fused forward + backward over a batch: signatures `(B, |I|)` and
/// path gradients `(B, M+1, d)` from **one** forward sweep — the
/// reverse reconstruction starts from the terminal state the forward
/// sweep just produced instead of recomputing it. This is the
/// training-step primitive (Table 1: forward + backward per step).
pub fn signature_and_backward_batch(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
) -> (Vec<f64>, Vec<f64>) {
    let mut sig_out = vec![0.0; batch * eng.out_dim()];
    let mut grad_out = vec![0.0; paths.len()];
    signature_and_backward_batch_into(eng, paths, grads_out, batch, &mut sig_out, &mut grad_out);
    (sig_out, grad_out)
}

/// [`signature_and_backward_batch`] writing into caller-provided
/// buffers (`sig_out.len() == B·|I|`, `grad_out.len() == paths.len()`)
/// — zero allocations in steady state.
pub fn signature_and_backward_batch_into(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
    sig_out: &mut [f64],
    grad_out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(grads_out.len(), batch * odim);
    assert_eq!(sig_out.len(), batch * odim, "signature buffer has wrong size");
    assert_eq!(grad_out.len(), paths.len(), "gradient buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 1, "bad path shape");
    let steps = per_path / d - 1;
    // Long paths with small batches: the fused tree gets the signature
    // from the boundary prefix scan the backward needs anyway.
    if let TimeMode::TimeParallel { chunk } = schedule::plan(eng, batch, steps) {
        return super::tree::signature_and_backward_batch_tree_into(
            eng, paths, grads_out, batch, chunk, sig_out, grad_out,
        );
    }
    let lanes = eng.lanes();
    // SAFETY (both branches below): each unit index is claimed exactly
    // once by `parallel_for_into`, so the signature rows derived from
    // it are disjoint; `sig_out` outlives the scoped workers.
    let sig_ptr = SendPtr(sig_out.as_mut_ptr());

    if batch < lanes {
        // Scalar fallback: forward once into the workspace, project,
        // then run the reverse sweep from the state just computed.
        let nw = eng.threads.min(batch).max(1);
        let mut workers = eng.bwd_pool.take_at_least(nw);
        parallel_for_into(grad_out, per_path, &mut workers[..nw], move |b, row, ws| {
            // Capture the SendPtr wrapper by value (edition-2021
            // disjoint capture would otherwise grab the raw field and
            // lose the Send impl).
            let sig_ptr = sig_ptr;
            let path = &paths[b * per_path..(b + 1) * per_path];
            forward_sweep_range(eng, path, 0, steps, &mut ws.fwd.state, &mut ws.fwd.dx);
            let sig_row =
                unsafe { std::slice::from_raw_parts_mut(sig_ptr.0.add(b * odim), odim) };
            eng.table.project(&ws.fwd.state, sig_row);
            scalar_backward_from_state(
                eng,
                path,
                &grads_out[b * odim..(b + 1) * odim],
                ws,
                row,
            );
        });
        eng.bwd_pool.put(workers);
        return;
    }

    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks).max(1);
    let mut workers = eng.bwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes(eng);
    }
    parallel_for_into(
        grad_out,
        lanes * per_path,
        &mut workers[..nw],
        move |blk, out_rows, ws| {
            // See above: capture the SendPtr wrapper, not its field.
            let sig_ptr = sig_ptr;
            let b0 = blk * lanes;
            let nb = (batch - b0).min(lanes);
            let block = &paths[b0 * per_path..(b0 + nb) * per_path];
            super::lanes::lane_forward_dispatch(eng, block, nb, per_path, 0, steps, &mut ws.fwd);
            let sig_rows = unsafe {
                std::slice::from_raw_parts_mut(sig_ptr.0.add(b0 * odim), nb * odim)
            };
            project_block(eng, &ws.fwd.lane_state, lanes, nb, sig_rows);
            lane_backward_dispatch(
                eng,
                block,
                nb,
                per_path,
                &grads_out[b0 * odim..(b0 + nb) * odim],
                ws,
                out_rows,
                true,
            );
        },
    );
    eng.bwd_pool.put(workers);
}

/// Batched forward that also **exports each path's terminal closure
/// state** (`states_out`, `(B, state_len)` row-major) alongside the
/// projected signatures (`sig_out`, `(B, |I|)`). The exported states
/// are the cache that lets a later
/// [`sig_backward_batch_from_states_into`] skip its forward sweep —
/// the two-phase form of the fused entry point for training loops
/// where the cotangents only exist after a head/loss evaluation.
/// Memory cost of the cache is the paper's `O(B·D_sig)` (Table 2).
///
/// The two-phase pair always uses the sequential-time kernels (its
/// contract is the cached terminal state, which the time-parallel
/// tree replaces with chunk-boundary checkpoints); long-path training
/// should prefer [`signature_and_backward_batch_into`], which routes
/// through the checkpointed tree automatically.
pub fn signature_batch_states_into(
    eng: &SigEngine,
    paths: &[f64],
    batch: usize,
    sig_out: &mut [f64],
    states_out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    let sl = eng.table.state_len;
    assert_eq!(sig_out.len(), batch * odim, "signature buffer has wrong size");
    assert_eq!(states_out.len(), batch * sl, "state buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 1, "bad path shape");
    let steps = per_path / d - 1;
    let lanes = eng.lanes();
    // SAFETY (both branches): each unit index is claimed exactly once
    // by `parallel_for_into`, so the signature rows derived from it
    // are disjoint; `sig_out` outlives the scoped workers.
    let sig_ptr = SendPtr(sig_out.as_mut_ptr());

    if batch < lanes {
        let nw = eng.threads.min(batch).max(1);
        let mut workers = eng.bwd_pool.take_at_least(nw);
        parallel_for_into(states_out, sl, &mut workers[..nw], move |b, state_row, ws| {
            let sig_ptr = sig_ptr; // capture the wrapper, not its field
            let path = &paths[b * per_path..(b + 1) * per_path];
            forward_sweep_range(eng, path, 0, steps, &mut ws.fwd.state, &mut ws.fwd.dx);
            let sig_row =
                unsafe { std::slice::from_raw_parts_mut(sig_ptr.0.add(b * odim), odim) };
            eng.table.project(&ws.fwd.state, sig_row);
            state_row.copy_from_slice(&ws.fwd.state);
        });
        eng.bwd_pool.put(workers);
        return;
    }

    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks).max(1);
    let mut workers = eng.bwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes(eng);
    }
    parallel_for_into(
        states_out,
        lanes * sl,
        &mut workers[..nw],
        move |blk, state_rows, ws| {
            let sig_ptr = sig_ptr; // capture the wrapper, not its field
            let b0 = blk * lanes;
            let nb = (batch - b0).min(lanes);
            let block = &paths[b0 * per_path..(b0 + nb) * per_path];
            super::lanes::lane_forward_dispatch(eng, block, nb, per_path, 0, steps, &mut ws.fwd);
            let sig_rows = unsafe {
                std::slice::from_raw_parts_mut(sig_ptr.0.add(b0 * odim), nb * odim)
            };
            project_block(eng, &ws.fwd.lane_state, lanes, nb, sig_rows);
            // De-transpose the terminal lane states into per-path rows.
            for (l, row) in state_rows.chunks_exact_mut(sl).enumerate() {
                for (w, slot) in row.iter_mut().enumerate() {
                    *slot = ws.fwd.lane_state[w * lanes + l];
                }
            }
        },
    );
    eng.bwd_pool.put(workers);
}

/// Batched backward starting from **cached terminal states** (the
/// `(B, state_len)` rows exported by [`signature_batch_states_into`])
/// instead of re-running the forward sweep — the reverse
/// reconstruction begins directly at `S_{0,T}`. With this pair a
/// training step performs exactly one forward pass even though the
/// cotangents arrive late (after the loss).
pub fn sig_backward_batch_from_states_into(
    eng: &SigEngine,
    paths: &[f64],
    states: &[f64],
    grads_out: &[f64],
    batch: usize,
    out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    let sl = eng.table.state_len;
    assert_eq!(states.len(), batch * sl, "state cache has wrong size");
    assert_eq!(grads_out.len(), batch * odim);
    assert_eq!(out.len(), paths.len(), "gradient buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 1, "bad path shape");
    let lanes = eng.lanes();

    if batch < lanes {
        let nw = eng.threads.min(batch).max(1);
        let mut workers = eng.bwd_pool.take_at_least(nw);
        parallel_for_into(out, per_path, &mut workers[..nw], |b, row, ws| {
            ws.fwd.state.clear();
            ws.fwd.state.extend_from_slice(&states[b * sl..(b + 1) * sl]);
            scalar_backward_from_state(
                eng,
                &paths[b * per_path..(b + 1) * per_path],
                &grads_out[b * odim..(b + 1) * odim],
                ws,
                row,
            );
        });
        eng.bwd_pool.put(workers);
        return;
    }

    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks).max(1);
    let mut workers = eng.bwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes(eng);
    }
    parallel_for_into(out, lanes * per_path, &mut workers[..nw], |blk, out_rows, ws| {
        let b0 = blk * lanes;
        let nb = (batch - b0).min(lanes);
        // Transpose the cached per-path states into the lane-major
        // layout; inactive lanes stay zero (finite — their λ is zero,
        // so every contribution they touch is an exact zero).
        ws.fwd.lane_state.fill(0.0);
        for l in 0..nb {
            let row = &states[(b0 + l) * sl..(b0 + l + 1) * sl];
            for (w, &v) in row.iter().enumerate() {
                ws.fwd.lane_state[w * lanes + l] = v;
            }
        }
        lane_backward_dispatch(
            eng,
            &paths[b0 * per_path..(b0 + nb) * per_path],
            nb,
            per_path,
            &grads_out[b0 * odim..(b0 + nb) * odim],
            ws,
            out_rows,
            true,
        );
    });
    eng.bwd_pool.put(workers);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature, signature_batch};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, Word, WordTable};

    fn trunc_engine(d: usize, n: usize) -> SigEngine {
        SigEngine::new(WordTable::build(d, &truncated_words(d, n)))
    }

    /// Central finite-difference gradient of L(X) = <g, sig(X)>.
    fn fd_grad(eng: &SigEngine, path: &[f64], g: &[f64], eps: f64) -> Vec<f64> {
        let mut out = vec![0.0; path.len()];
        let mut p = path.to_vec();
        for k in 0..path.len() {
            p[k] = path[k] + eps;
            let up: f64 = signature(eng, &p).iter().zip(g).map(|(a, b)| a * b).sum();
            p[k] = path[k] - eps;
            let dn: f64 = signature(eng, &p).iter().zip(g).map(|(a, b)| a * b).sum();
            p[k] = path[k];
            out[k] = (up - dn) / (2.0 * eps);
        }
        out
    }

    #[test]
    fn gradcheck_truncated() {
        let mut rng = Rng::new(200);
        for &(d, n, m) in &[(2, 3, 4), (3, 2, 6), (2, 5, 3), (4, 3, 5)] {
            let eng = trunc_engine(d, n);
            let path = rng.brownian_path(m, d, 0.6);
            let g: Vec<f64> = (0..eng.out_dim()).map(|_| rng.gaussian()).collect();
            let got = sig_backward(&eng, &path, &g);
            let want = fd_grad(&eng, &path, &g, 1e-5);
            assert_allclose(&got, &want, 1e-6, 1e-5, &format!("gradcheck d={d} n={n} m={m}"));
        }
    }

    #[test]
    fn gradcheck_projection() {
        let mut rng = Rng::new(201);
        let d = 3;
        let request = vec![
            Word(vec![0, 1, 2]),
            Word(vec![2]),
            Word(vec![1, 1, 0, 2]),
            Word(vec![0, 0]),
        ];
        let eng = SigEngine::new(WordTable::build(d, &request));
        let path = rng.brownian_path(7, d, 0.5);
        let g: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
        let got = sig_backward(&eng, &path, &g);
        let want = fd_grad(&eng, &path, &g, 1e-5);
        assert_allclose(&got, &want, 1e-6, 1e-5, "projection gradcheck");
    }

    #[test]
    fn gradcheck_single_word() {
        // Sparsity fast-path: one deep word only.
        let mut rng = Rng::new(202);
        let d = 2;
        let eng = SigEngine::new(WordTable::build(d, &[Word(vec![0, 1, 0, 1])]));
        let path = rng.brownian_path(6, d, 0.8);
        let got = sig_backward(&eng, &path, &[1.0]);
        let want = fd_grad(&eng, &path, &[1.0], 1e-5);
        assert_allclose(&got, &want, 1e-6, 1e-5, "single word");
    }

    #[test]
    fn grad_level1_is_endpoint_indicator() {
        // L = S((i)) = X_M^{(i)} - X_0^{(i)} ⇒ grad is -1 at start, +1 at
        // end, 0 inside.
        let d = 2;
        let eng = SigEngine::new(WordTable::build(d, &[Word(vec![1])]));
        let mut rng = Rng::new(203);
        let path = rng.brownian_path(5, d, 1.0);
        let grad = sig_backward(&eng, &path, &[1.0]);
        let mut want = vec![0.0; path.len()];
        want[1] = -1.0;
        want[5 * d + 1] = 1.0;
        assert_allclose(&grad, &want, 1e-12, 0.0, "level-1 grad");
    }

    #[test]
    fn batch_backward_matches_single() {
        let mut rng = Rng::new(204);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let b = 4;
        let m = 6;
        let mut paths = Vec::new();
        let mut grads = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
            grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
        }
        let all = sig_backward_batch(&eng, &paths, &grads, b);
        let per = (m + 1) * d;
        for k in 0..b {
            let single = sig_backward(
                &eng,
                &paths[k * per..(k + 1) * per],
                &grads[k * eng.out_dim()..(k + 1) * eng.out_dim()],
            );
            assert_allclose(&all[k * per..(k + 1) * per], &single, 1e-15, 0.0, "row");
        }
    }

    #[test]
    fn batch_backward_lane_path_matches_scalar_oracle() {
        // Batch wide enough to engage the lane kernel, size chosen so
        // the last block is partial.
        let mut rng = Rng::new(206);
        let d = 3;
        let eng = trunc_engine(d, 3);
        let b = eng.lanes() * 2 + 3;
        let m = 6;
        let mut paths = Vec::new();
        let mut grads = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 0.8));
            grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
        }
        let got = sig_backward_batch(&eng, &paths, &grads, b);
        let want = sig_backward_batch_scalar(&eng, &paths, &grads, b);
        assert_allclose(&got, &want, 1e-12, 1e-12, "lane vs scalar backward");
    }

    #[test]
    fn fused_matches_separate_calls() {
        let mut rng = Rng::new(207);
        let d = 2;
        let eng = trunc_engine(d, 4);
        for &b in &[3usize, 8, 19] {
            // straddles the lane width (fallback / exact / padded tail)
            let m = 5;
            let mut paths = Vec::new();
            let mut grads = Vec::new();
            for _ in 0..b {
                paths.extend(rng.brownian_path(m, d, 0.7));
                grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
            }
            let (sig, grad) = signature_and_backward_batch(&eng, &paths, &grads, b);
            let sig_want = signature_batch(&eng, &paths, b);
            let grad_want = sig_backward_batch(&eng, &paths, &grads, b);
            assert_allclose(&sig, &sig_want, 1e-15, 0.0, &format!("fused sig B={b}"));
            assert_allclose(&grad, &grad_want, 1e-15, 0.0, &format!("fused grad B={b}"));
        }
    }

    #[test]
    fn states_roundtrip_matches_plain_batch() {
        // signature_batch_states_into + sig_backward_batch_from_states_into
        // must equal signature_batch + sig_backward_batch exactly, on
        // both the scalar fallback and the lane path (padded tail).
        let mut rng = Rng::new(209);
        let d = 3;
        let eng = trunc_engine(d, 3);
        let sl = eng.state_len();
        for &b in &[2usize, 8, 19] {
            let m = 5;
            let mut paths = Vec::new();
            let mut grads = Vec::new();
            for _ in 0..b {
                paths.extend(rng.brownian_path(m, d, 0.6));
                grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
            }
            let mut sig = vec![0.0; b * eng.out_dim()];
            let mut states = vec![0.0; b * sl];
            signature_batch_states_into(&eng, &paths, b, &mut sig, &mut states);
            assert_allclose(&sig, &signature_batch(&eng, &paths, b), 0.0, 0.0, "sig rows");
            // Exported states are the terminal closure states.
            let per = (m + 1) * d;
            for k in 0..b {
                let want = crate::sig::sig_forward_state(&eng, &paths[k * per..(k + 1) * per]);
                assert_allclose(&states[k * sl..(k + 1) * sl], &want, 0.0, 0.0, "state row");
            }
            let mut grad = vec![0.0; paths.len()];
            sig_backward_batch_from_states_into(&eng, &paths, &states, &grads, b, &mut grad);
            let want = sig_backward_batch(&eng, &paths, &grads, b);
            assert_allclose(&grad, &want, 0.0, 0.0, &format!("from-states grad B={b}"));
        }
    }

    #[test]
    fn backward_batch_into_reuses_buffer() {
        let mut rng = Rng::new(208);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let b = eng.lanes() + 2;
        let m = 4;
        let mut paths = Vec::new();
        let mut grads = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
            grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
        }
        let mut out = vec![f64::NAN; paths.len()];
        sig_backward_batch_into(&eng, &paths, &grads, b, &mut out);
        let want = sig_backward_batch_scalar(&eng, &paths, &grads, b);
        assert_allclose(&out, &want, 1e-12, 1e-12, "into == scalar");
        // Second call with the same buffer must fully overwrite it.
        sig_backward_batch_into(&eng, &paths, &grads, b, &mut out);
        assert_allclose(&out, &want, 1e-12, 1e-12, "second call");
    }

    #[test]
    fn backward_long_path_stable() {
        // The backward reconstruction must stay accurate over hundreds of
        // steps (the paper relies on it for M up to 1600).
        let mut rng = Rng::new(205);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let path = rng.brownian_path(400, d, 0.1);
        let g: Vec<f64> = (0..eng.out_dim()).map(|_| rng.gaussian()).collect();
        let got = sig_backward(&eng, &path, &g);
        // Spot-check 10 random coordinates against finite differences.
        let mut p = path.clone();
        for _ in 0..10 {
            let k = rng.below(path.len());
            let eps = 1e-5;
            p[k] = path[k] + eps;
            let up: f64 = signature(&eng, &p).iter().zip(&g).map(|(a, b)| a * b).sum();
            p[k] = path[k] - eps;
            let dn: f64 = signature(&eng, &p).iter().zip(&g).map(|(a, b)| a * b).sum();
            p[k] = path[k];
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (got[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coord {k}: got {}, fd {}",
                got[k],
                fd
            );
        }
    }
}
