//! Backward pass — §4 of the paper, memory-minimal form.
//!
//! Given output cotangents `∂L/∂S_{0,T}(X,w)` for `w ∈ I`, produce
//! `∂L/∂X_j^{(i)}` while storing **only the terminal signature** from the
//! forward pass. Intermediate signatures are reconstructed backward in
//! time with the group inverse (Prop 4.6: `S_{0,t_{j-1}} = S_{0,t_j} ⊗
//! exp(-ΔX_j)`), and the cotangent state `λ` is propagated by the exact
//! transpose of the forward Chen update.
//!
//! Derivation used here (equivalent to Prop 4.1/4.2; see DESIGN.md):
//! the forward step `S_j(w) = Σ_{w=p∘s} S_{j-1}(p)·exp(ΔX_j, s)` is
//! *linear* in `S_{j-1}`, so reverse-mode gives
//!
//! ```text
//! λ_{j-1}(p)      = Σ_{w=p∘s ∈ C} λ_j(w)·exp(ΔX_j, s)        (transpose)
//! ∂L/∂ΔX_j^{(i)}  = Σ_w λ_j(w) Σ_{w=p∘s} S_{j-1}(p)·∂exp(ΔX_j, s)/∂ΔX^{(i)}
//! ```
//!
//! Both sums run over prefix decompositions of words in the closure `C`,
//! so the prefix tables of [`crate::words::WordTable`] suffice — no
//! suffix indices are needed. Per word of length `n`, the ΔX-gradient is
//! accumulated in `O(n)` via a left-to-right Horner sweep (`A_p`) against
//! precomputed right suffix products (`R_p`):
//! `∂/∂ΔX^{(i_p)} += λ(w)·A_p·R_p` with
//! `A_{p+1} = A_p·ΔX^{(i_p)} + S_{j-1}(w_[p])/(n-p)!`.

use super::forward::forward_sweep_range;
use super::{chen_update, SigEngine};
use crate::util::threadpool::parallel_for_into;

/// Reusable buffers for a single-path backward pass.
#[derive(Debug, Default)]
pub struct BackwardWorkspace {
    state: Vec<f64>,
    lambda: Vec<f64>,
    lambda_next: Vec<f64>,
    dx: Vec<f64>,
    neg_dx: Vec<f64>,
    right_prod: Vec<f64>,
    grad_dx: Vec<f64>,
}

/// Gradient of `L` with respect to the path points, for a single path.
///
/// * `path` — row-major `(M+1, d)`.
/// * `grad_out` — `∂L/∂(projected signature)`, length `|I|`.
///
/// Returns `∂L/∂X` as row-major `(M+1, d)`. Memory: `O(|C|)` plus the
/// path itself — the paper's `O(B·D_sig)` claim (Table 2) with `B = 1`.
pub fn sig_backward(eng: &SigEngine, path: &[f64], grad_out: &[f64]) -> Vec<f64> {
    let mut ws = BackwardWorkspace::default();
    sig_backward_ws(eng, path, grad_out, &mut ws)
}

/// [`sig_backward`] with caller-provided workspace.
pub fn sig_backward_ws(
    eng: &SigEngine,
    path: &[f64],
    grad_out: &[f64],
    ws: &mut BackwardWorkspace,
) -> Vec<f64> {
    let mut out = vec![0.0; path.len()];
    sig_backward_into(eng, path, grad_out, ws, &mut out);
    out
}

/// [`sig_backward`] with caller-provided workspace **and** output
/// buffer (`out.len() == path.len()`) — the zero-allocation hot path.
pub fn sig_backward_into(
    eng: &SigEngine,
    path: &[f64],
    grad_out: &[f64],
    ws: &mut BackwardWorkspace,
    out: &mut [f64],
) {
    let t = &eng.table;
    let d = t.d;
    assert_eq!(path.len() % d, 0);
    let m1 = path.len() / d;
    let steps = m1 - 1;
    assert_eq!(grad_out.len(), t.out_dim());
    assert_eq!(out.len(), path.len(), "gradient buffer has wrong size");

    // Forward pass to the terminal signature (the only stored state).
    forward_sweep_range(eng, path, 0, steps, &mut ws.state, &mut ws.dx);

    // Seed λ_M: scatter the output cotangents onto the closure.
    ws.lambda.clear();
    ws.lambda.resize(t.state_len, 0.0);
    t.scatter_grad(grad_out, &mut ws.lambda);
    ws.lambda_next.clear();
    ws.lambda_next.resize(t.state_len, 0.0);

    ws.dx.resize(d, 0.0);
    ws.neg_dx.resize(d, 0.0);
    ws.right_prod.resize(t.max_level + 1, 0.0);
    ws.grad_dx.clear();
    ws.grad_dx.resize(steps * d, 0.0);

    for j in (1..=steps).rev() {
        for i in 0..d {
            ws.dx[i] = path[j * d + i] - path[(j - 1) * d + i];
            ws.neg_dx[i] = -ws.dx[i];
        }
        // Reconstruct S_{j-1} (Prop 4.6): S ← S ⊗ exp(-ΔX_j).
        chen_update(eng, &mut ws.state, &ws.neg_dx);

        // λ transpose + ΔX gradient, one in-place sweep over the
        // closure. The transpose sends contributions strictly from a
        // word to its *shorter* prefixes, so processing levels in
        // ASCENDING order reads every λ(w) before any contribution to
        // it lands — no double buffer needed (mirror of the forward's
        // descending in-place trick; the s = ε split is the identity
        // term λ(w) += λ(w)·1, a no-op in place).
        let gdx = &mut ws.grad_dx[(j - 1) * d..j * d];
        let lambda = ws.lambda.as_mut_slice();
        let state = ws.state.as_slice();
        let right_prod = ws.right_prod.as_mut_slice();
        let dx = ws.dx.as_slice();
        for n in 1..=t.max_level {
            let inv_fact_n = eng.inv_fact[n];
            let level_base = t.level_csr_base(n);
            let level = t.level_range(n);
            for (off, w) in level.enumerate() {
                // SAFETY: all indices below come from the validated
                // WordTable (letters < d, prefix indices < state_len,
                // CSR rows within bounds) — checked by
                // `WordTable::check_invariants` in tests.
                unsafe {
                    let lam = *lambda.get_unchecked(w);
                    if lam == 0.0 {
                        continue;
                    }
                    let base = level_base + off * n;
                    let letters = t.csr_letters.get_unchecked(base..base + n);
                    let prefixes = t.csr_prefix.get_unchecked(base..base + n);
                    // Right suffix products R_p = Π_{q=p+1..n} dx_{i_q}.
                    *right_prod.get_unchecked_mut(n) = 1.0;
                    for p in (1..n).rev() {
                        let letter = *letters.get_unchecked(p) as usize; // i_{p+1}
                        *right_prod.get_unchecked_mut(p) =
                            right_prod.get_unchecked(p + 1) * dx.get_unchecked(letter);
                    }
                    // Fused sweep over positions p = 1..=n:
                    //   gdx[i_p]    += λ·A_p·R_p       (A_1 = 1/n!)
                    //   λ(w_[p-1])  += λ·e_{p-1}, e_k = dx_{i_{k+1}}·R_{k+1}/(n-k)!
                    //   A_{p+1}      = A_p·dx_{i_p} + S(w_[p])/(n-p)!
                    let mut a = inv_fact_n;
                    for p in 1..=n {
                        let letter = *letters.get_unchecked(p - 1) as usize; // i_p
                        let dxl = *dx.get_unchecked(letter);
                        let rp = *right_prod.get_unchecked(p);
                        *gdx.get_unchecked_mut(letter) += lam * a * rp;
                        let e_k = dxl * rp * eng.inv_fact.get_unchecked(n - p + 1);
                        *lambda.get_unchecked_mut(*prefixes.get_unchecked(p - 1) as usize) +=
                            lam * e_k;
                        if p < n {
                            let s_pref =
                                *state.get_unchecked(*prefixes.get_unchecked(p) as usize);
                            a = a * dxl + s_pref * eng.inv_fact.get_unchecked(n - p);
                        }
                    }
                }
            }
        }
    }

    // Chain rule from increments to points:
    // ∂L/∂X_0 = -g_1, ∂L/∂X_j = g_j - g_{j+1}, ∂L/∂X_M = g_M.
    out.fill(0.0);
    for i in 0..d {
        if steps > 0 {
            out[i] = -ws.grad_dx[i];
            out[steps * d + i] = ws.grad_dx[(steps - 1) * d + i];
        }
    }
    for j in 1..steps {
        for i in 0..d {
            out[j * d + i] = ws.grad_dx[(j - 1) * d + i] - ws.grad_dx[j * d + i];
        }
    }
}

/// Batched backward: `paths` `(B, M+1, d)`, `grads_out` `(B, |I|)` →
/// `(B, M+1, d)`. Parallel over paths.
pub fn sig_backward_batch(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
) -> Vec<f64> {
    let mut out = vec![0.0; paths.len()];
    sig_backward_batch_into(eng, paths, grads_out, batch, &mut out);
    out
}

/// [`sig_backward_batch`] writing into a caller-provided `(B, M+1, d)`
/// buffer: each path's gradient row is written in place by a pooled
/// per-worker workspace — no per-row allocation, no post-join copy.
pub fn sig_backward_batch_into(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
    out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(grads_out.len(), batch * odim);
    assert_eq!(out.len(), paths.len(), "gradient buffer has wrong size");
    let nw = eng.threads.min(batch).max(1);
    let mut workers = eng.bwd_pool.take_at_least(nw);
    parallel_for_into(out, per_path, &mut workers[..nw], |b, row, ws| {
        sig_backward_into(
            eng,
            &paths[b * per_path..(b + 1) * per_path],
            &grads_out[b * odim..(b + 1) * odim],
            ws,
            row,
        );
    });
    eng.bwd_pool.put(workers);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::signature;
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, Word, WordTable};

    fn trunc_engine(d: usize, n: usize) -> SigEngine {
        SigEngine::new(WordTable::build(d, &truncated_words(d, n)))
    }

    /// Central finite-difference gradient of L(X) = <g, sig(X)>.
    fn fd_grad(eng: &SigEngine, path: &[f64], g: &[f64], eps: f64) -> Vec<f64> {
        let mut out = vec![0.0; path.len()];
        let mut p = path.to_vec();
        for k in 0..path.len() {
            p[k] = path[k] + eps;
            let up: f64 = signature(eng, &p).iter().zip(g).map(|(a, b)| a * b).sum();
            p[k] = path[k] - eps;
            let dn: f64 = signature(eng, &p).iter().zip(g).map(|(a, b)| a * b).sum();
            p[k] = path[k];
            out[k] = (up - dn) / (2.0 * eps);
        }
        out
    }

    #[test]
    fn gradcheck_truncated() {
        let mut rng = Rng::new(200);
        for &(d, n, m) in &[(2, 3, 4), (3, 2, 6), (2, 5, 3), (4, 3, 5)] {
            let eng = trunc_engine(d, n);
            let path = rng.brownian_path(m, d, 0.6);
            let g: Vec<f64> = (0..eng.out_dim()).map(|_| rng.gaussian()).collect();
            let got = sig_backward(&eng, &path, &g);
            let want = fd_grad(&eng, &path, &g, 1e-5);
            assert_allclose(&got, &want, 1e-6, 1e-5, &format!("gradcheck d={d} n={n} m={m}"));
        }
    }

    #[test]
    fn gradcheck_projection() {
        let mut rng = Rng::new(201);
        let d = 3;
        let request = vec![
            Word(vec![0, 1, 2]),
            Word(vec![2]),
            Word(vec![1, 1, 0, 2]),
            Word(vec![0, 0]),
        ];
        let eng = SigEngine::new(WordTable::build(d, &request));
        let path = rng.brownian_path(7, d, 0.5);
        let g: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
        let got = sig_backward(&eng, &path, &g);
        let want = fd_grad(&eng, &path, &g, 1e-5);
        assert_allclose(&got, &want, 1e-6, 1e-5, "projection gradcheck");
    }

    #[test]
    fn gradcheck_single_word() {
        // Sparsity fast-path: one deep word only.
        let mut rng = Rng::new(202);
        let d = 2;
        let eng = SigEngine::new(WordTable::build(d, &[Word(vec![0, 1, 0, 1])]));
        let path = rng.brownian_path(6, d, 0.8);
        let got = sig_backward(&eng, &path, &[1.0]);
        let want = fd_grad(&eng, &path, &[1.0], 1e-5);
        assert_allclose(&got, &want, 1e-6, 1e-5, "single word");
    }

    #[test]
    fn grad_level1_is_endpoint_indicator() {
        // L = S((i)) = X_M^{(i)} - X_0^{(i)} ⇒ grad is -1 at start, +1 at
        // end, 0 inside.
        let d = 2;
        let eng = SigEngine::new(WordTable::build(d, &[Word(vec![1])]));
        let mut rng = Rng::new(203);
        let path = rng.brownian_path(5, d, 1.0);
        let grad = sig_backward(&eng, &path, &[1.0]);
        let mut want = vec![0.0; path.len()];
        want[1] = -1.0;
        want[5 * d + 1] = 1.0;
        assert_allclose(&grad, &want, 1e-12, 0.0, "level-1 grad");
    }

    #[test]
    fn batch_backward_matches_single() {
        let mut rng = Rng::new(204);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let b = 4;
        let m = 6;
        let mut paths = Vec::new();
        let mut grads = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
            grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
        }
        let all = sig_backward_batch(&eng, &paths, &grads, b);
        let per = (m + 1) * d;
        for k in 0..b {
            let single = sig_backward(
                &eng,
                &paths[k * per..(k + 1) * per],
                &grads[k * eng.out_dim()..(k + 1) * eng.out_dim()],
            );
            assert_allclose(&all[k * per..(k + 1) * per], &single, 1e-15, 0.0, "row");
        }
    }

    #[test]
    fn backward_long_path_stable() {
        // The backward reconstruction must stay accurate over hundreds of
        // steps (the paper relies on it for M up to 1600).
        let mut rng = Rng::new(205);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let path = rng.brownian_path(400, d, 0.1);
        let g: Vec<f64> = (0..eng.out_dim()).map(|_| rng.gaussian()).collect();
        let got = sig_backward(&eng, &path, &g);
        // Spot-check 10 random coordinates against finite differences.
        let mut p = path.clone();
        for _ in 0..10 {
            let k = rng.below(path.len());
            let eps = 1e-5;
            p[k] = path[k] + eps;
            let up: f64 = signature(&eng, &p).iter().zip(&g).map(|(a, b)| a * b).sum();
            p[k] = path[k] - eps;
            let dn: f64 = signature(&eng, &p).iter().zip(&g).map(|(a, b)| a * b).sum();
            p[k] = path[k];
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (got[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coord {k}: got {}, fd {}",
                got[k],
                fd
            );
        }
    }
}
