//! Streaming signature engine — amortized-O(1) sliding windows.
//!
//! The batch windowed path (`sig::windows`) recomputes every window
//! from its left edge: serving a live tick stream that way costs
//! O(window) per new sample. This module maintains the same quantities
//! incrementally:
//!
//! * **extend**: the running signature `S_{0,t}` advances by one
//!   [`crate::sig::chen_update`] per sample (Chen's identity is inherently
//!   incremental);
//! * **sliding window**: the last-`w`-increments signature
//!   `S_{t-w,t}` is maintained in amortized `O(|W|·N)` per push —
//!   *independent of `w`* — with a **two-stack banker's queue** of
//!   partial signatures, avoiding group inverses entirely (inverse-based
//!   sliding updates are the numerically fragile scheme the paper warns
//!   about; see `baselines::chen_windows`).
//!
//! ## The two-stack queue
//!
//! The window's increments are split into an older *front* segment and
//! a newer *back* segment (`window = front ∘ back`):
//!
//! ```text
//!   increments:   v1 v2 v3 | v4 v5          (chronological)
//!                 ---front---  ---back---
//!   front stack:  [S3, S2, S1]   Si = vi ⊗ … ⊗ v3  (suffix products,
//!                        ^ top = S1 = oldest)
//!   back stack:   raw v4, v5  +  back_agg = v4 ⊗ v5 (running prefix)
//!   window sig =  front.top ⊗ back_agg  =  v1 ⊗ v2 ⊗ v3 ⊗ v4 ⊗ v5
//! ```
//!
//! A push extends `back_agg` by one Chen update. Evicting the oldest
//! increment pops the front stack; when the front is empty the back is
//! **re-folded**: its raw increments are replayed newest-to-oldest,
//! each left-multiplied onto the previous suffix product
//! (`S_i = exp(v_i) ⊗ S_{i+1}`), and pushed so the oldest ends on top.
//! Every increment is folded exactly once, so the amortized cost per
//! push is one Chen update plus one left-multiply — O(1) in the window
//! length (the classic banker's-queue argument).
//!
//! ## Factor closure
//!
//! The left-multiply `(exp(dx) ⊗ S)(w) = Σ_k dx^{w_{:k}}/k! · S(w_{k:})`
//! and the front⊗back combine both read **suffixes** of table words,
//! while the engine's state set is only prefix-closed. [`StreamTable`]
//! therefore builds its word table over the **factor closure** (every
//! contiguous subword of every requested word) and adds a suffix-index
//! CSR mirroring `csr_prefix`. For truncated, anisotropic and DAG word
//! sets the factor closure *is* the prefix closure (those sets are
//! already suffix-closed), so the augmentation is free; sparse custom
//! word lists grow by at most `|w|²/2` entries per requested word.
//!
//! ## Vectorized sessions
//!
//! [`MultiStream`] runs `M` lockstep streams through the lane-major
//! SoA kernels of [`crate::sig::lanes`]: pushes go through
//! [`chen_update_lanes`], refolds and window queries through lane-major
//! left-multiply/combine sweeps, so `M` concurrent sessions cost one
//! table walk per `L` streams. Per lane the arithmetic order is
//! identical to the scalar [`StreamEngine`], so results match bitwise.
//!
//! All per-stream buffers are sized at construction (bounded by the
//! window length), so a warm push performs **zero heap allocations** —
//! asserted by the counting allocator in `benches/fig3_windows.rs`.

use super::forward::chen_update;
use super::lanes::chen_update_lanes;
use super::SigEngine;
use crate::words::{Word, WordTable};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// A [`SigEngine`] over the **factor closure** of a requested word set,
/// extended with the suffix-index CSR the streaming kernels need
/// (left-multiplication and general Chen combine read suffix values;
/// see the module docs). Build once per configuration and share across
/// sessions via `Arc`.
#[derive(Clone, Debug)]
pub struct StreamTable {
    /// Engine over the factor-closed table. Its `output_map` covers the
    /// augmented request; streaming entry points project through the
    /// table's private `out_map` (the originally requested words) via
    /// [`StreamTable::project_into`] instead.
    pub eng: SigEngine,
    /// Suffix state indices in the same packed level-major CSR layout
    /// as `csr_prefix`: `csr_suffix[csr_start[i] + k]` = state index of
    /// `w_i` with its first `k` letters dropped (entry `k = 0` is `i`
    /// itself).
    csr_suffix: Vec<u32>,
    /// State indices of the *originally requested* words, request
    /// order — the streaming output projection.
    out_map: Vec<u32>,
}

impl StreamTable {
    /// Build the factor-closed streaming table for `request` over
    /// alphabet `d`. The underlying [`WordTable`] is built over the
    /// request augmented with every proper suffix of every requested
    /// word; its prefix closure is then exactly the factor closure.
    pub fn new(d: usize, request: &[Word]) -> StreamTable {
        let mut aug = request.to_vec();
        let mut seen: HashSet<Vec<u16>> = request.iter().map(|w| w.0.clone()).collect();
        for w in request {
            for k in 1..w.len() {
                let s = w.suffix_from(k);
                if seen.insert(s.0.clone()) {
                    aug.push(s);
                }
            }
        }
        let eng = SigEngine::new(WordTable::build(d, &aug));
        let t = &eng.table;
        let out_map = t.output_map[..request.len()].to_vec();
        let index: HashMap<&[u16], u32> = t
            .words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.0.as_slice(), i as u32))
            .collect();
        let mut csr_suffix = vec![0u32; t.csr_letters.len()];
        for (i, w) in t.words.iter().enumerate() {
            let base = t.csr_start[i] as usize;
            for k in 0..w.len() {
                csr_suffix[base + k] = *index
                    .get(&w.0[k..])
                    .expect("factor closure must contain every suffix");
            }
        }
        let table = StreamTable {
            eng,
            csr_suffix,
            out_map,
        };
        debug_assert!({
            table.check_invariants();
            true
        });
        table
    }

    /// Alphabet size `d`.
    #[inline]
    pub fn dim(&self) -> usize {
        self.eng.table.d
    }

    /// Output dimension `|I|` of the *original* request.
    #[inline]
    pub fn out_dim(&self) -> usize {
        self.out_map.len()
    }

    /// Closure state length (factor closure, including ε).
    #[inline]
    pub fn state_len(&self) -> usize {
        self.eng.table.state_len
    }

    /// Project a factor-closure state onto the originally requested
    /// coordinates (`out.len() == out_dim()`).
    pub fn project_into(&self, state: &[f64], out: &mut [f64]) {
        debug_assert_eq!(state.len(), self.state_len());
        debug_assert_eq!(out.len(), self.out_dim());
        for (o, &idx) in out.iter_mut().zip(&self.out_map) {
            *o = state[idx as usize];
        }
    }

    /// Reset a state vector to the trivial signature (ε = 1).
    fn identity_into(&self, state: &mut Vec<f64>) {
        state.clear();
        state.resize(self.state_len(), 0.0);
        state[0] = 1.0;
    }

    /// In-place **left** Chen/Horner update `S ← exp(dx) ⊗ S` — the
    /// mirror of [`crate::sig::chen_update`], walking the suffix chain instead of
    /// the prefix chain:
    ///
    /// ```text
    /// S'(w) = S(w) + dx_{i_1}·( S(w_{1:}) + dx_{i_2}/2·( S(w_{2:}) + … ))
    /// ```
    ///
    /// Levels are processed top-down so in-place updates read only
    /// old (strictly shorter) suffix values.
    pub fn lmul_update(&self, state: &mut [f64], dx: &[f64]) {
        let t = &self.eng.table;
        assert_eq!(state.len(), t.state_len, "state must be a closure state");
        assert_eq!(dx.len(), t.d, "dx must have d entries");
        for n in (1..=t.max_level).rev() {
            let level_base = t.level_csr_base(n);
            for (off, i) in t.level_range(n).enumerate() {
                let base = level_base + off * n;
                let letters = &t.csr_letters[base..base + n];
                let suffixes = &self.csr_suffix[base..base + n];
                let mut acc = 1.0; // S(ε)
                for k in (1..n).rev() {
                    acc = state[suffixes[k] as usize]
                        + dx[letters[k] as usize] * self.eng.recip[k + 1] * acc;
                }
                state[i] += dx[letters[0] as usize] * acc;
            }
        }
    }

    /// Lane-major [`StreamTable::lmul_update`]: `state` is
    /// `state_len × L` (lanes contiguous), `dx` is `d × L`. Per lane
    /// the operation order matches the scalar kernel exactly, so
    /// results are bitwise identical lane by lane.
    pub fn lmul_update_lanes<const L: usize>(&self, state: &mut [f64], dx: &[f64]) {
        let t = &self.eng.table;
        assert_eq!(state.len(), t.state_len * L, "state must be state_len × L");
        assert_eq!(dx.len(), t.d * L, "dx must be d × L");
        for n in (1..=t.max_level).rev() {
            let level_base = t.level_csr_base(n);
            for (off, i) in t.level_range(n).enumerate() {
                let base = level_base + off * n;
                let letters = &t.csr_letters[base..base + n];
                let suffixes = &self.csr_suffix[base..base + n];
                let mut acc = [1.0f64; L];
                for k in (1..n).rev() {
                    let suf = suffixes[k] as usize * L;
                    let letter = letters[k] as usize * L;
                    let r = self.eng.recip[k + 1];
                    for l in 0..L {
                        acc[l] = state[suf + l] + dx[letter + l] * r * acc[l];
                    }
                }
                let letter0 = letters[0] as usize * L;
                for l in 0..L {
                    state[i * L + l] += dx[letter0 + l] * acc[l];
                }
            }
        }
    }

    /// General Chen product `out ← a ⊗ b` of two factor-closure states:
    /// `C(w) = Σ_{k=0}^{|w|} A(w_{:k})·B(w_{k:})` via the prefix and
    /// suffix CSR rows. Used once per window query to join the front
    /// stack's suffix product with the back stack's running prefix.
    pub fn combine(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let t = &self.eng.table;
        assert_eq!(a.len(), t.state_len, "a must be a closure state");
        assert_eq!(b.len(), t.state_len, "b must be a closure state");
        assert_eq!(out.len(), t.state_len, "out must be a closure state");
        out[0] = 1.0;
        for n in 1..=t.max_level {
            let level_base = t.level_csr_base(n);
            for (off, i) in t.level_range(n).enumerate() {
                let base = level_base + off * n;
                let prefixes = &t.csr_prefix[base..base + n];
                let suffixes = &self.csr_suffix[base..base + n];
                let mut acc = a[i] + b[i];
                for k in 1..n {
                    acc += a[prefixes[k] as usize] * b[suffixes[k] as usize];
                }
                out[i] = acc;
            }
        }
    }

    /// Transpose of right Chen multiplication: given a constant factor
    /// `e` (a closure state), rewrite `lambda` in place from the
    /// cotangent of `S ⊗ e` to the cotangent of `S`:
    ///
    /// ```text
    /// λ'(p) = Σ_{w = p∘s ∈ C} λ(w)·e(s)
    /// ```
    ///
    /// This is the chunk-boundary cotangent scan of the checkpointed
    /// backward pass ([`crate::sig::tree`]): one call replaces a whole
    /// chunk's worth of per-step transposes. Levels are processed in
    /// ASCENDING order — contributions go strictly from a word to its
    /// shorter prefixes (the `s = ε` split is the in-place identity
    /// term `λ(w) += λ(w)·1`, skipped), so every `λ(w)` is read before
    /// anything lands on it, exactly like the per-step backward sweep.
    pub fn combine_transpose_right(&self, e: &[f64], lambda: &mut [f64]) {
        let t = &self.eng.table;
        assert_eq!(e.len(), t.state_len, "e must be a closure state");
        assert_eq!(lambda.len(), t.state_len, "lambda must be a closure state");
        for n in 1..=t.max_level {
            let level_base = t.level_csr_base(n);
            for (off, w) in t.level_range(n).enumerate() {
                let lam = lambda[w];
                if lam == 0.0 {
                    continue;
                }
                let base = level_base + off * n;
                let prefixes = &t.csr_prefix[base..base + n];
                let suffixes = &self.csr_suffix[base..base + n];
                // Splits k = 0..n-1: prefix w_{:k} gains λ(w)·e(w_{k:})
                // (k = 0 sends λ(w)·e(w) to ε, which is inert).
                for k in 0..n {
                    lambda[prefixes[k] as usize] += lam * e[suffixes[k] as usize];
                }
            }
        }
    }

    /// Adjoint of [`StreamTable::project_into`]: accumulate
    /// requested-coordinate cotangents onto a factor-closure state
    /// vector (duplicate requests accumulate, like
    /// [`crate::words::WordTable::scatter_grad`]).
    pub fn scatter_into(&self, grad_out: &[f64], state: &mut [f64]) {
        // Hard asserts: a short `grad_out` would otherwise be silently
        // truncated by the zip in release builds, dropping cotangents.
        assert_eq!(grad_out.len(), self.out_dim(), "grad_out must have |I| entries");
        assert_eq!(state.len(), self.state_len(), "state must be a closure state");
        for (g, &idx) in grad_out.iter().zip(&self.out_map) {
            state[idx as usize] += *g;
        }
    }

    /// Lane-major [`StreamTable::combine`] (`a`, `b`, `out` are
    /// `state_len × L`, lanes contiguous); bitwise identical per lane
    /// to the scalar kernel.
    pub fn combine_lanes<const L: usize>(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let t = &self.eng.table;
        assert_eq!(a.len(), t.state_len * L, "a must be state_len × L");
        assert_eq!(b.len(), t.state_len * L, "b must be state_len × L");
        assert_eq!(out.len(), t.state_len * L, "out must be state_len × L");
        out[..L].fill(1.0); // ε row
        for n in 1..=t.max_level {
            let level_base = t.level_csr_base(n);
            for (off, i) in t.level_range(n).enumerate() {
                let base = level_base + off * n;
                let prefixes = &t.csr_prefix[base..base + n];
                let suffixes = &self.csr_suffix[base..base + n];
                let mut acc = [0.0f64; L];
                for l in 0..L {
                    acc[l] = a[i * L + l] + b[i * L + l];
                }
                for k in 1..n {
                    let p = prefixes[k] as usize * L;
                    let s = suffixes[k] as usize * L;
                    for l in 0..L {
                        acc[l] += a[p + l] * b[s + l];
                    }
                }
                out[i * L..i * L + L].copy_from_slice(&acc);
            }
        }
    }

    /// Verify the suffix CSR against [`Word::suffix_from`] (used by
    /// tests; mirrors `WordTable::check_invariants`).
    pub fn check_invariants(&self) {
        let t = &self.eng.table;
        t.check_invariants();
        for (i, w) in t.words.iter().enumerate() {
            let base = t.csr_start[i] as usize;
            for k in 0..w.len() {
                let s = &t.words[self.csr_suffix[base + k] as usize];
                assert_eq!(s.0, w.0[k..], "suffix table wrong for word {i} k={k}");
            }
        }
        for (r, &idx) in self.out_map.iter().enumerate() {
            assert!((idx as usize) < t.state_len, "out_map entry {r} out of range");
        }
    }
}

/// Reusable buffer set of a [`StreamEngine`], recyclable through a
/// [`crate::util::pool::Pool`] so reopening a session reuses the
/// previous session's allocations (the coordinator keeps one pool per
/// service).
#[derive(Clone, Debug, Default)]
pub struct StreamScratch {
    last: Vec<f64>,
    total: Vec<f64>,
    dx: Vec<f64>,
    back_dx: Vec<f64>,
    back_agg: Vec<f64>,
    front: Vec<f64>,
    qstate: Vec<f64>,
}

/// A stateful single-stream signature session: push one sample at a
/// time, query the running signature `S_{0,t}` and the sliding-window
/// signature `S_{t-w,t}` at any point. Amortized cost per push is
/// independent of the window length, and a warm push allocates nothing
/// (all buffers are bounded by the window length and reserved up
/// front).
///
/// # Examples
///
/// ```
/// use pathsig::sig::{StreamEngine, StreamTable};
/// use pathsig::words::truncated_words;
/// use std::sync::Arc;
///
/// // 1-D stream at depth 2, window = 2 increments.
/// let tbl = Arc::new(StreamTable::new(1, &truncated_words(1, 2)));
/// let mut s = StreamEngine::new(tbl, 2);
/// for x in [0.0, 1.0, 3.0, 6.0] {
///     s.push(&[x]);
/// }
/// // Window covers the last two increments: X_3 - X_1 = 5.
/// let w = s.window_signature();
/// assert!((w[0] - 5.0).abs() < 1e-12);
/// assert!((w[1] - 12.5).abs() < 1e-12); // 5²/2
/// // The running signature covers the whole stream: X_3 - X_0 = 6.
/// assert!((s.signature()[0] - 6.0).abs() < 1e-12);
/// ```
#[derive(Clone, Debug)]
pub struct StreamEngine {
    tbl: Arc<StreamTable>,
    window: usize,
    n_seen: usize,
    back_len: usize,
    front_len: usize,
    s: StreamScratch,
}

impl StreamEngine {
    /// Open a stream over `tbl` maintaining a sliding window of
    /// `window ≥ 1` increments.
    pub fn new(tbl: Arc<StreamTable>, window: usize) -> StreamEngine {
        StreamEngine::with_scratch(tbl, window, StreamScratch::default())
    }

    /// [`StreamEngine::new`] reusing a recycled buffer set (see
    /// [`StreamEngine::into_scratch`]); buffer capacities are kept, so
    /// a pooled reopen allocates at most up to the new window bound.
    pub fn with_scratch(tbl: Arc<StreamTable>, window: usize, mut s: StreamScratch) -> StreamEngine {
        assert!(window >= 1, "window must hold at least one increment");
        let d = tbl.dim();
        let sl = tbl.state_len();
        s.last.clear();
        s.last.resize(d, 0.0);
        s.dx.clear();
        s.dx.resize(d, 0.0);
        tbl.identity_into(&mut s.total);
        tbl.identity_into(&mut s.back_agg);
        s.qstate.clear();
        s.qstate.resize(sl, 0.0);
        s.back_dx.clear();
        s.back_dx.reserve(window * d);
        s.front.clear();
        s.front.reserve(window * sl);
        StreamEngine {
            tbl,
            window,
            n_seen: 0,
            back_len: 0,
            front_len: 0,
            s,
        }
    }

    /// The shared streaming table.
    #[inline]
    pub fn table(&self) -> &StreamTable {
        &self.tbl
    }

    /// Alphabet size `d`.
    #[inline]
    pub fn dim(&self) -> usize {
        self.tbl.dim()
    }

    /// Output dimension `|I|`.
    #[inline]
    pub fn out_dim(&self) -> usize {
        self.tbl.out_dim()
    }

    /// Sliding-window capacity in increments.
    #[inline]
    pub fn window_len(&self) -> usize {
        self.window
    }

    /// Samples pushed so far.
    #[inline]
    pub fn samples_seen(&self) -> usize {
        self.n_seen
    }

    /// Increments currently inside the window
    /// (`min(samples_seen - 1, window_len)`; 0 before the second
    /// sample).
    #[inline]
    pub fn window_fill(&self) -> usize {
        self.front_len + self.back_len
    }

    /// Push one sample (`sample.len() == d`). The first sample sets the
    /// stream's base point; each later one contributes the increment to
    /// the previous sample. Warm pushes perform no heap allocation.
    pub fn push(&mut self, sample: &[f64]) {
        let d = self.tbl.dim();
        assert_eq!(sample.len(), d, "sample must have d entries");
        self.n_seen += 1;
        if self.n_seen == 1 {
            self.s.last.copy_from_slice(sample);
            return;
        }
        for (x, (&a, &b)) in self.s.dx.iter_mut().zip(sample.iter().zip(&self.s.last)) {
            *x = a - b;
        }
        self.s.last.copy_from_slice(sample);
        chen_update(&self.tbl.eng, &mut self.s.total, &self.s.dx);
        if self.front_len + self.back_len == self.window {
            if self.front_len == 0 {
                self.refold();
            }
            self.front_len -= 1;
            let sl = self.tbl.state_len();
            self.s.front.truncate(self.front_len * sl);
        }
        self.s.back_dx.extend_from_slice(&self.s.dx);
        chen_update(&self.tbl.eng, &mut self.s.back_agg, &self.s.dx);
        self.back_len += 1;
    }

    /// Re-fold the back stack into front-stack suffix products (called
    /// with the front empty): replay the raw increments newest to
    /// oldest, left-multiplying each onto the previous suffix product,
    /// so the oldest increment's product ends on top.
    fn refold(&mut self) {
        debug_assert_eq!(self.front_len, 0);
        let sl = self.tbl.state_len();
        let d = self.tbl.dim();
        for j in (0..self.back_len).rev() {
            let row = self.front_len;
            self.s.front.resize((row + 1) * sl, 0.0);
            let (prev, cur) = self.s.front.split_at_mut(row * sl);
            let cur = &mut cur[..sl];
            if row == 0 {
                cur.fill(0.0);
                cur[0] = 1.0;
            } else {
                cur.copy_from_slice(&prev[(row - 1) * sl..row * sl]);
            }
            self.tbl.lmul_update(cur, &self.s.back_dx[j * d..(j + 1) * d]);
            self.front_len += 1;
        }
        self.back_len = 0;
        self.s.back_dx.clear();
        self.tbl.identity_into(&mut self.s.back_agg);
    }

    /// Sliding-window signature `π_I(S_{t-w,t})` into a caller buffer
    /// (`out.len() == out_dim()`). Before the window is full it covers
    /// all increments seen so far; with no increments yet it is the
    /// trivial signature (all requested coordinates 0).
    pub fn window_into(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.tbl.out_dim(), "output buffer has wrong size");
        let sl = self.tbl.state_len();
        if self.front_len == 0 {
            self.tbl.project_into(&self.s.back_agg, out);
        } else if self.back_len == 0 {
            let top = &self.s.front[(self.front_len - 1) * sl..self.front_len * sl];
            self.tbl.project_into(top, out);
        } else {
            self.tbl.combine(
                &self.s.front[(self.front_len - 1) * sl..self.front_len * sl],
                &self.s.back_agg,
                &mut self.s.qstate,
            );
            self.tbl.project_into(&self.s.qstate, out);
        }
    }

    /// Allocating convenience wrapper around [`StreamEngine::window_into`].
    pub fn window_signature(&mut self) -> Vec<f64> {
        let mut out = vec![0.0; self.tbl.out_dim()];
        self.window_into(&mut out);
        out
    }

    /// Running whole-stream signature `π_I(S_{0,t})` into a caller
    /// buffer. Arithmetic is step-for-step identical to
    /// [`crate::sig::signature`] over the same samples, so the values
    /// match bitwise.
    pub fn signature_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.tbl.out_dim(), "output buffer has wrong size");
        self.tbl.project_into(&self.s.total, out);
    }

    /// Allocating convenience wrapper around [`StreamEngine::signature_into`].
    pub fn signature(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.tbl.out_dim()];
        self.signature_into(&mut out);
        out
    }

    /// Forget all samples but keep the buffers (and their capacity):
    /// the stream restarts empty with zero further allocations.
    pub fn reset(&mut self) {
        self.n_seen = 0;
        self.back_len = 0;
        self.front_len = 0;
        self.s.back_dx.clear();
        self.s.front.clear();
        self.tbl.identity_into(&mut self.s.total);
        self.tbl.identity_into(&mut self.s.back_agg);
    }

    /// Tear down the stream, recovering its buffer set for pooling.
    pub fn into_scratch(self) -> StreamScratch {
        self.s
    }

    /// Snapshot the full two-stack state into a portable value (the
    /// durability layer's `SNAP` record, see [`crate::persist`]). The
    /// snapshot carries everything needed to resume: counters plus the
    /// `last`/`total`/`back_agg`/`back_dx`/`front` buffers. The
    /// transient `dx`/`qstate` scratch is *not* captured — it holds no
    /// state between pushes.
    pub fn checkpoint(&self) -> StreamCheckpoint {
        StreamCheckpoint {
            window: self.window,
            n_seen: self.n_seen,
            back_len: self.back_len,
            front_len: self.front_len,
            last: self.s.last.clone(),
            total: self.s.total.clone(),
            back_agg: self.s.back_agg.clone(),
            back_dx: self.s.back_dx[..self.back_len * self.tbl.dim()].to_vec(),
            front: self.s.front[..self.front_len * self.tbl.state_len()].to_vec(),
        }
    }

    /// Rebuild a stream from a [`StreamCheckpoint`] over `tbl`, reusing
    /// a recycled buffer set. Every buffer length and counter is
    /// validated against the table and the two-stack invariant
    /// (`window_fill == min(n_seen − 1, window)`), so a forged or
    /// table-mismatched snapshot errors instead of corrupting state.
    /// A restored engine is observationally identical to the one that
    /// was checkpointed: subsequent pushes and window/signature queries
    /// produce bitwise-equal results.
    pub fn from_checkpoint(
        tbl: Arc<StreamTable>,
        ck: &StreamCheckpoint,
        mut s: StreamScratch,
    ) -> Result<StreamEngine, String> {
        let d = tbl.dim();
        let sl = tbl.state_len();
        if ck.window == 0 {
            return Err("checkpoint window must hold at least one increment".into());
        }
        let fill = ck.front_len + ck.back_len;
        if fill > ck.window {
            return Err(format!(
                "checkpoint fill {fill} exceeds window {}",
                ck.window
            ));
        }
        if fill != ck.n_seen.saturating_sub(1).min(ck.window) {
            return Err(format!(
                "checkpoint fill {fill} inconsistent with n_seen {} and window {}",
                ck.n_seen, ck.window
            ));
        }
        if ck.last.len() != d
            || ck.total.len() != sl
            || ck.back_agg.len() != sl
            || ck.back_dx.len() != ck.back_len * d
            || ck.front.len() != ck.front_len * sl
        {
            return Err(format!(
                "checkpoint buffer lengths do not match the table \
                 (d {d}, state_len {sl}): last {}, total {}, back_agg {}, \
                 back_dx {}, front {}",
                ck.last.len(),
                ck.total.len(),
                ck.back_agg.len(),
                ck.back_dx.len(),
                ck.front.len()
            ));
        }
        s.last.clear();
        s.last.extend_from_slice(&ck.last);
        s.total.clear();
        s.total.extend_from_slice(&ck.total);
        s.back_agg.clear();
        s.back_agg.extend_from_slice(&ck.back_agg);
        s.back_dx.clear();
        s.back_dx.reserve(ck.window * d);
        s.back_dx.extend_from_slice(&ck.back_dx);
        s.front.clear();
        s.front.reserve(ck.window * sl);
        s.front.extend_from_slice(&ck.front);
        s.dx.clear();
        s.dx.resize(d, 0.0);
        s.qstate.clear();
        s.qstate.resize(sl, 0.0);
        Ok(StreamEngine {
            tbl,
            window: ck.window,
            n_seen: ck.n_seen,
            back_len: ck.back_len,
            front_len: ck.front_len,
            s,
        })
    }
}

/// Portable snapshot of a [`StreamEngine`]'s two-stack state — what the
/// durability layer serializes into periodic `SNAP` records so boot-time
/// recovery restores a session without replaying its whole history (see
/// [`crate::persist`]). Produced by [`StreamEngine::checkpoint`],
/// consumed by [`StreamEngine::from_checkpoint`].
#[derive(Clone, Debug, PartialEq)]
pub struct StreamCheckpoint {
    /// Sliding-window capacity in increments.
    pub window: usize,
    /// Samples pushed so far.
    pub n_seen: usize,
    /// Increments on the back stack.
    pub back_len: usize,
    /// Suffix products on the front stack.
    pub front_len: usize,
    /// Last sample seen (`d` entries).
    pub last: Vec<f64>,
    /// Running whole-stream signature state (`state_len` entries).
    pub total: Vec<f64>,
    /// Back stack's running prefix signature (`state_len` entries).
    pub back_agg: Vec<f64>,
    /// Raw back-stack increments (`back_len · d` entries).
    pub back_dx: Vec<f64>,
    /// Front-stack suffix products (`front_len · state_len` entries).
    pub front: Vec<f64>,
}

/// `M` lockstep streams vectorized through the lane-major SoA kernels:
/// streams are cut into blocks of `L = eng.lanes()` lanes, each block's
/// states are `state[word][lane]` matrices, and every push/refold/query
/// walks the word table once per block instead of once per stream. All
/// streams share one window configuration and advance together
/// ([`MultiStream::push_all`] takes one sample per stream).
///
/// Per lane the arithmetic matches the scalar [`StreamEngine`]
/// bitwise; trailing lanes of a partial block carry zero increments
/// and stay at the trivial signature.
#[derive(Clone, Debug)]
pub struct MultiStream {
    tbl: Arc<StreamTable>,
    window: usize,
    m: usize,
    lanes: usize,
    n_blocks: usize,
    n_seen: usize,
    back_len: usize,
    front_len: usize,
    last: Vec<f64>,
    total: Vec<f64>,
    back_dx: Vec<f64>,
    back_agg: Vec<f64>,
    front: Vec<f64>,
    dx_lanes: Vec<f64>,
    qstate: Vec<f64>,
}

impl MultiStream {
    /// Open `m ≥ 1` lockstep streams with a shared sliding window of
    /// `window ≥ 1` increments. All buffers (including the full
    /// two-stack store, `O(m · window · state_len)`) are allocated here;
    /// pushes and queries never allocate.
    pub fn new(tbl: Arc<StreamTable>, m: usize, window: usize) -> MultiStream {
        assert!(m >= 1, "need at least one stream");
        assert!(window >= 1, "window must hold at least one increment");
        let lanes = tbl.eng.lanes();
        let n_blocks = m.div_ceil(lanes);
        let d = tbl.dim();
        let sl = tbl.state_len();
        let mut ms = MultiStream {
            last: vec![0.0; m * d],
            total: vec![0.0; n_blocks * sl * lanes],
            back_dx: vec![0.0; n_blocks * window * d * lanes],
            back_agg: vec![0.0; n_blocks * sl * lanes],
            front: vec![0.0; n_blocks * window * sl * lanes],
            dx_lanes: vec![0.0; d * lanes],
            qstate: vec![0.0; sl * lanes],
            tbl,
            window,
            m,
            lanes,
            n_blocks,
            n_seen: 0,
            back_len: 0,
            front_len: 0,
        };
        for blk in 0..n_blocks {
            ms.total[blk * sl * lanes..blk * sl * lanes + lanes].fill(1.0);
            ms.back_agg[blk * sl * lanes..blk * sl * lanes + lanes].fill(1.0);
        }
        ms
    }

    /// Number of streams.
    #[inline]
    pub fn streams(&self) -> usize {
        self.m
    }

    /// Output dimension `|I|` per stream.
    #[inline]
    pub fn out_dim(&self) -> usize {
        self.tbl.out_dim()
    }

    /// Samples pushed per stream so far.
    #[inline]
    pub fn samples_seen(&self) -> usize {
        self.n_seen
    }

    /// Push one sample per stream (`samples` is row-major `(m, d)`).
    pub fn push_all(&mut self, samples: &[f64]) {
        assert_eq!(
            samples.len(),
            self.m * self.tbl.dim(),
            "need one d-sample per stream"
        );
        match self.lanes {
            4 => self.push_impl::<4>(samples),
            8 => self.push_impl::<8>(samples),
            16 => self.push_impl::<16>(samples),
            32 => self.push_impl::<32>(samples),
            // Buffers are strided by `self.lanes`; running a kernel at
            // any other width would corrupt silently, so fail loudly if
            // the lane domain ever grows without updating this match.
            other => unreachable!("unsupported MultiStream lane width {other}"),
        }
    }

    fn push_impl<const L: usize>(&mut self, samples: &[f64]) {
        self.n_seen += 1;
        if self.n_seen == 1 {
            self.last.copy_from_slice(samples);
            return;
        }
        if self.front_len + self.back_len == self.window {
            if self.front_len == 0 {
                self.refold_impl::<L>();
            }
            self.front_len -= 1;
        }
        let d = self.tbl.dim();
        let sl = self.tbl.state_len();
        let step = self.back_len;
        for blk in 0..self.n_blocks {
            let b0 = blk * L;
            let nb = (self.m - b0).min(L);
            self.dx_lanes.fill(0.0);
            for l in 0..nb {
                let s0 = (b0 + l) * d;
                for i in 0..d {
                    self.dx_lanes[i * L + l] = samples[s0 + i] - self.last[s0 + i];
                }
            }
            chen_update_lanes::<L>(
                &self.tbl.eng,
                &mut self.total[blk * sl * L..(blk + 1) * sl * L],
                &self.dx_lanes,
            );
            let slot = (blk * self.window + step) * d * L;
            self.back_dx[slot..slot + d * L].copy_from_slice(&self.dx_lanes);
            chen_update_lanes::<L>(
                &self.tbl.eng,
                &mut self.back_agg[blk * sl * L..(blk + 1) * sl * L],
                &self.dx_lanes,
            );
        }
        self.back_len += 1;
        self.last.copy_from_slice(samples);
    }

    fn refold_impl<const L: usize>(&mut self) {
        debug_assert_eq!(self.front_len, 0);
        let d = self.tbl.dim();
        let sl = self.tbl.state_len();
        let rows = self.back_len;
        for blk in 0..self.n_blocks {
            for r in 0..rows {
                let j = rows - 1 - r; // back step folded into front row r
                let dst = (blk * self.window + r) * sl * L;
                if r == 0 {
                    self.front[dst..dst + sl * L].fill(0.0);
                    self.front[dst..dst + L].fill(1.0);
                } else {
                    let src = (blk * self.window + r - 1) * sl * L;
                    self.front.copy_within(src..src + sl * L, dst);
                }
                let dx0 = (blk * self.window + j) * d * L;
                self.tbl.lmul_update_lanes::<L>(
                    &mut self.front[dst..dst + sl * L],
                    &self.back_dx[dx0..dx0 + d * L],
                );
            }
            let ba = &mut self.back_agg[blk * sl * L..(blk + 1) * sl * L];
            ba.fill(0.0);
            ba[..L].fill(1.0);
        }
        self.front_len = rows;
        self.back_len = 0;
    }

    /// Sliding-window signatures of all streams into a row-major
    /// `(m, |I|)` buffer.
    pub fn window_into(&mut self, out: &mut [f64]) {
        assert_eq!(
            out.len(),
            self.m * self.tbl.out_dim(),
            "output buffer has wrong size"
        );
        match self.lanes {
            4 => self.window_impl::<4>(out),
            8 => self.window_impl::<8>(out),
            16 => self.window_impl::<16>(out),
            32 => self.window_impl::<32>(out),
            other => unreachable!("unsupported MultiStream lane width {other}"),
        }
    }

    fn window_impl<const L: usize>(&mut self, out: &mut [f64]) {
        let sl = self.tbl.state_len();
        for blk in 0..self.n_blocks {
            let agg = blk * sl * L..(blk + 1) * sl * L;
            let top = (blk * self.window + self.front_len.max(1) - 1) * sl * L;
            if self.front_len > 0 && self.back_len > 0 {
                self.tbl.combine_lanes::<L>(
                    &self.front[top..top + sl * L],
                    &self.back_agg[agg.clone()],
                    &mut self.qstate,
                );
            }
            let src: &[f64] = if self.front_len == 0 {
                &self.back_agg[agg]
            } else if self.back_len == 0 {
                &self.front[top..top + sl * L]
            } else {
                &self.qstate
            };
            self.project_block::<L>(src, blk, out);
        }
    }

    /// Running whole-stream signatures into a row-major `(m, |I|)`
    /// buffer.
    pub fn signature_into(&self, out: &mut [f64]) {
        assert_eq!(
            out.len(),
            self.m * self.tbl.out_dim(),
            "output buffer has wrong size"
        );
        match self.lanes {
            4 => self.signature_impl::<4>(out),
            8 => self.signature_impl::<8>(out),
            16 => self.signature_impl::<16>(out),
            32 => self.signature_impl::<32>(out),
            other => unreachable!("unsupported MultiStream lane width {other}"),
        }
    }

    fn signature_impl<const L: usize>(&self, out: &mut [f64]) {
        let sl = self.tbl.state_len();
        for blk in 0..self.n_blocks {
            self.project_block::<L>(&self.total[blk * sl * L..(blk + 1) * sl * L], blk, out);
        }
    }

    /// Scatter block `blk`'s lane-major state `src` into per-stream
    /// output rows.
    fn project_block<const L: usize>(&self, src: &[f64], blk: usize, out: &mut [f64]) {
        let odim = self.tbl.out_dim();
        let b0 = blk * L;
        let nb = (self.m - b0).min(L);
        for l in 0..nb {
            let row = &mut out[(b0 + l) * odim..(b0 + l + 1) * odim];
            for (o, &idx) in row.iter_mut().zip(&self.tbl.out_map) {
                *o = src[idx as usize * L + l];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature, window_signature, SigEngine, Window};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::truncated_words;

    fn stream_tbl(d: usize, n: usize) -> Arc<StreamTable> {
        Arc::new(StreamTable::new(d, &truncated_words(d, n)))
    }

    #[test]
    fn factor_closure_contains_all_suffixes() {
        // A sparse custom request: the stream table must add exactly
        // the factors, and the suffix CSR must point at true suffixes.
        let req = vec![Word(vec![2, 0, 1]), Word(vec![1, 1])];
        let t = StreamTable::new(3, &req);
        t.check_invariants();
        assert_eq!(t.out_dim(), 2);
        // Factors of (2,0,1): ε,(2),(0),(1),(2,0),(0,1),(2,0,1);
        // of (1,1): (1),(1,1) → closure size 8.
        assert_eq!(t.state_len(), 8);
        // The plain prefix closure would have had only 6 entries.
        assert_eq!(WordTable::build(3, &req).state_len, 6);
    }

    #[test]
    fn truncated_tables_need_no_augmentation() {
        let t = StreamTable::new(2, &truncated_words(2, 3));
        let plain = WordTable::build(2, &truncated_words(2, 3));
        assert_eq!(t.state_len(), plain.state_len);
        assert_eq!(t.out_dim(), plain.out_dim());
        t.check_invariants();
    }

    #[test]
    fn lmul_of_identity_is_exponential() {
        // exp(dx) ⊗ 1 = exp(dx) = 1 ⊗ exp(dx): left- and right-multiply
        // onto the trivial signature must agree exactly.
        let t = stream_tbl(3, 4);
        let dx = [0.5, -1.25, 2.0];
        let mut left = vec![0.0; t.state_len()];
        let mut right = vec![0.0; t.state_len()];
        left[0] = 1.0;
        right[0] = 1.0;
        t.lmul_update(&mut left, &dx);
        chen_update(&t.eng, &mut right, &dx);
        assert_allclose(&left, &right, 1e-15, 1e-15, "exp via lmul");
    }

    #[test]
    fn lmul_matches_combine_with_exponential() {
        // exp(dx) ⊗ S computed by the left-multiply kernel must equal
        // the general combine of exp(dx) with S.
        let mut rng = Rng::new(7100);
        let t = stream_tbl(2, 4);
        let sl = t.state_len();
        // S = signature state of a random path.
        let path = rng.brownian_path(6, 2, 0.8);
        let mut s = vec![0.0; sl];
        s[0] = 1.0;
        for j in 1..=6 {
            let dxbuf = [
                path[j * 2] - path[(j - 1) * 2],
                path[j * 2 + 1] - path[(j - 1) * 2 + 1],
            ];
            chen_update(&t.eng, &mut s, &dxbuf);
        }
        let dx = [0.3, -0.7];
        let mut e = vec![0.0; sl];
        e[0] = 1.0;
        chen_update(&t.eng, &mut e, &dx);
        let mut want = vec![0.0; sl];
        t.combine(&e, &s, &mut want);
        let mut got = s.clone();
        t.lmul_update(&mut got, &dx);
        assert_allclose(&got, &want, 1e-13, 1e-12, "lmul vs combine");
    }

    #[test]
    fn combine_transpose_right_is_adjoint_of_combine() {
        // λ'(p) must equal ∂/∂a(p) Σ_w λ(w)·(a ⊗ e)(w): the combine is
        // linear in `a` (with a(ε) pinned to 1), so central differences
        // are exact up to rounding.
        let t = stream_tbl(2, 3);
        let sl = t.state_len();
        let mut rng = Rng::new(7104);
        let mut a = vec![0.0; sl];
        let mut e = vec![0.0; sl];
        a[0] = 1.0;
        e[0] = 1.0;
        for w in 1..sl {
            a[w] = rng.gaussian() * 0.3;
            e[w] = rng.gaussian() * 0.3;
        }
        let lam: Vec<f64> = (0..sl).map(|w| if w == 0 { 0.0 } else { rng.gaussian() }).collect();
        let mut lam_t = lam.clone();
        t.combine_transpose_right(&e, &mut lam_t);
        let f = |a: &[f64]| {
            let mut c = vec![0.0; sl];
            t.combine(a, &e, &mut c);
            (1..sl).map(|w| lam[w] * c[w]).sum::<f64>()
        };
        let eps = 1e-6;
        let mut ap = a.clone();
        for p in 1..sl {
            ap[p] = a[p] + eps;
            let up = f(&ap);
            ap[p] = a[p] - eps;
            let dn = f(&ap);
            ap[p] = a[p];
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (lam_t[p] - fd).abs() < 1e-7 * (1.0 + fd.abs()),
                "coord {p}: transpose {} vs fd {fd}",
                lam_t[p]
            );
        }
    }

    #[test]
    fn stream_window_matches_recompute() {
        // Every push: window query ≡ batch-style recompute over the
        // same index window (includes warmup, full and refold phases).
        let mut rng = Rng::new(7101);
        let d = 2;
        let tbl = stream_tbl(d, 3);
        let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, 3)));
        let w = 4;
        let mut s = StreamEngine::new(Arc::clone(&tbl), w);
        let m = 14;
        let path = rng.brownian_path(m, d, 0.6);
        for j in 0..=m {
            s.push(&path[j * d..(j + 1) * d]);
            let got = s.window_signature();
            if j == 0 {
                assert!(got.iter().all(|&x| x == 0.0), "empty window not trivial");
                continue;
            }
            let l = j.saturating_sub(w);
            let want = window_signature(&eng, &path, Window::new(l, j));
            assert_allclose(&got, &want, 1e-12, 1e-12, &format!("push {j}"));
        }
    }

    #[test]
    fn stream_extend_matches_signature_bitwise() {
        let mut rng = Rng::new(7102);
        let d = 3;
        let tbl = stream_tbl(d, 3);
        let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, 3)));
        let mut s = StreamEngine::new(tbl, 5);
        let m = 11;
        let path = rng.brownian_path(m, d, 0.4);
        for j in 0..=m {
            s.push(&path[j * d..(j + 1) * d]);
        }
        let got = s.signature();
        let want = signature(&eng, &path);
        assert_eq!(got, want, "streamed extend must be bitwise-equal");
    }

    #[test]
    fn window_one_is_last_increment_exponential() {
        let tbl = stream_tbl(2, 3);
        let mut s = StreamEngine::new(Arc::clone(&tbl), 1);
        s.push(&[0.0, 0.0]);
        s.push(&[1.0, 2.0]);
        s.push(&[1.5, 2.0]);
        // Window of one increment = exp(ΔX_last).
        let got = s.window_signature();
        let mut e = vec![0.0; tbl.state_len()];
        e[0] = 1.0;
        chen_update(&tbl.eng, &mut e, &[0.5, 0.0]);
        let mut want = vec![0.0; tbl.out_dim()];
        tbl.project_into(&e, &mut want);
        assert_allclose(&got, &want, 1e-15, 1e-15, "window 1");
    }

    #[test]
    fn multi_stream_matches_scalar_bitwise() {
        // M spanning several lane residues; every stream must match an
        // independent scalar StreamEngine bitwise at every push.
        let mut rng = Rng::new(7103);
        let d = 2;
        let tbl = stream_tbl(d, 3);
        let lanes = tbl.eng.lanes();
        for m_streams in [1, lanes - 1, lanes, lanes + 3] {
            let w = 3;
            let mut multi = MultiStream::new(Arc::clone(&tbl), m_streams, w);
            let mut singles: Vec<StreamEngine> =
                (0..m_streams).map(|_| StreamEngine::new(Arc::clone(&tbl), w)).collect();
            let steps = 9;
            let paths: Vec<Vec<f64>> =
                (0..m_streams).map(|_| rng.brownian_path(steps, d, 0.7)).collect();
            let odim = tbl.out_dim();
            let mut got = vec![0.0; m_streams * odim];
            let mut sample = vec![0.0; m_streams * d];
            for j in 0..=steps {
                for (k, p) in paths.iter().enumerate() {
                    sample[k * d..(k + 1) * d].copy_from_slice(&p[j * d..(j + 1) * d]);
                    singles[k].push(&p[j * d..(j + 1) * d]);
                }
                multi.push_all(&sample);
                multi.window_into(&mut got);
                for (k, single) in singles.iter_mut().enumerate() {
                    let want = single.window_signature();
                    assert_eq!(
                        &got[k * odim..(k + 1) * odim],
                        &want[..],
                        "stream {k}/{m_streams} push {j}"
                    );
                }
                multi.signature_into(&mut got);
                for (k, single) in singles.iter().enumerate() {
                    let want = single.signature();
                    assert_eq!(&got[k * odim..(k + 1) * odim], &want[..], "full {k} push {j}");
                }
            }
        }
    }

    #[test]
    fn reset_restarts_clean() {
        let tbl = stream_tbl(2, 2);
        let mut s = StreamEngine::new(Arc::clone(&tbl), 2);
        for j in 0..5 {
            s.push(&[j as f64, -(j as f64)]);
        }
        s.reset();
        assert_eq!(s.samples_seen(), 0);
        assert_eq!(s.window_fill(), 0);
        s.push(&[0.0, 0.0]);
        s.push(&[2.0, 1.0]);
        let got = s.window_signature();
        assert!((got[0] - 2.0).abs() < 1e-15 && (got[1] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn scratch_recycling_preserves_correctness() {
        let tbl = stream_tbl(2, 3);
        let mut s = StreamEngine::new(Arc::clone(&tbl), 3);
        for j in 0..7 {
            s.push(&[j as f64, (j * j) as f64]);
        }
        let scratch = s.into_scratch();
        let mut s2 = StreamEngine::with_scratch(Arc::clone(&tbl), 2, scratch);
        assert_eq!(s2.samples_seen(), 0);
        s2.push(&[0.0, 0.0]);
        s2.push(&[1.0, 0.0]);
        let got = s2.window_signature();
        assert!((got[0] - 1.0).abs() < 1e-15 && got[1].abs() < 1e-15);
    }

    #[test]
    fn checkpoint_roundtrip_is_bitwise_identical() {
        // Checkpoint at every phase of the two-stack lifecycle (empty,
        // filling, full, just-refolded) and drive the restored engine
        // alongside the original: every subsequent window and running
        // signature must match bitwise.
        let tbl = stream_tbl(2, 3);
        let mut rng = Rng::new(0x51AC);
        let mut s = StreamEngine::new(Arc::clone(&tbl), 4);
        let samples: Vec<[f64; 2]> = (0..16)
            .map(|_| [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)])
            .collect();
        for (i, x) in samples.iter().enumerate() {
            let ck = s.checkpoint();
            let mut orig = s.clone();
            let mut r =
                StreamEngine::from_checkpoint(Arc::clone(&tbl), &ck, StreamScratch::default())
                    .expect("engine-produced checkpoint restores");
            assert_eq!(r.samples_seen(), orig.samples_seen(), "step {i}");
            assert_eq!(r.window_fill(), orig.window_fill(), "step {i}");
            for y in &samples[i..] {
                orig.push(y);
                r.push(y);
                assert_eq!(orig.window_signature(), r.window_signature(), "step {i}");
                assert_eq!(orig.signature(), r.signature(), "step {i}");
            }
            s.push(x);
        }
    }

    #[test]
    fn forged_checkpoints_are_rejected() {
        let tbl = stream_tbl(2, 2);
        let mut s = StreamEngine::new(Arc::clone(&tbl), 3);
        for j in 0..6 {
            s.push(&[j as f64, 0.5 * j as f64]);
        }
        let good = s.checkpoint();
        let restore = |ck: &StreamCheckpoint| {
            StreamEngine::from_checkpoint(Arc::clone(&tbl), ck, StreamScratch::default())
        };
        assert!(restore(&good).is_ok());
        let mut bad = good.clone();
        bad.window = 0;
        assert!(restore(&bad).is_err(), "zero window must be rejected");
        let mut bad = good.clone();
        bad.front_len += 1;
        assert!(restore(&bad).is_err(), "fill/n_seen mismatch must be rejected");
        let mut bad = good.clone();
        bad.total.pop();
        assert!(restore(&bad).is_err(), "short total must be rejected");
        let mut bad = good.clone();
        bad.back_dx.push(1.0);
        assert!(restore(&bad).is_err(), "odd back_dx must be rejected");
        // A checkpoint taken over one table must not restore over a
        // table with a different state length.
        let other = stream_tbl(2, 3);
        assert!(
            StreamEngine::from_checkpoint(other, &good, StreamScratch::default()).is_err(),
            "table mismatch must be rejected"
        );
    }

    #[test]
    #[should_panic(expected = "window must hold at least one increment")]
    fn zero_window_rejected() {
        StreamEngine::new(stream_tbl(1, 1), 0);
    }

    #[test]
    #[should_panic(expected = "sample must have d entries")]
    fn wrong_sample_dim_rejected() {
        let mut s = StreamEngine::new(stream_tbl(2, 1), 1);
        s.push(&[1.0]);
    }
}
