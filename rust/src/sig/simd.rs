//! Runtime ISA dispatch and element-precision plumbing for the
//! lane-major kernels.
//!
//! PR 2's lane kernels lean on rustc autovectorizing a `[f64; L]`
//! loop; this module makes the vectorization *explicit* (ROADMAP open
//! item 2, mirroring pySigLib's hand-vectorized CPU kernels): a tiny
//! [`Vector`] trait abstracts one register's worth of lanes
//! (load/store/splat/mul/add — deliberately **no FMA**, see below),
//! implemented for
//!
//! * plain scalars (`f64`/`f32`, width 1 — the portable fallback and
//!   the bitwise oracle),
//! * AVX2 `__m256d`/`__m256` (width 4/8) on x86-64,
//! * AVX-512 `__m512d`/`__m512` (width 8/16) behind the off-by-default
//!   `avx512` cargo feature (the intrinsics need a newer rustc than the
//!   crate's MSRV),
//! * NEON `float64x2_t`/`float32x4_t` (width 2/4) on aarch64.
//!
//! [`Isa`] names the dispatch targets. Which one actually runs is
//! decided per engine at construction ([`Isa::pick`]: the
//! `PATHSIG_SIMD` override, else best detected via
//! `is_x86_feature_detected!`) and re-validated per kernel call
//! ([`Isa::effective`]) so a hand-set `eng.simd` can never execute an
//! instruction the CPU lacks — it silently downgrades along
//! AVX-512 → AVX2 → scalar (NEON → scalar) instead.
//!
//! **Bitwise contract.** Every ISA path must produce bit-identical
//! results to the scalar kernel at the same lane width (the repo's
//! lane ≡ scalar differential-testing story extends to ISA ≡ scalar,
//! see `tests/engine_properties.rs`). That is why the trait exposes
//! only elementwise IEEE-754 mul/add — a fused multiply-add would
//! change roundings — and why the kernel bodies in [`super::lanes`]
//! keep the exact per-lane operation order of the original `[f64; L]`
//! loops, merely regrouping lanes into width-`W` register chunks.
//!
//! [`Precision`] selects the element type of the *forward inference*
//! path: `F32` halves state bytes and doubles effective SIMD lanes per
//! register. Training (the backward pass), streaming and the tree
//! reduction stay f64 — see DESIGN.md "Explicit SIMD & precision
//! modes" for when f32 is safe.

/// A SIMD instruction-set target for the lane kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable `[f64; L]` loop (autovectorized at best). Always
    /// available; the bitwise oracle for every other path.
    Scalar,
    /// x86-64 AVX2: 256-bit registers, 4 × f64 / 8 × f32.
    Avx2,
    /// x86-64 AVX-512F: 512-bit registers, 8 × f64 / 16 × f32. Only
    /// dispatchable when the crate is built with the `avx512` feature
    /// (intrinsics post-date the MSRV) *and* the CPU reports avx512f.
    Avx512,
    /// aarch64 NEON: 128-bit registers, 2 × f64 / 4 × f32 (baseline on
    /// every aarch64 target, so no runtime probe is needed).
    Neon,
}

/// Element precision of the forward inference path. `F64` is the
/// training default; `F32` doubles effective lane width (the f32 lane
/// block is `2L` wide) at ~1e-7 relative element error — the engine's
/// conformance bar is 1e-5 against f64 on the property matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// IEEE-754 binary64 everywhere (training default).
    #[default]
    F64,
    /// binary32 forward/inference path; backward, streaming and the
    /// time-parallel tree still run f64.
    F32,
}

impl Precision {
    /// Artifact/CLI token: `"f64"` / `"f32"`.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

impl Isa {
    /// Artifact/env token: `"scalar"`, `"avx2"`, `"avx512"`, `"neon"`.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// f64 lanes per register on this ISA.
    pub fn width_f64(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 4,
            Isa::Avx512 => 8,
            Isa::Neon => 2,
        }
    }

    /// f32 lanes per register on this ISA.
    pub fn width_f32(self) -> usize {
        2 * self.width_f64()
    }

    /// Can this ISA actually execute here — right architecture, CPU
    /// reports the feature, and (for AVX-512) the intrinsics were
    /// compiled in? `Scalar` is always available.
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::is_x86_feature_detected!("avx2"),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => std::is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true, // NEON is baseline on aarch64.
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// One step down the fallback chain (AVX-512 → AVX2 → scalar,
    /// NEON → scalar).
    fn downgrade(self) -> Isa {
        match self {
            Isa::Avx512 => Isa::Avx2,
            _ => Isa::Scalar,
        }
    }

    /// The ISA a kernel call will actually run: downgrade until the
    /// target is available **and** its register width divides the lane
    /// width (e.g. AVX-512 f64 needs `L % 8 == 0`, so `L = 4` runs the
    /// AVX2 path). Kernels call this on every dispatch, so an
    /// `eng.simd` set by hand — tests do — is safe on any CPU.
    pub fn effective(self, lane_width: usize, f32_elems: bool) -> Isa {
        let mut isa = self;
        loop {
            let w = if f32_elems { isa.width_f32() } else { isa.width_f64() };
            if isa.available() && lane_width % w == 0 {
                return isa;
            }
            isa = isa.downgrade();
        }
    }

    /// Every ISA that can run here, best first (always ends with
    /// `Scalar`). `supported[0]` is what `auto` resolves to; tests
    /// iterate the whole list to pin ISA ≡ scalar per target.
    pub fn supported() -> Vec<Isa> {
        let mut v = Vec::with_capacity(4);
        for isa in [Isa::Avx512, Isa::Avx2, Isa::Neon] {
            if isa.available() {
                v.push(isa);
            }
        }
        v.push(Isa::Scalar);
        v
    }

    /// Resolve the engine's dispatch target from a raw `PATHSIG_SIMD`
    /// value: the best available ISA for unset/`auto`, the named ISA
    /// when it is available, and the best available — plus a warning —
    /// for unknown tokens or ISAs this machine/build cannot run.
    pub(crate) fn pick(env: Option<&str>) -> (Isa, Option<String>) {
        Isa::pick_from(env, &Isa::supported())
    }

    /// Pure core of [`Isa::pick`]: `available` is the best-first
    /// candidate list (unit-testable with a fake list; `Scalar` must be
    /// present).
    pub(crate) fn pick_from(env: Option<&str>, available: &[Isa]) -> (Isa, Option<String>) {
        debug_assert!(available.contains(&Isa::Scalar));
        let best = available[0];
        let Some(raw) = env else { return (best, None) };
        let s = raw.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("auto") {
            return (best, None);
        }
        let named = [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon]
            .into_iter()
            .find(|isa| s.eq_ignore_ascii_case(isa.name()));
        match named {
            Some(isa) if available.contains(&isa) => (isa, None),
            Some(isa) => (
                best,
                Some(format!(
                    "PATHSIG_SIMD={} is not available on this CPU/build; using {}",
                    isa.name(),
                    best.name()
                )),
            ),
            None => (
                best,
                Some(format!(
                    "ignoring invalid PATHSIG_SIMD={raw:?} \
                     (supported: auto, scalar, avx2, avx512, neon); using {}",
                    best.name()
                )),
            ),
        }
    }
}

/// Parse a raw `PATHSIG_PRECISION` value: `f64` (default) or `f32`,
/// anything else warns and keeps the default. Pure — unit-testable
/// without touching the process environment.
pub(crate) fn precision_from(env: Option<&str>) -> (Precision, Option<String>) {
    let Some(raw) = env else { return (Precision::F64, None) };
    let s = raw.trim();
    if s.is_empty() || s.eq_ignore_ascii_case("f64") || s == "64" {
        (Precision::F64, None)
    } else if s.eq_ignore_ascii_case("f32") || s == "32" {
        (Precision::F32, None)
    } else {
        (
            Precision::F64,
            Some(format!(
                "ignoring invalid PATHSIG_PRECISION={raw:?} (supported: f64, f32); using f64"
            )),
        )
    }
}

/// Kernel element scalar: the two IEEE-754 precisions the engine
/// computes in. `from_f64` is how the f32 path ingests the engine's
/// f64 constant tables (`1/k`, `1/k!`) and path data.
pub(crate) trait Elem: Copy + PartialEq + Send + Sync + 'static {
    const ZERO: Self;
    const ONE: Self;
    fn from_f64(x: f64) -> Self;
}

impl Elem for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    #[inline(always)]
    fn from_f64(x: f64) -> f64 {
        x
    }
}

impl Elem for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    #[inline(always)]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
}

/// One register's worth of kernel lanes. Methods are `unsafe` because
/// the x86 implementations are `core::arch` intrinsics that may only
/// execute inside a matching `#[target_feature]` region (the
/// monomorphic wrappers in [`super::lanes`]); `load`/`store`
/// additionally require `WIDTH` elements readable/writable at `p`.
/// No alignment requirement — all loads/stores are unaligned.
///
/// Only `mul` and `add` exist on purpose: the bitwise ISA ≡ scalar
/// contract rules out FMA (different rounding) and any horizontal op.
pub(crate) trait Vector: Copy {
    type E: Elem;
    const WIDTH: usize;
    unsafe fn load(p: *const Self::E) -> Self;
    unsafe fn store(self, p: *mut Self::E);
    unsafe fn splat(x: Self::E) -> Self;
    unsafe fn mul(self, o: Self) -> Self;
    unsafe fn add(self, o: Self) -> Self;
}

/// Width-1 "vector": the scalar fallback, and the reference semantics
/// every wider implementation must reproduce bit-for-bit.
#[derive(Clone, Copy)]
pub(crate) struct Scalar1<E>(E);

macro_rules! impl_scalar1 {
    ($e:ty) => {
        impl Vector for Scalar1<$e> {
            type E = $e;
            const WIDTH: usize = 1;
            #[inline(always)]
            unsafe fn load(p: *const $e) -> Self {
                Scalar1(*p)
            }
            #[inline(always)]
            unsafe fn store(self, p: *mut $e) {
                *p = self.0;
            }
            #[inline(always)]
            unsafe fn splat(x: $e) -> Self {
                Scalar1(x)
            }
            #[inline(always)]
            unsafe fn mul(self, o: Self) -> Self {
                Scalar1(self.0 * o.0)
            }
            #[inline(always)]
            unsafe fn add(self, o: Self) -> Self {
                Scalar1(self.0 + o.0)
            }
        }
    };
}
impl_scalar1!(f64);
impl_scalar1!(f32);

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::Vector;
    use core::arch::x86_64::*;

    /// AVX2 4 × f64 (the mul/add used are AVX ops; detection keys on
    /// avx2, which implies avx).
    #[derive(Clone, Copy)]
    pub(crate) struct F64x4(__m256d);

    impl Vector for F64x4 {
        type E = f64;
        const WIDTH: usize = 4;
        #[inline(always)]
        unsafe fn load(p: *const f64) -> Self {
            F64x4(_mm256_loadu_pd(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f64) {
            _mm256_storeu_pd(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f64) -> Self {
            F64x4(_mm256_set1_pd(x))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F64x4(_mm256_mul_pd(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F64x4(_mm256_add_pd(self.0, o.0))
        }
    }

    /// AVX2 8 × f32.
    #[derive(Clone, Copy)]
    pub(crate) struct F32x8(__m256);

    impl Vector for F32x8 {
        type E = f32;
        const WIDTH: usize = 8;
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x8(_mm256_loadu_ps(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm256_storeu_ps(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            F32x8(_mm256_set1_ps(x))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F32x8(_mm256_mul_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F32x8(_mm256_add_ps(self.0, o.0))
        }
    }

    /// AVX-512F 8 × f64 — gated: the 512-bit intrinsics stabilized
    /// after the crate's MSRV, so they are compiled only under
    /// `--features avx512`.
    #[cfg(feature = "avx512")]
    #[derive(Clone, Copy)]
    pub(crate) struct F64x8(__m512d);

    #[cfg(feature = "avx512")]
    impl Vector for F64x8 {
        type E = f64;
        const WIDTH: usize = 8;
        #[inline(always)]
        unsafe fn load(p: *const f64) -> Self {
            F64x8(_mm512_loadu_pd(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f64) {
            _mm512_storeu_pd(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f64) -> Self {
            F64x8(_mm512_set1_pd(x))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F64x8(_mm512_mul_pd(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F64x8(_mm512_add_pd(self.0, o.0))
        }
    }

    /// AVX-512F 16 × f32 (same gate as [`F64x8`]).
    #[cfg(feature = "avx512")]
    #[derive(Clone, Copy)]
    pub(crate) struct F32x16(__m512);

    #[cfg(feature = "avx512")]
    impl Vector for F32x16 {
        type E = f32;
        const WIDTH: usize = 16;
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x16(_mm512_loadu_ps(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm512_storeu_ps(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            F32x16(_mm512_set1_ps(x))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F32x16(_mm512_mul_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F32x16(_mm512_add_ps(self.0, o.0))
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::{F32x8, F64x4};
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
pub(crate) use x86::{F32x16, F64x8};

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::Vector;
    use core::arch::aarch64::*;

    /// NEON 2 × f64 (baseline on aarch64 — no feature gate needed).
    #[derive(Clone, Copy)]
    pub(crate) struct F64x2(float64x2_t);

    impl Vector for F64x2 {
        type E = f64;
        const WIDTH: usize = 2;
        #[inline(always)]
        unsafe fn load(p: *const f64) -> Self {
            F64x2(vld1q_f64(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f64) {
            vst1q_f64(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f64) -> Self {
            F64x2(vdupq_n_f64(x))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F64x2(vmulq_f64(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F64x2(vaddq_f64(self.0, o.0))
        }
    }

    /// NEON 4 × f32.
    #[derive(Clone, Copy)]
    pub(crate) struct F32x4(float32x4_t);

    impl Vector for F32x4 {
        type E = f32;
        const WIDTH: usize = 4;
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x4(vld1q_f32(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            vst1q_f32(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            F32x4(vdupq_n_f32(x))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F32x4(vmulq_f32(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F32x4(vaddq_f32(self.0, o.0))
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) use arm::{F32x4, F64x2};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supported_is_best_first_and_ends_scalar() {
        let sup = Isa::supported();
        assert_eq!(*sup.last().unwrap(), Isa::Scalar);
        assert!(sup.iter().all(|isa| isa.available()));
        // Strictly decreasing register width ⇒ no duplicates, best first.
        for pair in sup.windows(2) {
            assert!(pair[0].width_f64() > pair[1].width_f64(), "{sup:?}");
        }
    }

    #[test]
    fn pick_auto_and_named() {
        let avail = [Isa::Avx2, Isa::Scalar];
        for auto in [None, Some("auto"), Some(" AUTO "), Some("")] {
            assert_eq!(Isa::pick_from(auto, &avail), (Isa::Avx2, None));
        }
        assert_eq!(Isa::pick_from(Some("scalar"), &avail), (Isa::Scalar, None));
        assert_eq!(Isa::pick_from(Some("AVX2"), &avail), (Isa::Avx2, None));
    }

    #[test]
    fn pick_unavailable_isa_warns_and_falls_back() {
        let avail = [Isa::Avx2, Isa::Scalar];
        let (isa, warn) = Isa::pick_from(Some("avx512"), &avail);
        assert_eq!(isa, Isa::Avx2);
        let msg = warn.expect("unavailable ISA must warn");
        assert!(msg.contains("avx512") && msg.contains("avx2"), "{msg}");
        let (isa, warn) = Isa::pick_from(Some("neon"), &[Isa::Scalar]);
        assert_eq!(isa, Isa::Scalar);
        assert!(warn.unwrap().contains("neon"));
    }

    #[test]
    fn pick_invalid_token_warns_and_falls_back() {
        let avail = [Isa::Scalar];
        for bad in ["sse9", "42", "avx2 fast", "scalar,avx2"] {
            let (isa, warn) = Isa::pick_from(Some(bad), &avail);
            assert_eq!(isa, Isa::Scalar, "{bad}");
            let msg = warn.expect("invalid token must warn");
            assert!(msg.contains("invalid PATHSIG_SIMD") && msg.contains(bad), "{msg}");
        }
    }

    #[test]
    fn pick_resolves_against_this_machine() {
        // Whatever the hardware, `auto` resolves to something available
        // and warning-free, and `scalar` is always honoured.
        let (isa, warn) = Isa::pick(None);
        assert!(isa.available() && warn.is_none());
        assert_eq!(Isa::pick(Some("scalar")), (Isa::Scalar, None));
    }

    #[test]
    fn effective_downgrades_to_runnable() {
        // Scalar is a fixed point at every width/precision.
        for lw in [1usize, 4, 8, 16, 32] {
            assert_eq!(Isa::Scalar.effective(lw, false), Isa::Scalar);
            assert_eq!(Isa::Scalar.effective(lw, true), Isa::Scalar);
        }
        // Whatever is requested, the result is available and divides.
        for req in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            for lw in [4usize, 8, 16, 32] {
                for f32e in [false, true] {
                    let e = req.effective(lw, f32e);
                    let w = if f32e { e.width_f32() } else { e.width_f64() };
                    assert!(e.available(), "{req:?}@{lw} → {e:?} unavailable");
                    assert_eq!(lw % w, 0, "{req:?}@{lw} → {e:?} width {w}");
                }
            }
        }
        // A supported vector ISA is a fixed point when its width divides.
        for &isa in &Isa::supported() {
            assert_eq!(isa.effective(32, false), isa);
        }
    }

    #[test]
    fn widths_and_names() {
        assert_eq!(Isa::Scalar.width_f64(), 1);
        assert_eq!(Isa::Avx2.width_f64(), 4);
        assert_eq!(Isa::Avx512.width_f64(), 8);
        assert_eq!(Isa::Neon.width_f64(), 2);
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            assert_eq!(isa.width_f32(), 2 * isa.width_f64());
        }
        assert_eq!(Isa::Avx512.name(), "avx512");
        assert_eq!(Precision::F32.name(), "f32");
    }

    #[test]
    fn precision_parsing() {
        assert_eq!(precision_from(None), (Precision::F64, None));
        for ok64 in ["f64", "F64", " 64 ", ""] {
            assert_eq!(precision_from(Some(ok64)), (Precision::F64, None));
        }
        for ok32 in ["f32", "F32", " 32 "] {
            assert_eq!(precision_from(Some(ok32)), (Precision::F32, None));
        }
        let (p, warn) = precision_from(Some("half"));
        assert_eq!(p, Precision::F64);
        assert!(warn.unwrap().contains("PATHSIG_PRECISION"));
    }
}
