//! Forward signature computation — Algorithm 1 of the paper.
//!
//! One Chen update per time step: for every word `w = (i_1,…,i_n)` in the
//! prefix-closed state set,
//!
//! ```text
//! S_j(w) = S_{j-1}(w) + ΔX^{(i_n)}·( S_{j-1}(w_[n-1])
//!        + ΔX^{(i_{n-1})}/2·( S_{j-1}(w_[n-2]) + … + ΔX^{(i_1)}/n·S_{j-1}(ε) ))
//! ```
//!
//! evaluated with Horner's method — equivalent to the prefix–suffix sum
//! of Chen's relation (3) but without materialising `exp(ΔX_j)`.
//!
//! Batch entry points route through the lane-major kernel
//! ([`crate::sig::lanes`]) whenever the batch is at least one lane
//! block wide; the scalar per-path kernel below remains the `B < L`
//! fallback and the differential-testing oracle.

use super::lanes::{
    lane_forward_dispatch, lane_forward_f32_dispatch, project_block, project_block_f32,
    ForwardWorkspace,
};
use super::schedule::{self, TimeMode};
use super::{Precision, SigEngine};
use crate::util::threadpool::{parallel_for_into, parallel_map};

/// Apply one Chen/Horner update `S ← S ⊗ exp(dx)` in place.
///
/// `state` is a closure-state vector (`state[0] == 1`), `dx` the step
/// increment (`d` entries). Levels are processed top-down so in-place
/// updates read only step-`j-1` prefix values (see module docs of
/// [`crate::sig`]).
#[inline]
pub fn chen_update(eng: &SigEngine, state: &mut [f64], dx: &[f64]) {
    let t = &eng.table;
    debug_assert_eq!(state.len(), t.state_len);
    debug_assert_eq!(dx.len(), t.d);
    for n in (1..=t.max_level).rev() {
        let range = t.level_range(n);
        let level_base = t.level_csr_base(n);
        for (off, i) in range.enumerate() {
            let base = level_base + off * n;
            // Horner inner loop over the prefix chain.
            // SAFETY: indices come from the validated WordTable
            // (letters < d, prefix indices < state_len, CSR rows in
            // bounds; see `WordTable::check_invariants`).
            unsafe {
                let letters = t.csr_letters.get_unchecked(base..base + n);
                let prefixes = t.csr_prefix.get_unchecked(base..base + n);
                let mut acc = 1.0; // S(ε) — state[0] is pinned to 1.
                for k in 1..n {
                    let letter = *letters.get_unchecked(k - 1) as usize;
                    acc = acc * dx.get_unchecked(letter) * eng.recip.get_unchecked(n - k + 1)
                        + state.get_unchecked(*prefixes.get_unchecked(k) as usize);
                }
                let last = *letters.get_unchecked(n - 1) as usize;
                *state.get_unchecked_mut(i) += acc * dx.get_unchecked(last);
            }
        }
    }
}

/// The scalar forward sweep shared by every scalar entry point
/// (single-path, window, stream prologue and the backward pass's
/// forward reconstruction): (re)initialise `state`/`dx` for `eng` and
/// apply one Chen update per step in `jl+1 ..= jr`. Allocation-free in
/// steady state (`resize` within capacity).
pub(crate) fn forward_sweep_range(
    eng: &SigEngine,
    path: &[f64],
    jl: usize,
    jr: usize,
    state: &mut Vec<f64>,
    dx: &mut Vec<f64>,
) {
    let d = eng.table.d;
    state.clear();
    state.resize(eng.table.state_len, 0.0);
    state[0] = 1.0;
    dx.clear();
    dx.resize(d, 0.0);
    for j in (jl + 1)..=jr {
        for i in 0..d {
            dx[i] = path[j * d + i] - path[(j - 1) * d + i];
        }
        chen_update(eng, state, dx);
    }
}

/// Scalar forward sweep into workspace buffers (`ws.state` ends at the
/// terminal closure state). Allocation-free in steady state.
pub(crate) fn forward_into_ws(eng: &SigEngine, path: &[f64], ws: &mut ForwardWorkspace) {
    let d = eng.table.d;
    debug_assert_eq!(path.len() % d, 0);
    let m1 = path.len() / d;
    debug_assert!(m1 >= 1);
    forward_sweep_range(eng, path, 0, m1 - 1, &mut ws.state, &mut ws.dx);
}

/// Forward pass over a full path, returning the closure **state** vector
/// (index 0 = ε = 1.0). `path` is row-major `(M+1, d)`.
pub fn sig_forward_state(eng: &SigEngine, path: &[f64]) -> Vec<f64> {
    let d = eng.table.d;
    assert!(path.len() % d == 0, "path length not divisible by d");
    assert!(path.len() / d >= 1, "path needs at least one point");
    let mut ws = ForwardWorkspace::default();
    forward_into_ws(eng, path, &mut ws);
    ws.state
}

/// The projected signature `π_I(S_{0,T}(X))` of a single path
/// (row-major `(M+1, d)`), in the engine's requested-word order.
///
/// # Examples
///
/// ```
/// use pathsig::sig::{signature, SigEngine};
/// use pathsig::words::{truncated_words, WordTable};
///
/// // The axis path (0,0) → (1,0) → (1,1) at depth 2 over d = 2.
/// let eng = SigEngine::new(WordTable::build(2, &truncated_words(2, 2)));
/// let sig = signature(&eng, &[0.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
/// // Coordinate order: (1), (2), (1,1), (1,2), (2,1), (2,2).
/// assert_eq!(sig.len(), 6);
/// assert!((sig[0] - 1.0).abs() < 1e-12); // total x-increment
/// assert!((sig[3] - 1.0).abs() < 1e-12); // S((1,2)): x moved before y
/// assert!(sig[4].abs() < 1e-12);         // S((2,1)): y never led
/// ```
pub fn signature(eng: &SigEngine, path: &[f64]) -> Vec<f64> {
    let state = sig_forward_state(eng, path);
    let mut out = vec![0.0; eng.out_dim()];
    eng.table.project(&state, &mut out);
    out
}

/// Batched signatures: `paths` is `(B, M+1, d)` row-major, result is
/// `(B, |I|)` row-major. Parallel over paths (the paper's
/// batch-parallelism axis); blocks of [`SigEngine::lanes`] paths go
/// through the lane-major SIMD kernel.
pub fn signature_batch(eng: &SigEngine, paths: &[f64], batch: usize) -> Vec<f64> {
    let mut out = vec![0.0; batch * eng.out_dim()];
    signature_batch_into(eng, paths, batch, &mut out);
    out
}

/// [`signature_batch`] writing into a caller-provided buffer
/// (`out.len() == batch · |I|`). With a sequential engine this is the
/// zero-allocation hot path: workspaces come from the engine's pool and
/// every row is written in place (no join copy) — verified by the
/// counting allocator in `benches/fig1_truncated.rs`.
pub fn signature_batch_into(eng: &SigEngine, paths: &[f64], batch: usize, out: &mut [f64]) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(out.len(), batch * odim, "output buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 1, "bad path shape");
    let m1 = per_path / d;
    // The f32 inference mode takes the lane-major driver end to end
    // (2L lanes per block; even B < 2L rides the lane kernel with
    // inert padded lanes) so one engine mode means one numeric
    // profile — it never mixes with the f64 tree or scalar paths.
    if eng.precision == Precision::F32 {
        return signature_batch_f32_into(eng, paths, batch, per_path, m1, out);
    }
    // Long paths with small batches route to the time-parallel tree
    // (chunked Chen sweeps + log-depth combine reduction, ~1e-12 vs the
    // sequential kernels) — see `schedule` for the policy and the
    // `PATHSIG_TIME_CHUNK` knob.
    if let TimeMode::TimeParallel { chunk } = schedule::plan(eng, batch, m1 - 1) {
        return super::tree::signature_batch_tree_into(eng, paths, batch, chunk, out);
    }
    let lanes = eng.lanes();

    if batch < lanes {
        // Scalar per-path fallback, rows still written in place (the
        // scalar kernel sizes its own workspace buffers).
        let nw = eng.threads.min(batch).max(1);
        let mut workers = eng.fwd_pool.take_at_least(nw);
        parallel_for_into(out, odim, &mut workers[..nw], |b, row, ws| {
            forward_into_ws(eng, &paths[b * per_path..(b + 1) * per_path], ws);
            eng.table.project(&ws.state, row);
        });
        eng.fwd_pool.put(workers);
        return;
    }

    // Lane-major path: each unit is a block of `lanes` paths (last
    // block may be partial — padded lanes carry zero increments).
    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks).max(1);
    let mut workers = eng.fwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes(eng);
    }
    parallel_for_into(out, lanes * odim, &mut workers[..nw], |blk, out_rows, ws| {
        let b0 = blk * lanes;
        let nb = (batch - b0).min(lanes);
        let block = &paths[b0 * per_path..(b0 + nb) * per_path];
        lane_forward_dispatch(eng, block, nb, per_path, 0, m1 - 1, ws);
        project_block(eng, &ws.lane_state, lanes, nb, out_rows);
    });
    eng.fwd_pool.put(workers);
}

/// The [`Precision::F32`] batch driver: identical block structure to
/// the f64 lane path above, at `2L` lanes per block over f32 state.
/// Increments are rounded to f32 once at the transpose and results
/// widened to f64 once at the projection, so the public API stays
/// `&[f64]` end to end. Allocation-free in steady state (the f32
/// workspace buffers live in the same pooled [`ForwardWorkspace`]s).
fn signature_batch_f32_into(
    eng: &SigEngine,
    paths: &[f64],
    batch: usize,
    per_path: usize,
    m1: usize,
    out: &mut [f64],
) {
    let odim = eng.out_dim();
    let lanes = eng.lanes_f32();
    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks).max(1);
    let mut workers = eng.fwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes_f32(eng);
    }
    parallel_for_into(out, lanes * odim, &mut workers[..nw], |blk, out_rows, ws| {
        let b0 = blk * lanes;
        let nb = (batch - b0).min(lanes);
        let block = &paths[b0 * per_path..(b0 + nb) * per_path];
        lane_forward_f32_dispatch(eng, block, nb, per_path, 0, m1 - 1, ws);
        project_block_f32(eng, &ws.lane_state_f32, lanes, nb, out_rows);
    });
    eng.fwd_pool.put(workers);
}

/// The pre-lane scalar batch path: one allocation-per-row
/// `parallel_map` over paths. Kept verbatim as (a) the baseline the
/// Fig-1 bench measures the lane kernel against and (b) the
/// differential-testing oracle for `signature_batch`.
pub fn signature_batch_scalar(eng: &SigEngine, paths: &[f64], batch: usize) -> Vec<f64> {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let out_dim = eng.out_dim();
    let rows = parallel_map(batch, eng.threads, |b| {
        let path = &paths[b * per_path..(b + 1) * per_path];
        let state = sig_forward_state(eng, path);
        let mut row = vec![0.0; out_dim];
        eng.table.project(&state, &mut row);
        row
    });
    let mut out = Vec::with_capacity(batch * out_dim);
    for row in rows {
        out.extend(row);
    }
    out
}

/// Expanding-window stream `j ↦ π_I(S_{0,t_j}(X))` for `j = 0..=M`
/// (§5's "signatures as stochastic processes" view). Returns row-major
/// `(M+1, |I|)`. Costs one forward pass — each step's projection is
/// emitted as the recursion passes through it.
pub fn signature_stream(eng: &SigEngine, path: &[f64]) -> Vec<f64> {
    let m1 = path.len() / eng.table.d;
    let mut out = vec![0.0; m1 * eng.out_dim()];
    signature_stream_into(eng, path, &mut out);
    out
}

/// [`signature_stream`] writing into a caller-provided `(M+1, |I|)`
/// buffer, with scratch from the engine's workspace pool — zero
/// allocations in steady state.
pub fn signature_stream_into(eng: &SigEngine, path: &[f64], out: &mut [f64]) {
    let d = eng.table.d;
    assert!(path.len() % d == 0, "path length not divisible by d");
    let m1 = path.len() / d;
    assert!(m1 >= 1, "path needs at least one point");
    let odim = eng.out_dim();
    assert_eq!(out.len(), m1 * odim, "output buffer has wrong size");
    let mut workers = eng.fwd_pool.take_at_least(1);
    let ws = &mut workers[0];
    forward_sweep_range(eng, path, 0, 0, &mut ws.state, &mut ws.dx); // init only
    eng.table.project(&ws.state, &mut out[0..odim]);
    for j in 1..m1 {
        for i in 0..d {
            ws.dx[i] = path[j * d + i] - path[(j - 1) * d + i];
        }
        chen_update(eng, &mut ws.state, &ws.dx);
        eng.table.project(&ws.state, &mut out[j * odim..(j + 1) * odim]);
    }
    eng.fwd_pool.put(workers);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::SigEngine;
    use crate::tensor::TruncTensor;
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, Word, WordTable};

    fn trunc_engine(d: usize, n: usize) -> SigEngine {
        SigEngine::new(WordTable::build(d, &truncated_words(d, n)))
    }

    /// Oracle: signature via dense tensor-algebra recursion.
    fn oracle_signature(d: usize, depth: usize, path: &[f64]) -> Vec<f64> {
        let m1 = path.len() / d;
        let mut s = TruncTensor::one(d, depth);
        let mut scratch = Vec::new();
        for j in 1..m1 {
            let dx: Vec<f64> = (0..d)
                .map(|i| path[j * d + i] - path[(j - 1) * d + i])
                .collect();
            s.mul_assign(&TruncTensor::exp_level1(&dx, depth), &mut scratch);
        }
        s.flatten_nonscalar()
    }

    #[test]
    fn single_segment_is_tensor_exponential() {
        // Proposition 3.1: one linear segment ⇒ S = exp(ΔX).
        let eng = trunc_engine(3, 4);
        let path = [0.0, 0.0, 0.0, 0.5, -1.0, 2.0];
        let got = signature(&eng, &path);
        let want = TruncTensor::exp_level1(&[0.5, -1.0, 2.0], 4).flatten_nonscalar();
        assert_allclose(&got, &want, 1e-14, 1e-12, "exp closed form");
    }

    #[test]
    fn matches_tensor_algebra_oracle() {
        let mut rng = Rng::new(100);
        for &(d, n, m) in &[(2, 3, 5), (3, 4, 8), (4, 2, 20), (2, 6, 10)] {
            let eng = trunc_engine(d, n);
            let path = rng.brownian_path(m, d, 0.5);
            let got = signature(&eng, &path);
            let want = oracle_signature(d, n, &path);
            assert_allclose(&got, &want, 1e-11, 1e-9, &format!("d={d} n={n} m={m}"));
        }
    }

    #[test]
    fn level1_is_total_increment() {
        let mut rng = Rng::new(101);
        let d = 3;
        let eng = trunc_engine(d, 2);
        let path = rng.brownian_path(12, d, 1.0);
        let sig = signature(&eng, &path);
        let m = path.len() / d - 1;
        for i in 0..d {
            let total = path[m * d + i] - path[i];
            assert!((sig[i] - total).abs() < 1e-12);
        }
    }

    #[test]
    fn level2_antisymmetric_part_is_levy_area() {
        // For the unit square loop (0,0)→(1,0)→(1,1)→(0,1)→(0,0),
        // S((0,1)) - S((1,0)) = 2 · (signed area) = 2·1 = … the loop
        // encloses area 1, sign depends on orientation (ccw = +).
        let eng = trunc_engine(2, 2);
        let path = [
            0.0, 0.0, //
            1.0, 0.0, //
            1.0, 1.0, //
            0.0, 1.0, //
            0.0, 0.0,
        ];
        let sig = signature(&eng, &path);
        // order: (0),(1),(00),(01),(10),(11)
        let area2 = sig[3] - sig[4];
        assert!((area2 - 2.0).abs() < 1e-12, "2·area = {area2}");
        // Level 1 of a loop vanishes.
        assert!(sig[0].abs() < 1e-14 && sig[1].abs() < 1e-14);
    }

    #[test]
    fn projection_matches_truncated_subset() {
        // A projected engine must agree with the truncated engine on the
        // requested coordinates.
        let mut rng = Rng::new(102);
        let d = 3;
        let full = trunc_engine(d, 4);
        let request = vec![
            Word(vec![2, 0, 1, 1]),
            Word(vec![0]),
            Word(vec![1, 1]),
            Word(vec![2, 2, 2]),
        ];
        let proj = SigEngine::new(WordTable::build(d, &request));
        let path = rng.brownian_path(15, d, 0.7);
        let full_sig = signature(&full, &path);
        let proj_sig = signature(&proj, &path);
        let all_words = truncated_words(d, 4);
        for (k, w) in request.iter().enumerate() {
            let pos = all_words.iter().position(|x| x == w).unwrap();
            assert!(
                (proj_sig[k] - full_sig[pos]).abs() < 1e-12,
                "word {} mismatch",
                w.pretty()
            );
        }
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(103);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let b = 7;
        let m = 9;
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
        }
        let batch_out = signature_batch(&eng, &paths, b);
        for k in 0..b {
            let single = signature(&eng, &paths[k * (m + 1) * d..(k + 1) * (m + 1) * d]);
            assert_allclose(
                &batch_out[k * eng.out_dim()..(k + 1) * eng.out_dim()],
                &single,
                1e-15,
                0.0,
                "batch row",
            );
        }
    }

    #[test]
    fn batch_lane_path_matches_scalar_oracle() {
        // Batch wide enough to engage the lane kernel, size chosen so
        // the last block is partial.
        let mut rng = Rng::new(106);
        let d = 3;
        let eng = trunc_engine(d, 3);
        let b = eng.lanes() * 2 + 3;
        let m = 6;
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 0.8));
        }
        let got = signature_batch(&eng, &paths, b);
        let want = signature_batch_scalar(&eng, &paths, b);
        assert_allclose(&got, &want, 0.0, 0.0, "lane vs scalar batch");
    }

    #[test]
    fn batch_into_reuses_buffer() {
        let mut rng = Rng::new(107);
        let d = 2;
        let eng = trunc_engine(d, 2);
        let b = 12;
        let m = 4;
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
        }
        let mut out = vec![f64::NAN; b * eng.out_dim()];
        signature_batch_into(&eng, &paths, b, &mut out);
        let want = signature_batch_scalar(&eng, &paths, b);
        assert_allclose(&out, &want, 0.0, 0.0, "into == scalar");
        // Second call with the same buffer must fully overwrite it.
        signature_batch_into(&eng, &paths, b, &mut out);
        assert_allclose(&out, &want, 0.0, 0.0, "second call");
    }

    #[test]
    fn stream_last_row_is_full_signature() {
        let mut rng = Rng::new(104);
        let d = 3;
        let eng = trunc_engine(d, 3);
        let path = rng.brownian_path(11, d, 0.5);
        let stream = signature_stream(&eng, &path);
        let full = signature(&eng, &path);
        let odim = eng.out_dim();
        assert_allclose(&stream[11 * odim..], &full, 1e-14, 1e-12, "stream end");
        // Row 0 is the trivial signature (all zero beyond ε).
        assert!(stream[..odim].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn constant_path_has_trivial_signature() {
        let eng = trunc_engine(2, 4);
        let path = [3.0, -1.0].repeat(10);
        let sig = signature(&eng, &path);
        assert!(sig.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn reparametrisation_invariance() {
        // Inserting duplicated points (zero increments) and re-spacing
        // must not change the signature.
        let mut rng = Rng::new(105);
        let d = 2;
        let eng = trunc_engine(d, 4);
        let path = rng.brownian_path(8, d, 1.0);
        let base = signature(&eng, &path);
        // Duplicate every point.
        let mut dup = Vec::new();
        for j in 0..9 {
            dup.extend_from_slice(&path[j * d..(j + 1) * d]);
            dup.extend_from_slice(&path[j * d..(j + 1) * d]);
        }
        let dup_sig = signature(&eng, &dup);
        assert_allclose(&dup_sig, &base, 1e-13, 1e-12, "duplicated points");
        // Split every segment in half (finer linear interpolation).
        let mut fine = Vec::new();
        for j in 0..8 {
            let p0 = &path[j * d..(j + 1) * d];
            let p1 = &path[(j + 1) * d..(j + 2) * d];
            fine.extend_from_slice(p0);
            for i in 0..d {
                fine.push(0.5 * (p0[i] + p1[i]));
            }
        }
        fine.extend_from_slice(&path[8 * d..]);
        let fine_sig = signature(&eng, &fine);
        assert_allclose(&fine_sig, &base, 1e-12, 1e-11, "refined partition");
    }
}
