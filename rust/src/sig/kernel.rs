//! Signature kernels and random projected-word features (Tóth's
//! kernel-methods workload class; ROADMAP item 4).
//!
//! The signature kernel of two paths under a projection `π_I` is the
//! inner product of their projected signatures:
//!
//! ```text
//! k(x, y) = ⟨π_I(S(x)), π_I(S(y))⟩ = Σ_{w ∈ I} S_w(x) · S_w(y)
//! ```
//!
//! Crucially this needs only the **terminal** signature of each path —
//! no intermediate states, no pairwise path alignment — so a B×B Gram
//! matrix costs `B` forward sweeps plus one dense syrk-style reduction,
//! not `B²` signature computations. The forward sweeps go through the
//! standard batch entry point, which means they inherit the whole
//! engine stack for free: the lane-major SIMD kernel packs lanes of
//! paths, long paths route through the time-parallel tree
//! ([`crate::sig::schedule`]), and per-worker scratch comes from the
//! engine pools so a warm [`gram_into`] performs **zero heap
//! allocations** (asserted by `benches/fig7_kernels.rs`).
//!
//! The reduction itself exploits symmetry: only the upper triangle
//! `j ≥ i` is computed (rows in parallel across the thread pool), then
//! mirrored — half the FLOPs of the rectangular product, and the
//! mirror pass is a pure copy.
//!
//! [`RandomWords`] is the low-rank half of the story: sampling `F`
//! words from a (possibly anisotropic) truncated word set gives an
//! unbiased random feature map `φ(x)` with
//! `E⟨φ(x), φ(y)⟩ = k(x, y)` — the paper's projection machinery used
//! as a Monte-Carlo sampler, so a kernel-ridge fit runs on `(n, F)`
//! features instead of an `(n, n)` Gram matrix. Sampling is
//! deterministic per seed (a `splitmix64`-seeded [`Rng`] stream, the
//! same construction the coordinator uses for shard hashing) and
//! independent of thread count.

use super::forward::signature_batch_into;
use super::SigEngine;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_fill_rows;
use crate::words::{anisotropic_words, sig_dim, Word, WordTable};

/// Pooled scratch for Gram computations: the `(B, |I|)` feature matrix
/// (and a second one for rectangular cross-kernels). Engine-owned via
/// `SigEngine::gram_pool`, so warm calls reuse capacity.
#[derive(Default)]
pub(crate) struct GramScratch {
    feats: Vec<f64>,
    feats_rhs: Vec<f64>,
}

/// The `B×B` signature-kernel Gram matrix `G[i·B+j] = k(x_i, x_j)` of a
/// batch of paths, row-major. `paths` is `(B, M+1, d)` row-major, all
/// paths the same length.
///
/// # Examples
///
/// Two single-segment paths at depth 2 over `d = 2`: one linear segment
/// has `S = exp(ΔX)`, so `S_i = ΔX_i` and `S_{ij} = ΔX_i ΔX_j / 2`.
/// For `x` with `ΔX = (1, 0)` and `y` with `ΔY = (0, 2)`:
/// `k(x,x) = 1 + 1/4`, `k(y,y) = 4 + 4`, and `k(x,y) = 0` (no
/// coordinate is active in both).
///
/// ```
/// use pathsig::sig::{gram, SigEngine};
/// use pathsig::words::{truncated_words, WordTable};
///
/// let eng = SigEngine::new(WordTable::build(2, &truncated_words(2, 2)));
/// let paths = [
///     0.0, 0.0, 1.0, 0.0, // x: (0,0) → (1,0)
///     0.0, 0.0, 0.0, 2.0, // y: (0,0) → (0,2)
/// ];
/// let g = gram(&eng, &paths, 2);
/// assert!((g[0] - 1.25).abs() < 1e-12); // k(x,x)
/// assert!(g[1].abs() < 1e-12);          // k(x,y)
/// assert!((g[2] - g[1]).abs() < 1e-12); // symmetry
/// assert!((g[3] - 8.0).abs() < 1e-12);  // k(y,y)
/// ```
pub fn gram(eng: &SigEngine, paths: &[f64], batch: usize) -> Vec<f64> {
    let mut out = vec![0.0; batch * batch];
    gram_into(eng, paths, batch, &mut out);
    out
}

/// [`gram`] writing into a caller-provided `B×B` buffer. This is the
/// zero-allocation hot path: the feature matrix lives in pooled
/// scratch, the forward sweeps draw engine-pool workspaces, and the
/// syrk reduction writes `out` rows in place.
pub fn gram_into(eng: &SigEngine, paths: &[f64], batch: usize, out: &mut [f64]) {
    assert!(batch > 0, "empty batch");
    assert_eq!(paths.len() % batch, 0, "paths not divisible by batch");
    assert_eq!(out.len(), batch * batch, "output buffer has wrong size");
    let odim = eng.out_dim();
    let mut scratch = eng.gram_pool.take_at_least(1);
    let ws = &mut scratch[0];
    ws.feats.clear();
    ws.feats.resize(batch * odim, 0.0);
    signature_batch_into(eng, paths, batch, &mut ws.feats);
    syrk_mirror(&ws.feats, batch, odim, eng.threads, out);
    eng.gram_pool.put(scratch);
}

/// The rectangular cross-kernel `K[i·By+j] = k(x_i, y_j)` between two
/// batches (e.g. train × test for kernel-ridge prediction). `xs` is
/// `(Bx, Mx+1, d)`, `ys` is `(By, My+1, d)`; the two batches may have
/// different path lengths.
pub fn gram_cross(
    eng: &SigEngine,
    xs: &[f64],
    bx: usize,
    ys: &[f64],
    by: usize,
) -> Vec<f64> {
    let mut out = vec![0.0; bx * by];
    gram_cross_into(eng, xs, bx, ys, by, &mut out);
    out
}

/// [`gram_cross`] writing into a caller-provided `(Bx, By)` buffer,
/// with both feature matrices in pooled scratch.
pub fn gram_cross_into(
    eng: &SigEngine,
    xs: &[f64],
    bx: usize,
    ys: &[f64],
    by: usize,
    out: &mut [f64],
) {
    assert!(bx > 0 && by > 0, "empty batch");
    assert_eq!(xs.len() % bx, 0, "xs not divisible by bx");
    assert_eq!(ys.len() % by, 0, "ys not divisible by by");
    assert_eq!(out.len(), bx * by, "output buffer has wrong size");
    let odim = eng.out_dim();
    let mut scratch = eng.gram_pool.take_at_least(1);
    let ws = &mut scratch[0];
    ws.feats.clear();
    ws.feats.resize(bx * odim, 0.0);
    signature_batch_into(eng, xs, bx, &mut ws.feats);
    ws.feats_rhs.clear();
    ws.feats_rhs.resize(by * odim, 0.0);
    signature_batch_into(eng, ys, by, &mut ws.feats_rhs);
    let (fx, fy) = (&ws.feats, &ws.feats_rhs);
    parallel_fill_rows(out, by, eng.threads, |i, row| {
        let xi = &fx[i * odim..(i + 1) * odim];
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = dot(xi, &fy[j * odim..(j + 1) * odim]);
        }
    });
    eng.gram_pool.put(scratch);
}

/// Upper-triangle syrk + mirror: `out[i][j] = ⟨feats_i, feats_j⟩` for
/// `j ≥ i` computed row-parallel, then the strict lower triangle is
/// copied from the upper. Exactly symmetric by construction (the `j<i`
/// entries are the same floats, not re-derived sums).
fn syrk_mirror(feats: &[f64], b: usize, k: usize, threads: usize, out: &mut [f64]) {
    parallel_fill_rows(out, b, threads, |i, row| {
        let fi = &feats[i * k..(i + 1) * k];
        for j in i..b {
            row[j] = dot(fi, &feats[j * k..(j + 1) * k]);
        }
    });
    for i in 1..b {
        for j in 0..i {
            out[i * b + j] = out[j * b + i];
        }
    }
}

/// Dense dot product; fixed-stride slices so rustc autovectorizes.
#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// A seeded random projected-word feature map: `F` words sampled
/// uniformly **with replacement** from a truncated (or anisotropic)
/// word set `W`, scaled by `√(|W|/F)` so the feature inner product is
/// an unbiased Monte-Carlo estimate of the exact signature kernel over
/// `W`:
///
/// ```text
/// φ(x) = √(|W|/F) · (S_{w_1}(x), …, S_{w_F}(x)),   w_i ~ U(W)
/// E⟨φ(x), φ(y)⟩ = Σ_{w ∈ W} S_w(x) S_w(y) = k(x, y)
/// ```
///
/// Duplicates are kept (that is what makes the estimator unbiased);
/// the engine computes only the prefix closure of the sampled set, so
/// `F ≪ |W|` features cost a fraction of the exact kernel's sweep.
/// Sampling is a pure function of the seed — same seed, same words,
/// regardless of thread count or platform.
///
/// # Examples
///
/// ```
/// use pathsig::sig::RandomWords;
///
/// let a = RandomWords::truncated(3, 4, 16, 42);
/// let b = RandomWords::truncated(3, 4, 16, 42);
/// assert_eq!(a.words, b.words); // seeded: deterministic
/// assert_eq!(a.words.len(), 16);
/// let c = RandomWords::truncated(3, 4, 16, 43);
/// assert_ne!(a.words, c.words); // different stream
/// ```
#[derive(Clone, Debug)]
pub struct RandomWords {
    /// Alphabet size `d`.
    pub d: usize,
    /// The sampled words, in draw order (duplicates kept).
    pub words: Vec<Word>,
    /// `√(|W|/F)` — multiply raw signature coordinates by this to make
    /// `⟨φ(x), φ(y)⟩` unbiased for the exact kernel over `W`.
    pub scale: f64,
}

impl RandomWords {
    /// Sample `features` words uniformly from the full truncated set
    /// `W_{≤depth}` over alphabet size `d`, seeded.
    ///
    /// Words are drawn by index into the canonical (level, lex) order
    /// and decoded arithmetically — the set (size `Σ d^n`, the paper's
    /// `D_sig`) is never materialised.
    pub fn truncated(d: usize, depth: usize, features: usize, seed: u64) -> RandomWords {
        assert!(d >= 1 && depth >= 1 && features >= 1);
        let total = sig_dim(d, depth);
        let mut rng = Rng::new(seed);
        let words = (0..features)
            .map(|_| decode_truncated_index(d, depth, rng.below(total)))
            .collect();
        RandomWords {
            d,
            words,
            scale: (total as f64 / features as f64).sqrt(),
        }
    }

    /// Sample `features` words uniformly from the anisotropic set
    /// `W^γ_{≤cutoff}` (Definition 7.1), seeded. The set is
    /// materialised once to index into it.
    pub fn anisotropic(
        d: usize,
        gamma: &[f64],
        cutoff: f64,
        features: usize,
        seed: u64,
    ) -> RandomWords {
        assert!(features >= 1);
        let pool = anisotropic_words(d, gamma, cutoff);
        assert!(!pool.is_empty(), "anisotropic cutoff admits no words");
        let mut rng = Rng::new(seed);
        let words = (0..features)
            .map(|_| pool[rng.below(pool.len())].clone())
            .collect();
        RandomWords {
            d,
            words,
            scale: (pool.len() as f64 / features as f64).sqrt(),
        }
    }

    /// Number of features `F`.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// Build the projected engine computing exactly the sampled
    /// coordinates (their prefix closure, per the paper's §7.1
    /// projection machinery).
    pub fn engine(&self) -> SigEngine {
        SigEngine::new(WordTable::build(self.d, &self.words))
    }

    /// The scaled feature matrix `φ` of a batch: `(B, F)` row-major.
    /// `eng` must come from [`RandomWords::engine`] (or share its word
    /// order).
    pub fn features(&self, eng: &SigEngine, paths: &[f64], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0; batch * self.words.len()];
        self.features_into(eng, paths, batch, &mut out);
        out
    }

    /// [`RandomWords::features`] writing into a caller-provided buffer
    /// — one batched forward sweep plus an in-place scale.
    pub fn features_into(&self, eng: &SigEngine, paths: &[f64], batch: usize, out: &mut [f64]) {
        assert_eq!(
            eng.out_dim(),
            self.words.len(),
            "engine word set does not match the sampled features"
        );
        signature_batch_into(eng, paths, batch, out);
        for v in out.iter_mut() {
            *v *= self.scale;
        }
    }
}

/// Decode index `idx` (0-based over the (level, lex) order of
/// `W_{≤depth} \ {ε}`) into its word: peel level sizes `d^n` off, then
/// read the remainder as `n` base-`d` digits, most significant first.
fn decode_truncated_index(d: usize, depth: usize, mut idx: usize) -> Word {
    for n in 1..=depth {
        let level = d.pow(n as u32);
        if idx < level {
            let mut letters = vec![0u16; n];
            for slot in letters.iter_mut().rev() {
                *slot = (idx % d) as u16;
                idx /= d;
            }
            return Word(letters);
        }
        idx -= level;
    }
    unreachable!("index out of range for W_{{<={depth}}}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::signature;
    use crate::util::proptest::assert_allclose;
    use crate::words::truncated_words;

    fn trunc_engine(d: usize, n: usize) -> SigEngine {
        SigEngine::new(WordTable::build(d, &truncated_words(d, n)))
    }

    fn rand_paths(rng: &mut Rng, b: usize, m: usize, d: usize) -> Vec<f64> {
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 0.4));
        }
        paths
    }

    /// Naive pairwise baseline: one `signature()` per path, dot per pair.
    fn naive_gram(eng: &SigEngine, paths: &[f64], b: usize) -> Vec<f64> {
        let per = paths.len() / b;
        let sigs: Vec<Vec<f64>> = (0..b)
            .map(|i| signature(eng, &paths[i * per..(i + 1) * per]))
            .collect();
        let mut g = vec![0.0; b * b];
        for i in 0..b {
            for j in 0..b {
                g[i * b + j] = dot(&sigs[i], &sigs[j]);
            }
        }
        g
    }

    #[test]
    fn gram_matches_naive_pairwise() {
        let mut rng = Rng::new(800);
        let eng = trunc_engine(3, 3);
        for &b in &[1usize, 2, 7, 19] {
            let paths = rand_paths(&mut rng, b, 12, 3);
            let got = gram(&eng, &paths, b);
            let want = naive_gram(&eng, &paths, b);
            assert_allclose(&got, &want, 1e-12, 1e-12, &format!("gram b={b}"));
        }
    }

    #[test]
    fn gram_is_symmetric_bitwise() {
        let mut rng = Rng::new(801);
        let eng = trunc_engine(2, 4);
        let b = 11;
        let paths = rand_paths(&mut rng, b, 20, 2);
        let g = gram(&eng, &paths, b);
        for i in 0..b {
            for j in 0..b {
                assert_eq!(g[i * b + j].to_bits(), g[j * b + i].to_bits());
            }
        }
    }

    #[test]
    fn gram_into_reuses_buffer() {
        let mut rng = Rng::new(802);
        let eng = trunc_engine(2, 3);
        let b = 5;
        let paths = rand_paths(&mut rng, b, 9, 2);
        let mut out = vec![f64::NAN; b * b];
        gram_into(&eng, &paths, b, &mut out);
        let want = gram(&eng, &paths, b);
        assert_allclose(&out, &want, 0.0, 0.0, "into == owning");
        gram_into(&eng, &paths, b, &mut out);
        assert_allclose(&out, &want, 0.0, 0.0, "second call");
    }

    #[test]
    fn cross_kernel_matches_square_blocks() {
        // gram_cross(xs, ys) must equal the off-diagonal block of the
        // big Gram over the concatenated batch (same path length).
        let mut rng = Rng::new(803);
        let eng = trunc_engine(2, 3);
        let (bx, by, m) = (4usize, 6usize, 10usize);
        let xs = rand_paths(&mut rng, bx, m, 2);
        let ys = rand_paths(&mut rng, by, m, 2);
        let cross = gram_cross(&eng, &xs, bx, &ys, by);
        let mut all = xs.clone();
        all.extend_from_slice(&ys);
        let big = gram(&eng, &all, bx + by);
        for i in 0..bx {
            for j in 0..by {
                let want = big[i * (bx + by) + (bx + j)];
                assert!((cross[i * by + j] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cross_kernel_allows_different_lengths() {
        let mut rng = Rng::new(804);
        let eng = trunc_engine(2, 2);
        let xs = rand_paths(&mut rng, 3, 8, 2);
        let ys = rand_paths(&mut rng, 2, 15, 2);
        let cross = gram_cross(&eng, &xs, 3, &ys, 2);
        // Spot check one entry against single-path signatures.
        let sx = signature(&eng, &xs[0..9 * 2]);
        let sy = signature(&eng, &ys[16 * 2..]);
        assert!((cross[1] - dot(&sx, &sy)).abs() < 1e-12);
    }

    #[test]
    fn random_words_land_in_the_truncated_set() {
        let (d, depth) = (3usize, 4usize);
        let rw = RandomWords::truncated(d, depth, 64, 7);
        let all = truncated_words(d, depth);
        for w in &rw.words {
            assert!(w.len() >= 1 && w.len() <= depth);
            assert!(w.0.iter().all(|&l| (l as usize) < d));
            assert!(all.contains(w));
        }
        let expect = (sig_dim(d, depth) as f64 / 64.0).sqrt();
        assert!((rw.scale - expect).abs() < 1e-15);
    }

    #[test]
    fn decode_covers_the_canonical_order() {
        // Index k must decode to truncated_words[k] for every k.
        let (d, depth) = (2usize, 3usize);
        let all = truncated_words(d, depth);
        for (k, w) in all.iter().enumerate() {
            assert_eq!(&decode_truncated_index(d, depth, k), w, "index {k}");
        }
    }

    #[test]
    fn anisotropic_sampler_respects_cutoff() {
        let gamma = [1.0, 2.0];
        let rw = RandomWords::anisotropic(2, &gamma, 3.0, 32, 5);
        for w in &rw.words {
            assert!(w.weighted_degree(&gamma) <= 3.0 + 1e-12);
        }
        // Deterministic across calls.
        let again = RandomWords::anisotropic(2, &gamma, 3.0, 32, 5);
        assert_eq!(rw.words, again.words);
    }

    #[test]
    fn feature_inner_products_approach_the_exact_kernel() {
        // Monte-Carlo error must shrink as F grows (averaged over seeds).
        let mut rng = Rng::new(806);
        let (d, depth) = (2usize, 3usize);
        let exact_eng = trunc_engine(d, depth);
        let paths = rand_paths(&mut rng, 6, 10, d);
        let exact = gram(&exact_eng, &paths, 6);
        let err_at = |features: usize| -> f64 {
            let mut total = 0.0;
            for seed in 0..8u64 {
                let rw = RandomWords::truncated(d, depth, features, 900 + seed);
                let feng = rw.engine();
                let phi = rw.features(&feng, &paths, 6);
                let mut err: f64 = 0.0;
                for i in 0..6 {
                    for j in 0..6 {
                        let approx = dot(
                            &phi[i * features..(i + 1) * features],
                            &phi[j * features..(j + 1) * features],
                        );
                        err = err.max((approx - exact[i * 6 + j]).abs());
                    }
                }
                total += err;
            }
            total / 8.0
        };
        let coarse = err_at(4);
        let fine = err_at(64);
        assert!(
            fine < coarse,
            "random-feature error must decrease in F: F=4 → {coarse}, F=64 → {fine}"
        );
    }
}
