//! Lane-major batched Chen kernel — the CPU analogue of the paper's
//! one-CUDA-thread-per-word mapping (§3.2).
//!
//! A block of `L` paths ("lanes", `L ∈ {4, 8, 16, 32}`) is transposed
//! into a state matrix `lane_state[word][lane]` with the **lane axis
//! contiguous** (structure-of-arrays). The Chen/Horner recursion then
//! runs once over the word table per step, and its innermost loop is a
//! straight-line multiply–add sweep over the `L` lanes of each word —
//! a fixed-trip-count loop over a contiguous `[f64; L]` that rustc
//! auto-vectorizes. Two wins over the scalar per-path kernel:
//!
//! * the word-table metadata (CSR letters/prefix rows, loop control)
//!   is read once per `L` paths instead of once per path;
//! * every load/store in the inner loop is a full contiguous vector,
//!   so the FLOPs actually issue as SIMD.
//!
//! Arithmetic is performed in exactly the same order per lane as the
//! scalar kernel, so results are bitwise identical to
//! [`crate::sig::signature`] — the scalar kernel stays as the `B < L`
//! fallback and as the differential-testing oracle
//! (`signature_batch_scalar`).
//!
//! The **backward pass** (§4) is vectorized the same way: the cotangent
//! state `λ[word][lane]` and the reconstructed signature share the SoA
//! layout, the group-inverse reconstruction `S_{0,t_{j-1}} = S_{0,t_j}
//! ⊗ exp(-ΔX_j)` is one [`chen_update_lanes`] call on negated
//! increments, and [`backward_step_lanes`] runs the transposed
//! Chen/Horner cotangent sweep plus the ΔX-gradient Horner sweep with
//! the lane axis innermost — the CSR word walk is again read once per
//! `L` paths. See `sig::backward` for the block driver.

use super::SigEngine;

/// Default lane width: 8 f64 lanes = one AVX-512 register or two
/// AVX2/NEON registers — wide enough to amortize the table walk,
/// small enough that `state_len · L` stays cache-resident.
pub const DEFAULT_LANE_WIDTH: usize = 8;

/// Reusable scratch buffers for forward-pass kernels. One workspace per
/// worker thread; engines cache them in a [`crate::util::pool::Pool`]
/// so steady-state batch calls allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct ForwardWorkspace {
    /// Scalar closure state (`state_len`), for the `B < L` fallback and
    /// single-path entry points.
    pub(crate) state: Vec<f64>,
    /// Scalar step increment (`d`).
    pub(crate) dx: Vec<f64>,
    /// Lane-major state matrix, `state_len × L` with lanes contiguous.
    pub(crate) lane_state: Vec<f64>,
    /// Lane-major step increments, `d × L` with lanes contiguous.
    pub(crate) dx_lanes: Vec<f64>,
}

impl ForwardWorkspace {
    /// Size the lane-major buffers for `eng` (idempotent; steady state
    /// performs no allocation *and no writes* — the kernels fully
    /// re-initialize both buffers before reading, so a bare `resize`
    /// suffices). The scalar buffers are sized by the scalar kernels
    /// themselves, so purely scalar paths never pay for the `×L` lane
    /// matrix.
    pub(crate) fn ensure_lanes(&mut self, eng: &SigEngine) {
        let l = eng.lanes();
        self.lane_state.resize(eng.table.state_len * l, 0.0);
        self.dx_lanes.resize(eng.table.d * l, 0.0);
    }
}

/// One lane-major Chen/Horner update `S_l ← S_l ⊗ exp(dx_l)` for all
/// `L` lanes at once. `lane_state` is `state_len × L` (lane
/// contiguous, `lane_state[0..L] == 1`), `dx_lanes` is `d × L`.
/// Levels are processed top-down so the update is in place, exactly as
/// in the scalar [`crate::sig::chen_update`].
pub fn chen_update_lanes<const L: usize>(
    eng: &SigEngine,
    lane_state: &mut [f64],
    dx_lanes: &[f64],
) {
    let t = &eng.table;
    // Hard asserts, not debug: the kernel below does unchecked reads
    // and writes at multiples of L, so these size contracts are what
    // keeps it a *safe* public function in release builds.
    assert_eq!(lane_state.len(), t.state_len * L, "lane_state must be state_len × L");
    assert_eq!(dx_lanes.len(), t.d * L, "dx_lanes must be d × L");
    let dx_ptr = dx_lanes.as_ptr();
    for n in (1..=t.max_level).rev() {
        let range = t.level_range(n);
        let level_base = t.level_csr_base(n);
        for (off, i) in range.enumerate() {
            let base = level_base + off * n;
            // SAFETY: indices come from the validated WordTable
            // (letters < d, prefix indices < state_len, CSR rows in
            // bounds; see `WordTable::check_invariants`), and every
            // `[f64; L]` view starts at a multiple-of-L offset inside
            // a buffer of length (state_len|d)·L, so it is in bounds.
            // The shared view of a prefix row and the mutable view of
            // row `i` never alias: prefixes are strictly shorter words
            // (level < n), while `i` is a level-`n` word.
            unsafe {
                let letters = t.csr_letters.get_unchecked(base..base + n);
                let prefixes = t.csr_prefix.get_unchecked(base..base + n);
                let mut acc = [1.0f64; L]; // S(ε) broadcast across lanes.
                for k in 1..n {
                    let letter = *letters.get_unchecked(k - 1) as usize;
                    let r = *eng.recip.get_unchecked(n - k + 1);
                    let dxl = &*(dx_ptr.add(letter * L) as *const [f64; L]);
                    let pref = *prefixes.get_unchecked(k) as usize;
                    let s = &*(lane_state.as_ptr().add(pref * L) as *const [f64; L]);
                    for l in 0..L {
                        acc[l] = acc[l] * dxl[l] * r + s[l];
                    }
                }
                let last = *letters.get_unchecked(n - 1) as usize;
                let dxl = &*(dx_ptr.add(last * L) as *const [f64; L]);
                let st = &mut *(lane_state.as_mut_ptr().add(i * L) as *mut [f64; L]);
                for l in 0..L {
                    st[l] += acc[l] * dxl[l];
                }
            }
        }
    }
}

/// One lane-major backward step: given the reconstructed state
/// `S_{j-1}` (`lane_state`, `state_len × L`), the step increments
/// (`dx_lanes`, `d × L`) and the incoming cotangents `λ_j`
/// (`lane_lambda`, `state_len × L`), update `λ` in place to `λ_{j-1}`
/// and accumulate this step's increment gradient into `gdx_lanes`
/// (`d × L`, caller-zeroed). `right_prod` is `(max_level+1) × L`
/// scratch for the right suffix products.
///
/// Per lane this performs exactly the scalar fused sweep of
/// `sig_backward_into` (same word order, same operation order per
/// accumulator), so results match the scalar kernel bitwise; lanes
/// whose `λ` is identically zero contribute exact zeros. Levels are
/// processed in ASCENDING order: the transpose sends contributions
/// strictly from a word to its shorter prefixes, so every `λ(w)` is
/// read before anything lands on it — the in-place mirror of the
/// forward's descending trick.
pub fn backward_step_lanes<const L: usize>(
    eng: &SigEngine,
    lane_state: &[f64],
    lane_lambda: &mut [f64],
    dx_lanes: &[f64],
    right_prod: &mut [f64],
    gdx_lanes: &mut [f64],
) {
    let t = &eng.table;
    // Hard asserts, not debug: the kernel below does unchecked reads
    // and writes at multiples of L (see `chen_update_lanes`).
    assert_eq!(lane_state.len(), t.state_len * L, "lane_state must be state_len × L");
    assert_eq!(lane_lambda.len(), t.state_len * L, "lane_lambda must be state_len × L");
    assert_eq!(dx_lanes.len(), t.d * L, "dx_lanes must be d × L");
    assert!(right_prod.len() >= (t.max_level + 1) * L, "right_prod too small");
    assert_eq!(gdx_lanes.len(), t.d * L, "gdx_lanes must be d × L");
    let dx_ptr = dx_lanes.as_ptr();
    let st_ptr = lane_state.as_ptr();
    let lam_ptr = lane_lambda.as_mut_ptr();
    let rp_ptr = right_prod.as_mut_ptr();
    for n in 1..=t.max_level {
        let inv_fact_n = eng.inv_fact[n];
        let level_base = t.level_csr_base(n);
        for (off, w) in t.level_range(n).enumerate() {
            // SAFETY: indices come from the validated WordTable
            // (letters < d, prefix indices < state_len, CSR rows in
            // bounds), and every `[f64; L]` view starts at a
            // multiple-of-L offset inside a buffer of length
            // (state_len|d|max_level+1)·L asserted above. `lam_v` is a
            // copy, and the `&mut` prefix-row views into `lane_lambda`
            // target strictly shorter words (level < n), never row `w`.
            unsafe {
                let lam_v = *(lam_ptr.add(w * L) as *const [f64; L]);
                if lam_v.iter().all(|&x| x == 0.0) {
                    continue;
                }
                let base = level_base + off * n;
                let letters = t.csr_letters.get_unchecked(base..base + n);
                let prefixes = t.csr_prefix.get_unchecked(base..base + n);
                // Right suffix products R_p = Π_{q=p+1..n} dx_{i_q}.
                *(rp_ptr.add(n * L) as *mut [f64; L]) = [1.0; L];
                for p in (1..n).rev() {
                    let letter = *letters.get_unchecked(p) as usize; // i_{p+1}
                    let dxl = &*(dx_ptr.add(letter * L) as *const [f64; L]);
                    let hi = *(rp_ptr.add((p + 1) * L) as *const [f64; L]);
                    let lo = &mut *(rp_ptr.add(p * L) as *mut [f64; L]);
                    for l in 0..L {
                        lo[l] = hi[l] * dxl[l];
                    }
                }
                // Fused sweep over positions p = 1..=n (per lane, the
                // exact scalar recurrence — see `sig_backward_into`):
                //   gdx[i_p]    += λ·A_p·R_p       (A_1 = 1/n!)
                //   λ(w_[p-1])  += λ·dx_{i_p}·R_p/(n-p+1)!
                //   A_{p+1}      = A_p·dx_{i_p} + S(w_[p])/(n-p)!
                let mut a = [inv_fact_n; L];
                for p in 1..=n {
                    let letter = *letters.get_unchecked(p - 1) as usize; // i_p
                    let dxl = &*(dx_ptr.add(letter * L) as *const [f64; L]);
                    let rp = &*(rp_ptr.add(p * L) as *const [f64; L]);
                    let inv1 = *eng.inv_fact.get_unchecked(n - p + 1);
                    let g = &mut *(gdx_lanes.as_mut_ptr().add(letter * L) as *mut [f64; L]);
                    let pref_lam = &mut *(lam_ptr
                        .add(*prefixes.get_unchecked(p - 1) as usize * L)
                        as *mut [f64; L]);
                    for l in 0..L {
                        g[l] += lam_v[l] * a[l] * rp[l];
                        pref_lam[l] += lam_v[l] * (dxl[l] * rp[l] * inv1);
                    }
                    if p < n {
                        let s = &*(st_ptr.add(*prefixes.get_unchecked(p) as usize * L)
                            as *const [f64; L]);
                        let inv2 = *eng.inv_fact.get_unchecked(n - p);
                        for l in 0..L {
                            a[l] = a[l] * dxl[l] + s[l] * inv2;
                        }
                    }
                }
            }
        }
    }
}

/// Forward-sweep a block of `nb ≤ L` paths over steps
/// `jl+1 ..= jr` (the `[jl, jr]` index window; the full path is
/// `jl = 0, jr = M`), leaving the result in `ws.lane_state`. Inactive
/// lanes (`nb < L`) carry zero increments and stay at the trivial
/// signature. `block` holds the `nb` paths back to back, `per_path`
/// values each, row-major `(M+1, d)`.
pub(crate) fn lane_forward<const L: usize>(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    jl: usize,
    jr: usize,
    ws: &mut ForwardWorkspace,
) {
    let d = eng.table.d;
    let sl = eng.table.state_len;
    debug_assert!(nb >= 1 && nb <= L);
    debug_assert_eq!(block.len(), nb * per_path);
    debug_assert!(ws.lane_state.len() >= sl * L && ws.dx_lanes.len() >= d * L);
    let lane_state = &mut ws.lane_state[..sl * L];
    let dx_lanes = &mut ws.dx_lanes[..d * L];
    lane_state.fill(0.0);
    lane_state[..L].fill(1.0); // ε row.
    dx_lanes.fill(0.0); // inactive lanes keep Δx = 0 throughout.
    for j in (jl + 1)..=jr {
        // Transpose this step's increments into lane-major layout.
        for (l, p) in block.chunks_exact(per_path).enumerate() {
            for i in 0..d {
                dx_lanes[i * L + l] = p[j * d + i] - p[(j - 1) * d + i];
            }
        }
        chen_update_lanes::<L>(eng, lane_state, dx_lanes);
    }
}

/// Dispatch a generic-over-`L` kernel on the runtime lane width —
/// the ONE place the supported width set `{4, 8, 16, 32}` is spelled
/// out for monomorphization. These are the only values
/// [`SigEngine::lanes`] can return; workspace buffers are strided by
/// the lane width, so running a kernel at any other width would
/// corrupt silently — fail loudly if the lane domain ever grows
/// without updating this match.
macro_rules! lane_dispatch {
    ($lanes:expr, $func:ident($($args:expr),* $(,)?)) => {
        match $lanes {
            4 => $func::<4>($($args),*),
            8 => $func::<8>($($args),*),
            16 => $func::<16>($($args),*),
            32 => $func::<32>($($args),*),
            other => unreachable!("unsupported lane width {other}"),
        }
    };
}
pub(crate) use lane_dispatch;

/// Monomorphization dispatch for [`lane_forward`] on the engine's lane
/// width.
pub(crate) fn lane_forward_dispatch(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    jl: usize,
    jr: usize,
    ws: &mut ForwardWorkspace,
) {
    lane_dispatch!(eng.lanes(), lane_forward(eng, block, nb, per_path, jl, jr, ws));
}

/// Project lane `l` of a lane-major state matrix onto the requested
/// coordinates (`row.len() == |I|`). `lw` is the runtime lane width the
/// matrix was built with.
pub(crate) fn project_lane(
    eng: &SigEngine,
    lane_state: &[f64],
    lw: usize,
    l: usize,
    row: &mut [f64],
) {
    debug_assert!(l < lw);
    for (o, &idx) in row.iter_mut().zip(&eng.table.output_map) {
        *o = lane_state[idx as usize * lw + l];
    }
}

/// Project the first `nb` lanes into `nb` consecutive output rows
/// (`out.len() == nb · |I|`) — the de-transpose at the end of a block.
pub(crate) fn project_block(
    eng: &SigEngine,
    lane_state: &[f64],
    lw: usize,
    nb: usize,
    out: &mut [f64],
) {
    let odim = eng.out_dim();
    debug_assert_eq!(out.len(), nb * odim);
    for (l, row) in out.chunks_exact_mut(odim).enumerate() {
        project_lane(eng, lane_state, lw, l, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature, SigEngine};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, Word, WordTable};

    fn lane_rows(eng: &SigEngine, paths: &[f64], nb: usize, per_path: usize) -> Vec<f64> {
        let mut ws = ForwardWorkspace::default();
        ws.ensure_lanes(eng);
        let m1 = per_path / eng.table.d;
        lane_forward_dispatch(eng, paths, nb, per_path, 0, m1 - 1, &mut ws);
        let mut out = vec![0.0; nb * eng.out_dim()];
        project_block(eng, &ws.lane_state, eng.lanes(), nb, &mut out);
        out
    }

    #[test]
    fn full_block_matches_scalar_bitwise() {
        let mut rng = Rng::new(900);
        let eng = SigEngine::sequential(WordTable::build(3, &truncated_words(3, 4)));
        let lw = eng.lanes();
        let m = 7;
        let per = (m + 1) * 3;
        let mut paths = Vec::new();
        for _ in 0..lw {
            paths.extend(rng.brownian_path(m, 3, 0.6));
        }
        let rows = lane_rows(&eng, &paths, lw, per);
        for l in 0..lw {
            let single = signature(&eng, &paths[l * per..(l + 1) * per]);
            // Same arithmetic order per lane ⇒ bitwise identical.
            assert_eq!(&rows[l * eng.out_dim()..(l + 1) * eng.out_dim()], &single[..]);
        }
    }

    #[test]
    fn partial_block_padded_lanes_are_inert() {
        let mut rng = Rng::new(901);
        let eng = SigEngine::sequential(WordTable::build(2, &truncated_words(2, 3)));
        let m = 5;
        let per = (m + 1) * 2;
        let nb = 3; // < lane width
        let mut paths = Vec::new();
        for _ in 0..nb {
            paths.extend(rng.brownian_path(m, 2, 1.0));
        }
        let rows = lane_rows(&eng, &paths, nb, per);
        for l in 0..nb {
            let single = signature(&eng, &paths[l * per..(l + 1) * per]);
            assert_allclose(
                &rows[l * eng.out_dim()..(l + 1) * eng.out_dim()],
                &single,
                0.0,
                0.0,
                "padded block row",
            );
        }
    }

    #[test]
    fn projected_word_set_lanes() {
        // Lane kernel over a sparse projected table (uneven word
        // lengths exercise the CSR level bases).
        let mut rng = Rng::new(902);
        let request = vec![Word(vec![1, 0, 2]), Word(vec![2]), Word(vec![0, 0, 1, 1])];
        let eng = SigEngine::sequential(WordTable::build(3, &request));
        let m = 9;
        let per = (m + 1) * 3;
        let nb = 5;
        let mut paths = Vec::new();
        for _ in 0..nb {
            paths.extend(rng.brownian_path(m, 3, 0.4));
        }
        let rows = lane_rows(&eng, &paths, nb, per);
        for l in 0..nb {
            let single = signature(&eng, &paths[l * per..(l + 1) * per]);
            assert_allclose(
                &rows[l * eng.out_dim()..(l + 1) * eng.out_dim()],
                &single,
                0.0,
                0.0,
                "projected row",
            );
        }
    }
}
