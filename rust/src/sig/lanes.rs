//! Lane-major batched Chen kernel — the CPU analogue of the paper's
//! one-CUDA-thread-per-word mapping (§3.2), with **explicit SIMD**.
//!
//! A block of `L` paths ("lanes", `L ∈ {4, 8, 16, 32}` at f64) is
//! transposed into a state matrix `lane_state[word][lane]` with the
//! **lane axis contiguous** (structure-of-arrays). The Chen/Horner
//! recursion then runs once over the word table per step, and its
//! innermost loop sweeps the `L` lanes of each word in register-width
//! chunks of explicit `core::arch` vectors — AVX2, AVX-512 (feature
//! `avx512`) or NEON, chosen at runtime per engine (the `sig::simd`
//! module, `PATHSIG_SIMD`), with the original portable `[f64; L]` loop as the
//! scalar fallback *and* the bitwise oracle. Three wins over the
//! scalar per-path kernel:
//!
//! * the word-table metadata (CSR letters/prefix rows, loop control)
//!   is read once per `L` paths instead of once per path;
//! * every load/store in the inner loop is a full contiguous vector,
//!   and with explicit intrinsics the FLOPs issue as SIMD regardless
//!   of what the autovectorizer decides;
//! * [`Precision::F32`](super::Precision) runs the same kernel bodies
//!   over `f32` at double the lane count (`2L` paths per block) for
//!   inference-grade workloads.
//!
//! Arithmetic is performed in exactly the same order per lane as the
//! scalar kernel on **every** ISA path — the vector chunks regroup
//! lanes, never reassociate within one, and the internal `Vector`
//! trait deliberately has no FMA — so results are bitwise identical to
//! [`crate::sig::signature`] under any `PATHSIG_SIMD` setting. The
//! scalar kernel stays as the `B < L` fallback and as the
//! differential-testing oracle (`signature_batch_scalar`).
//!
//! The **backward pass** (§4) is vectorized the same way: the cotangent
//! state `λ[word][lane]` and the reconstructed signature share the SoA
//! layout, the group-inverse reconstruction `S_{0,t_{j-1}} = S_{0,t_j}
//! ⊗ exp(-ΔX_j)` is one [`chen_update_lanes`] call on negated
//! increments, and [`backward_step_lanes`] runs the transposed
//! Chen/Horner cotangent sweep plus the ΔX-gradient Horner sweep with
//! the lane axis innermost — the CSR word walk is again read once per
//! `L` paths. See `sig::backward` for the block driver. The backward
//! pass is f64-only: training keeps full precision (see DESIGN.md
//! "Explicit SIMD & precision modes").

use super::simd::{Elem, Isa, Scalar1, Vector};
use super::SigEngine;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
use super::simd::{F32x16, F64x8};
#[cfg(target_arch = "x86_64")]
use super::simd::{F32x8, F64x4};
#[cfg(target_arch = "aarch64")]
use super::simd::{F32x4, F64x2};

/// Default lane width: 8 f64 lanes = one AVX-512 register or two
/// AVX2/NEON registers — wide enough to amortize the table walk,
/// small enough that `state_len · L` stays cache-resident.
pub const DEFAULT_LANE_WIDTH: usize = 8;

/// Reusable scratch buffers for forward-pass kernels. One workspace per
/// worker thread; engines cache them in a [`crate::util::pool::Pool`]
/// so steady-state batch calls allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct ForwardWorkspace {
    /// Scalar closure state (`state_len`), for the `B < L` fallback and
    /// single-path entry points.
    pub(crate) state: Vec<f64>,
    /// Scalar step increment (`d`).
    pub(crate) dx: Vec<f64>,
    /// Lane-major state matrix, `state_len × L` with lanes contiguous.
    pub(crate) lane_state: Vec<f64>,
    /// Lane-major step increments, `d × L` with lanes contiguous.
    pub(crate) dx_lanes: Vec<f64>,
    /// f32 lane-major state matrix, `state_len × 2L` — only sized when
    /// the engine runs [`Precision::F32`](super::Precision).
    pub(crate) lane_state_f32: Vec<f32>,
    /// f32 lane-major step increments, `d × 2L`.
    pub(crate) dx_lanes_f32: Vec<f32>,
}

impl ForwardWorkspace {
    /// Size the lane-major buffers for `eng` (idempotent; steady state
    /// performs no allocation *and no writes* — the kernels fully
    /// re-initialize both buffers before reading, so a bare `resize`
    /// suffices). The scalar buffers are sized by the scalar kernels
    /// themselves, so purely scalar paths never pay for the `×L` lane
    /// matrix.
    pub(crate) fn ensure_lanes(&mut self, eng: &SigEngine) {
        let l = eng.lanes();
        self.lane_state.resize(eng.table.state_len * l, 0.0);
        self.dx_lanes.resize(eng.table.d * l, 0.0);
    }

    /// [`ForwardWorkspace::ensure_lanes`] for the f32 inference path
    /// (`2L` lanes per block); the f64 buffers stay untouched so a
    /// workspace can serve either precision.
    pub(crate) fn ensure_lanes_f32(&mut self, eng: &SigEngine) {
        let l = eng.lanes_f32();
        self.lane_state_f32.resize(eng.table.state_len * l, 0.0);
        self.dx_lanes_f32.resize(eng.table.d * l, 0.0);
    }
}

// ---------------------------------------------------------------------
// Generic kernel bodies.
//
// One body per kernel, generic over the register type `V` and the lane
// width `L` (`L % V::WIDTH == 0`). The lane loop of the original
// portable kernel becomes a chunk loop of `L / V::WIDTH` register
// sweeps; **within** a lane the operation order is exactly the
// original's, which is the whole bitwise ISA ≡ scalar contract. Bodies
// are `#[inline(always)]` so that, called from a `#[target_feature]`
// wrapper below, they compile *inside* the enabled-feature region.
// ---------------------------------------------------------------------

/// One lane-major Chen/Horner update over raw SoA buffers.
///
/// # Safety
/// `lane_state` must point at `state_len × L` elements, `dx_lanes` at
/// `d × L`, and when `V` is a `core::arch` type the caller must be a
/// region where that ISA is enabled and runtime-available.
#[inline(always)]
unsafe fn chen_body<V: Vector, const L: usize>(
    eng: &SigEngine,
    lane_state: *mut V::E,
    dx_lanes: *const V::E,
) {
    debug_assert_eq!(L % V::WIDTH, 0);
    let t = &eng.table;
    for n in (1..=t.max_level).rev() {
        let range = t.level_range(n);
        let level_base = t.level_csr_base(n);
        for (off, i) in range.enumerate() {
            let base = level_base + off * n;
            // Indices come from the validated WordTable (letters < d,
            // prefix indices < state_len, CSR rows in bounds; see
            // `WordTable::check_invariants`), and every vector chunk
            // starts at `row · L + c` with `c + WIDTH ≤ L`, so loads
            // and stores stay inside the caller-asserted buffers. A
            // prefix row (level < n) never aliases the written row `i`
            // (level n).
            let letters = t.csr_letters.get_unchecked(base..base + n);
            let prefixes = t.csr_prefix.get_unchecked(base..base + n);
            let mut c = 0;
            while c < L {
                let mut acc = V::splat(<V::E as Elem>::ONE); // S(ε).
                for k in 1..n {
                    let letter = *letters.get_unchecked(k - 1) as usize;
                    let r = V::splat(<V::E as Elem>::from_f64(
                        *eng.recip.get_unchecked(n - k + 1),
                    ));
                    let dxl = V::load(dx_lanes.add(letter * L + c));
                    let pref = *prefixes.get_unchecked(k) as usize;
                    let s = V::load(lane_state.add(pref * L + c));
                    // acc = acc·dx·r + s, left-associated as in the
                    // scalar kernel.
                    acc = acc.mul(dxl).mul(r).add(s);
                }
                let last = *letters.get_unchecked(n - 1) as usize;
                let dxl = V::load(dx_lanes.add(last * L + c));
                let st = lane_state.add(i * L + c);
                V::load(st).add(acc.mul(dxl)).store(st);
                c += V::WIDTH;
            }
        }
    }
}

/// One lane-major backward step over raw SoA buffers (see
/// [`backward_step_lanes`] for the contract).
///
/// # Safety
/// As [`chen_body`], plus `lane_lambda` at `state_len × L`,
/// `right_prod` at `(max_level+1) × L` and `gdx_lanes` at `d × L`.
#[inline(always)]
unsafe fn backward_body<V: Vector, const L: usize>(
    eng: &SigEngine,
    lane_state: *const V::E,
    lane_lambda: *mut V::E,
    dx_lanes: *const V::E,
    right_prod: *mut V::E,
    gdx_lanes: *mut V::E,
) {
    debug_assert_eq!(L % V::WIDTH, 0);
    let t = &eng.table;
    for n in 1..=t.max_level {
        let inv_fact_n = eng.inv_fact[n];
        let level_base = t.level_csr_base(n);
        for (off, w) in t.level_range(n).enumerate() {
            // The whole-word skip must look at all L lanes regardless
            // of chunking, or chunked and unchunked sweeps could
            // disagree on which exact-zero contributions are added.
            let lam_row = std::slice::from_raw_parts(lane_lambda.add(w * L), L);
            if lam_row.iter().all(|&x| x == <V::E as Elem>::ZERO) {
                continue;
            }
            let base = level_base + off * n;
            let letters = t.csr_letters.get_unchecked(base..base + n);
            let prefixes = t.csr_prefix.get_unchecked(base..base + n);
            let mut c = 0;
            while c < L {
                // λ is read once into registers before any prefix-row
                // write — prefix rows are strictly shorter words, never
                // row `w`, so this copy matches the scalar kernel.
                let lam_v = V::load(lane_lambda.add(w * L + c));
                // Right suffix products R_p = Π_{q=p+1..n} dx_{i_q}.
                V::splat(<V::E as Elem>::ONE).store(right_prod.add(n * L + c));
                for p in (1..n).rev() {
                    let letter = *letters.get_unchecked(p) as usize; // i_{p+1}
                    let dxl = V::load(dx_lanes.add(letter * L + c));
                    let hi = V::load(right_prod.add((p + 1) * L + c));
                    hi.mul(dxl).store(right_prod.add(p * L + c));
                }
                // Fused sweep over positions p = 1..=n (per lane, the
                // exact scalar recurrence — see `sig_backward_into`):
                //   gdx[i_p]    += λ·A_p·R_p       (A_1 = 1/n!)
                //   λ(w_[p-1])  += λ·dx_{i_p}·R_p/(n-p+1)!
                //   A_{p+1}      = A_p·dx_{i_p} + S(w_[p])/(n-p)!
                let mut a = V::splat(<V::E as Elem>::from_f64(inv_fact_n));
                for p in 1..=n {
                    let letter = *letters.get_unchecked(p - 1) as usize; // i_p
                    let dxl = V::load(dx_lanes.add(letter * L + c));
                    let rp = V::load(right_prod.add(p * L + c));
                    let inv1 = V::splat(<V::E as Elem>::from_f64(
                        *eng.inv_fact.get_unchecked(n - p + 1),
                    ));
                    let g = gdx_lanes.add(letter * L + c);
                    V::load(g).add(lam_v.mul(a).mul(rp)).store(g);
                    let pref_lam =
                        lane_lambda.add(*prefixes.get_unchecked(p - 1) as usize * L + c);
                    V::load(pref_lam)
                        .add(lam_v.mul(dxl.mul(rp).mul(inv1)))
                        .store(pref_lam);
                    if p < n {
                        let s = V::load(
                            lane_state.add(*prefixes.get_unchecked(p) as usize * L + c),
                        );
                        let inv2 = V::splat(<V::E as Elem>::from_f64(
                            *eng.inv_fact.get_unchecked(n - p),
                        ));
                        a = a.mul(dxl).add(s.mul(inv2));
                    }
                }
                c += V::WIDTH;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Monomorphic per-ISA wrappers.
//
// `#[target_feature]` requires non-generic functions on the crate's
// MSRV, so each ISA gets one wrapper per kernel that matches the
// runtime lane width onto the `const L` instantiations — the only
// place the supported width sets are spelled out per element type
// (f64: {4, 8, 16, 32}; f32: {8, 16, 32, 64}). Dispatch guarantees
// the width is in the set *and* divisible by the register width
// (`Isa::effective`), so the `unreachable!` arms are the same loud
// contract as `lane_dispatch!`.
// ---------------------------------------------------------------------

macro_rules! chen_wrapper {
    ($(#[$attr:meta])* $name:ident, $vec:ty, $e:ty, [$($l:literal),+]) => {
        $(#[$attr])*
        unsafe fn $name(eng: &SigEngine, l: usize, lane_state: *mut $e, dx_lanes: *const $e) {
            match l {
                $( $l => chen_body::<$vec, $l>(eng, lane_state, dx_lanes), )+
                other => unreachable!("unsupported lane width {other}"),
            }
        }
    };
}

macro_rules! backward_wrapper {
    ($(#[$attr:meta])* $name:ident, $vec:ty, [$($l:literal),+]) => {
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        unsafe fn $name(
            eng: &SigEngine,
            l: usize,
            lane_state: *const f64,
            lane_lambda: *mut f64,
            dx_lanes: *const f64,
            right_prod: *mut f64,
            gdx_lanes: *mut f64,
        ) {
            match l {
                $( $l => backward_body::<$vec, $l>(
                    eng, lane_state, lane_lambda, dx_lanes, right_prod, gdx_lanes), )+
                other => unreachable!("unsupported lane width {other}"),
            }
        }
    };
}

chen_wrapper!(
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    chen_avx2, F64x4, f64, [4, 8, 16, 32]
);
chen_wrapper!(
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    #[target_feature(enable = "avx512f")]
    chen_avx512, F64x8, f64, [8, 16, 32]
);
chen_wrapper!(
    #[cfg(target_arch = "aarch64")]
    chen_neon, F64x2, f64, [4, 8, 16, 32]
);
chen_wrapper!(
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    chen_avx2_f32, F32x8, f32, [8, 16, 32, 64]
);
chen_wrapper!(
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    #[target_feature(enable = "avx512f")]
    chen_avx512_f32, F32x16, f32, [16, 32, 64]
);
chen_wrapper!(
    #[cfg(target_arch = "aarch64")]
    chen_neon_f32, F32x4, f32, [8, 16, 32, 64]
);
backward_wrapper!(
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    backward_avx2, F64x4, [4, 8, 16, 32]
);
backward_wrapper!(
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    #[target_feature(enable = "avx512f")]
    backward_avx512, F64x8, [8, 16, 32]
);
backward_wrapper!(
    #[cfg(target_arch = "aarch64")]
    backward_neon, F64x2, [4, 8, 16, 32]
);

/// The dispatch target for a kernel call at lane width `l`: the
/// engine's configured ISA, re-validated against this CPU and the
/// width (`Isa::effective`), with widths outside the wrapper sets
/// pinned to the scalar body (which handles any `L`).
#[inline]
fn dispatch_isa(eng: &SigEngine, l: usize, f32_elems: bool, supported: bool) -> Isa {
    if supported {
        eng.simd.effective(l, f32_elems)
    } else {
        Isa::Scalar
    }
}

/// One lane-major Chen/Horner update `S_l ← S_l ⊗ exp(dx_l)` for all
/// `L` lanes at once. `lane_state` is `state_len × L` (lane
/// contiguous, `lane_state[0..L] == 1`), `dx_lanes` is `d × L`.
/// Levels are processed top-down so the update is in place, exactly as
/// in the scalar [`crate::sig::chen_update`].
///
/// The inner loop runs on the engine's configured ISA
/// ([`SigEngine::simd`], `PATHSIG_SIMD`) — bitwise-equal to the scalar
/// path at any width, see the module docs.
pub fn chen_update_lanes<const L: usize>(
    eng: &SigEngine,
    lane_state: &mut [f64],
    dx_lanes: &[f64],
) {
    let t = &eng.table;
    // Hard asserts, not debug: the kernels below do unchecked reads
    // and writes at multiples of L, so these size contracts are what
    // keeps this a *safe* public function in release builds.
    assert_eq!(lane_state.len(), t.state_len * L, "lane_state must be state_len × L");
    assert_eq!(dx_lanes.len(), t.d * L, "dx_lanes must be d × L");
    let isa = dispatch_isa(eng, L, false, matches!(L, 4 | 8 | 16 | 32));
    // SAFETY: sizes asserted above; a non-scalar `isa` passed
    // `Isa::available()` inside `effective`, so its `#[target_feature]`
    // wrapper may run, and L is in the wrapper's width set (effective
    // checked divisibility; the sets contain every multiple of the
    // register width in {4,8,16,32}/{8,16,32,64}).
    unsafe {
        let (st, dx) = (lane_state.as_mut_ptr(), dx_lanes.as_ptr());
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => chen_avx2(eng, L, st, dx),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => chen_avx512(eng, L, st, dx),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => chen_neon(eng, L, st, dx),
            _ => chen_body::<Scalar1<f64>, L>(eng, st, dx),
        }
    }
}

/// [`chen_update_lanes`] over f32 elements — the inference-mode kernel
/// (`L` here is the f32 lane count, `2 ×` the engine's f64 width).
pub(crate) fn chen_update_lanes_f32<const L: usize>(
    eng: &SigEngine,
    lane_state: &mut [f32],
    dx_lanes: &[f32],
) {
    let t = &eng.table;
    assert_eq!(lane_state.len(), t.state_len * L, "lane_state must be state_len × L");
    assert_eq!(dx_lanes.len(), t.d * L, "dx_lanes must be d × L");
    let isa = dispatch_isa(eng, L, true, matches!(L, 8 | 16 | 32 | 64));
    // SAFETY: as in `chen_update_lanes`, with the f32 width sets.
    unsafe {
        let (st, dx) = (lane_state.as_mut_ptr(), dx_lanes.as_ptr());
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => chen_avx2_f32(eng, L, st, dx),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => chen_avx512_f32(eng, L, st, dx),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => chen_neon_f32(eng, L, st, dx),
            _ => chen_body::<Scalar1<f32>, L>(eng, st, dx),
        }
    }
}

/// One lane-major backward step: given the reconstructed state
/// `S_{j-1}` (`lane_state`, `state_len × L`), the step increments
/// (`dx_lanes`, `d × L`) and the incoming cotangents `λ_j`
/// (`lane_lambda`, `state_len × L`), update `λ` in place to `λ_{j-1}`
/// and accumulate this step's increment gradient into `gdx_lanes`
/// (`d × L`, caller-zeroed). `right_prod` is `(max_level+1) × L`
/// scratch for the right suffix products.
///
/// Per lane this performs exactly the scalar fused sweep of
/// `sig_backward_into` (same word order, same operation order per
/// accumulator) on the engine's configured ISA, so results match the
/// scalar kernel bitwise; lanes whose `λ` is identically zero
/// contribute exact zeros. Levels are processed in ASCENDING order:
/// the transpose sends contributions strictly from a word to its
/// shorter prefixes, so every `λ(w)` is read before anything lands on
/// it — the in-place mirror of the forward's descending trick.
pub fn backward_step_lanes<const L: usize>(
    eng: &SigEngine,
    lane_state: &[f64],
    lane_lambda: &mut [f64],
    dx_lanes: &[f64],
    right_prod: &mut [f64],
    gdx_lanes: &mut [f64],
) {
    let t = &eng.table;
    // Hard asserts, not debug: the kernels below do unchecked reads
    // and writes at multiples of L (see `chen_update_lanes`).
    assert_eq!(lane_state.len(), t.state_len * L, "lane_state must be state_len × L");
    assert_eq!(lane_lambda.len(), t.state_len * L, "lane_lambda must be state_len × L");
    assert_eq!(dx_lanes.len(), t.d * L, "dx_lanes must be d × L");
    assert!(right_prod.len() >= (t.max_level + 1) * L, "right_prod too small");
    assert_eq!(gdx_lanes.len(), t.d * L, "gdx_lanes must be d × L");
    let isa = dispatch_isa(eng, L, false, matches!(L, 4 | 8 | 16 | 32));
    // SAFETY: sizes asserted above; ISA availability and width
    // divisibility guaranteed by `Isa::effective` (see
    // `chen_update_lanes`).
    unsafe {
        let st = lane_state.as_ptr();
        let lam = lane_lambda.as_mut_ptr();
        let dx = dx_lanes.as_ptr();
        let rp = right_prod.as_mut_ptr();
        let g = gdx_lanes.as_mut_ptr();
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => backward_avx2(eng, L, st, lam, dx, rp, g),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => backward_avx512(eng, L, st, lam, dx, rp, g),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => backward_neon(eng, L, st, lam, dx, rp, g),
            _ => backward_body::<Scalar1<f64>, L>(eng, st, lam, dx, rp, g),
        }
    }
}

/// Forward-sweep a block of `nb ≤ L` paths over steps
/// `jl+1 ..= jr` (the `[jl, jr]` index window; the full path is
/// `jl = 0, jr = M`), leaving the result in `ws.lane_state`. Inactive
/// lanes (`nb < L`) carry zero increments and stay at the trivial
/// signature. `block` holds the `nb` paths back to back, `per_path`
/// values each, row-major `(M+1, d)`.
pub(crate) fn lane_forward<const L: usize>(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    jl: usize,
    jr: usize,
    ws: &mut ForwardWorkspace,
) {
    let d = eng.table.d;
    let sl = eng.table.state_len;
    debug_assert!(nb >= 1 && nb <= L);
    debug_assert_eq!(block.len(), nb * per_path);
    debug_assert!(ws.lane_state.len() >= sl * L && ws.dx_lanes.len() >= d * L);
    let lane_state = &mut ws.lane_state[..sl * L];
    let dx_lanes = &mut ws.dx_lanes[..d * L];
    lane_state.fill(0.0);
    lane_state[..L].fill(1.0); // ε row.
    dx_lanes.fill(0.0); // inactive lanes keep Δx = 0 throughout.
    for j in (jl + 1)..=jr {
        // Transpose this step's increments into lane-major layout.
        for (l, p) in block.chunks_exact(per_path).enumerate() {
            for i in 0..d {
                dx_lanes[i * L + l] = p[j * d + i] - p[(j - 1) * d + i];
            }
        }
        chen_update_lanes::<L>(eng, lane_state, dx_lanes);
    }
}

/// [`lane_forward`] over f32 state: increments are computed in f64
/// (exact for typical path data) and rounded once at the transpose, so
/// the f32 path's only precision loss is the in-kernel arithmetic.
pub(crate) fn lane_forward_f32<const L: usize>(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    jl: usize,
    jr: usize,
    ws: &mut ForwardWorkspace,
) {
    let d = eng.table.d;
    let sl = eng.table.state_len;
    debug_assert!(nb >= 1 && nb <= L);
    debug_assert_eq!(block.len(), nb * per_path);
    debug_assert!(ws.lane_state_f32.len() >= sl * L && ws.dx_lanes_f32.len() >= d * L);
    let lane_state = &mut ws.lane_state_f32[..sl * L];
    let dx_lanes = &mut ws.dx_lanes_f32[..d * L];
    lane_state.fill(0.0);
    lane_state[..L].fill(1.0); // ε row.
    dx_lanes.fill(0.0);
    for j in (jl + 1)..=jr {
        for (l, p) in block.chunks_exact(per_path).enumerate() {
            for i in 0..d {
                dx_lanes[i * L + l] = (p[j * d + i] - p[(j - 1) * d + i]) as f32;
            }
        }
        chen_update_lanes_f32::<L>(eng, lane_state, dx_lanes);
    }
}

/// Dispatch a generic-over-`L` kernel on the runtime lane width —
/// the ONE place the supported f64 width set `{4, 8, 16, 32}` is
/// spelled out for monomorphization. These are the only values
/// [`SigEngine::lanes`] can return; workspace buffers are strided by
/// the lane width, so running a kernel at any other width would
/// corrupt silently — fail loudly if the lane domain ever grows
/// without updating this match.
macro_rules! lane_dispatch {
    ($lanes:expr, $func:ident($($args:expr),* $(,)?)) => {
        match $lanes {
            4 => $func::<4>($($args),*),
            8 => $func::<8>($($args),*),
            16 => $func::<16>($($args),*),
            32 => $func::<32>($($args),*),
            other => unreachable!("unsupported lane width {other}"),
        }
    };
}
pub(crate) use lane_dispatch;

/// [`lane_dispatch!`] for the f32 lane widths `{8, 16, 32, 64}` — the
/// only values [`SigEngine::lanes_f32`] can return.
macro_rules! lane_dispatch_f32 {
    ($lanes:expr, $func:ident($($args:expr),* $(,)?)) => {
        match $lanes {
            8 => $func::<8>($($args),*),
            16 => $func::<16>($($args),*),
            32 => $func::<32>($($args),*),
            64 => $func::<64>($($args),*),
            other => unreachable!("unsupported f32 lane width {other}"),
        }
    };
}
pub(crate) use lane_dispatch_f32;

/// Monomorphization dispatch for [`lane_forward`] on the engine's lane
/// width.
pub(crate) fn lane_forward_dispatch(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    jl: usize,
    jr: usize,
    ws: &mut ForwardWorkspace,
) {
    lane_dispatch!(eng.lanes(), lane_forward(eng, block, nb, per_path, jl, jr, ws));
}

/// Monomorphization dispatch for [`lane_forward_f32`] on the engine's
/// f32 lane width.
pub(crate) fn lane_forward_f32_dispatch(
    eng: &SigEngine,
    block: &[f64],
    nb: usize,
    per_path: usize,
    jl: usize,
    jr: usize,
    ws: &mut ForwardWorkspace,
) {
    lane_dispatch_f32!(eng.lanes_f32(), lane_forward_f32(eng, block, nb, per_path, jl, jr, ws));
}

/// Project lane `l` of a lane-major state matrix onto the requested
/// coordinates (`row.len() == |I|`). `lw` is the runtime lane width the
/// matrix was built with.
pub(crate) fn project_lane(
    eng: &SigEngine,
    lane_state: &[f64],
    lw: usize,
    l: usize,
    row: &mut [f64],
) {
    debug_assert!(l < lw);
    for (o, &idx) in row.iter_mut().zip(&eng.table.output_map) {
        *o = lane_state[idx as usize * lw + l];
    }
}

/// Project the first `nb` lanes into `nb` consecutive output rows
/// (`out.len() == nb · |I|`) — the de-transpose at the end of a block.
pub(crate) fn project_block(
    eng: &SigEngine,
    lane_state: &[f64],
    lw: usize,
    nb: usize,
    out: &mut [f64],
) {
    let odim = eng.out_dim();
    debug_assert_eq!(out.len(), nb * odim);
    for (l, row) in out.chunks_exact_mut(odim).enumerate() {
        project_lane(eng, lane_state, lw, l, row);
    }
}

/// [`project_block`] from an f32 state matrix: the public API stays
/// f64, so inference results are widened exactly once on the way out.
pub(crate) fn project_block_f32(
    eng: &SigEngine,
    lane_state: &[f32],
    lw: usize,
    nb: usize,
    out: &mut [f64],
) {
    let odim = eng.out_dim();
    debug_assert_eq!(out.len(), nb * odim);
    for (l, row) in out.chunks_exact_mut(odim).enumerate() {
        for (o, &idx) in row.iter_mut().zip(&eng.table.output_map) {
            *o = lane_state[idx as usize * lw + l] as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature, SigEngine};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, Word, WordTable};

    fn lane_rows(eng: &SigEngine, paths: &[f64], nb: usize, per_path: usize) -> Vec<f64> {
        let mut ws = ForwardWorkspace::default();
        ws.ensure_lanes(eng);
        let m1 = per_path / eng.table.d;
        lane_forward_dispatch(eng, paths, nb, per_path, 0, m1 - 1, &mut ws);
        let mut out = vec![0.0; nb * eng.out_dim()];
        project_block(eng, &ws.lane_state, eng.lanes(), nb, &mut out);
        out
    }

    fn lane_rows_f32(eng: &SigEngine, paths: &[f64], nb: usize, per_path: usize) -> Vec<f64> {
        let mut ws = ForwardWorkspace::default();
        ws.ensure_lanes_f32(eng);
        let m1 = per_path / eng.table.d;
        lane_forward_f32_dispatch(eng, paths, nb, per_path, 0, m1 - 1, &mut ws);
        let mut out = vec![0.0; nb * eng.out_dim()];
        project_block_f32(eng, &ws.lane_state_f32, eng.lanes_f32(), nb, &mut out);
        out
    }

    #[test]
    fn full_block_matches_scalar_bitwise() {
        let mut rng = Rng::new(900);
        let eng = SigEngine::sequential(WordTable::build(3, &truncated_words(3, 4)));
        let lw = eng.lanes();
        let m = 7;
        let per = (m + 1) * 3;
        let mut paths = Vec::new();
        for _ in 0..lw {
            paths.extend(rng.brownian_path(m, 3, 0.6));
        }
        let rows = lane_rows(&eng, &paths, lw, per);
        for l in 0..lw {
            let single = signature(&eng, &paths[l * per..(l + 1) * per]);
            // Same arithmetic order per lane ⇒ bitwise identical.
            assert_eq!(&rows[l * eng.out_dim()..(l + 1) * eng.out_dim()], &single[..]);
        }
    }

    #[test]
    fn every_supported_isa_is_bitwise_equal_to_scalar() {
        // The kernel-level dispatch contract: at a fixed lane width,
        // each ISA this machine can run reproduces the scalar path
        // bit for bit, forward and backward state included. (The
        // engine-level sweep across entry points lives in
        // tests/engine_properties.rs.)
        let mut rng = Rng::new(903);
        let mut eng = SigEngine::sequential(WordTable::build(3, &truncated_words(3, 4)));
        let lw = eng.lanes();
        let m = 9;
        let per = (m + 1) * 3;
        let mut paths = Vec::new();
        for _ in 0..lw {
            paths.extend(rng.brownian_path(m, 3, 0.8));
        }
        eng.simd = crate::sig::Isa::Scalar;
        let want = lane_rows(&eng, &paths, lw, per);
        let want32 = lane_rows_f32(&eng, &paths, lw, per);
        for isa in crate::sig::Isa::supported() {
            eng.simd = isa;
            let got = lane_rows(&eng, &paths, lw, per);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "forward f64 mismatch on {isa:?}"
            );
            let got32 = lane_rows_f32(&eng, &paths, lw, per);
            assert!(
                got32.iter().zip(&want32).all(|(a, b)| a.to_bits() == b.to_bits()),
                "forward f32 mismatch on {isa:?}"
            );
        }
    }

    #[test]
    fn f32_block_tracks_f64_to_single_precision() {
        let mut rng = Rng::new(904);
        let eng = SigEngine::sequential(WordTable::build(2, &truncated_words(2, 4)));
        let lw = eng.lanes_f32();
        let m = 11;
        let per = (m + 1) * 2;
        let mut paths = Vec::new();
        for _ in 0..lw {
            paths.extend(rng.brownian_path(m, 2, 0.5));
        }
        let rows64 = lane_rows(&eng, &paths[..eng.lanes() * per], eng.lanes(), per);
        let rows32 = lane_rows_f32(&eng, &paths, lw, per);
        assert_allclose(
            &rows32[..eng.lanes() * eng.out_dim()],
            &rows64,
            1e-5,
            1e-5,
            "f32 lane block vs f64",
        );
    }

    #[test]
    fn partial_block_padded_lanes_are_inert() {
        let mut rng = Rng::new(901);
        let eng = SigEngine::sequential(WordTable::build(2, &truncated_words(2, 3)));
        let m = 5;
        let per = (m + 1) * 2;
        let nb = 3; // < lane width
        let mut paths = Vec::new();
        for _ in 0..nb {
            paths.extend(rng.brownian_path(m, 2, 1.0));
        }
        let rows = lane_rows(&eng, &paths, nb, per);
        for l in 0..nb {
            let single = signature(&eng, &paths[l * per..(l + 1) * per]);
            assert_allclose(
                &rows[l * eng.out_dim()..(l + 1) * eng.out_dim()],
                &single,
                0.0,
                0.0,
                "padded block row",
            );
        }
    }

    #[test]
    fn projected_word_set_lanes() {
        // Lane kernel over a sparse projected table (uneven word
        // lengths exercise the CSR level bases).
        let mut rng = Rng::new(902);
        let request = vec![Word(vec![1, 0, 2]), Word(vec![2]), Word(vec![0, 0, 1, 1])];
        let eng = SigEngine::sequential(WordTable::build(3, &request));
        let m = 9;
        let per = (m + 1) * 3;
        let nb = 5;
        let mut paths = Vec::new();
        for _ in 0..nb {
            paths.extend(rng.brownian_path(m, 3, 0.4));
        }
        let rows = lane_rows(&eng, &paths, nb, per);
        for l in 0..nb {
            let single = signature(&eng, &paths[l * per..(l + 1) * per]);
            assert_allclose(
                &rows[l * eng.out_dim()..(l + 1) * eng.out_dim()],
                &single,
                0.0,
                0.0,
                "projected row",
            );
        }
    }
}
