//! Windowed signatures — §5 of the paper.
//!
//! Given index pairs `(l_i, r_i)` with `l_i < r_i`, compute the
//! collection `(S_{t_{l_i}, t_{r_i}}(X))_i` in one call. Each window is an
//! independent forward recursion over its own increment range — windows
//! are the extra parallelism axis the paper uses to saturate the device,
//! and they parallelise across the thread pool here the same way
//! (units = batch × windows). In the batched entry point the window
//! list is shared across paths, so each (window, lane-block) unit runs
//! the lane-major SIMD kernel over a block of paths and scatters its
//! rows straight into the output tensor.
//!
//! A Chen-combination alternative (`S_{0,l}^{-1} ⊗ S_{0,r}` from
//! expanding-window states, as Signatory does) is implemented in
//! [`crate::baselines::chen_windows`] for the Fig-3 comparison; the paper
//! notes it is numerically unstable and memory-hungry for long sequences.

use super::forward::forward_sweep_range;
use super::lanes::{lane_forward_dispatch, project_lane, ForwardWorkspace};
use super::schedule::{self, TimeMode};
use super::SigEngine;
use crate::util::threadpool::{parallel_for_ctx, parallel_for_into, SendPtr};

/// An index window over path points `l..=r` (both endpoints included) —
/// the signature is computed over the segment increments
/// `l→l+1, …, r-1→r`, i.e. the paper's `S_{t_l, t_r}(X)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Window {
    /// Left edge (index of the window's first path point).
    pub l: usize,
    /// Right edge (index of the window's last path point, `> l`).
    pub r: usize,
}

impl Window {
    /// Construct the window spanning path points `l..=r`; panics unless
    /// `l < r`.
    pub fn new(l: usize, r: usize) -> Window {
        assert!(l < r, "window must satisfy l < r (got {l}, {r})");
        Window { l, r }
    }
}

/// Scalar forward sweep over one window into `ws.state`.
fn window_forward_ws(eng: &SigEngine, path: &[f64], w: Window, ws: &mut ForwardWorkspace) {
    forward_sweep_range(eng, path, w.l, w.r, &mut ws.state, &mut ws.dx);
}

/// Windowed signatures of a single path: returns row-major
/// `(K, |I|)` for `K = windows.len()`. `path` is `(M+1, d)`.
///
/// # Examples
///
/// ```
/// use pathsig::sig::{windowed_signatures, SigEngine, Window};
/// use pathsig::words::{truncated_words, WordTable};
///
/// let eng = SigEngine::new(WordTable::build(1, &truncated_words(1, 2)));
/// // 1-D path 0, 1, 3, 6; two windows over it.
/// let path = [0.0, 1.0, 3.0, 6.0];
/// let out = windowed_signatures(&eng, &path, &[Window::new(0, 2), Window::new(2, 3)]);
/// // Each row is [S((1)), S((1,1))] = [ΔX, ΔX²/2] over its window.
/// assert_eq!(out.len(), 4);
/// assert!((out[0] - 3.0).abs() < 1e-12); // X_2 - X_0
/// assert!((out[1] - 4.5).abs() < 1e-12); // 3²/2
/// assert!((out[2] - 3.0).abs() < 1e-12); // X_3 - X_2
/// ```
pub fn windowed_signatures(eng: &SigEngine, path: &[f64], windows: &[Window]) -> Vec<f64> {
    let mut out = vec![0.0; windows.len() * eng.out_dim()];
    windowed_signatures_into(eng, path, windows, &mut out);
    out
}

/// [`windowed_signatures`] writing into a caller-provided `(K, |I|)`
/// buffer. Delegates to the batch entry point with `B = 1` — same
/// arithmetic, and long paths pick up the time-parallel grid reuse.
pub fn windowed_signatures_into(
    eng: &SigEngine,
    path: &[f64],
    windows: &[Window],
    out: &mut [f64],
) {
    windowed_signatures_batch_into(eng, path, 1, windows, out);
}

/// One window's projected signature (sequential inner kernel).
pub fn window_signature(eng: &SigEngine, path: &[f64], w: Window) -> Vec<f64> {
    let d = eng.table.d;
    let m1 = path.len() / d;
    assert!(w.r < m1, "window right edge {} out of range (M={})", w.r, m1 - 1);
    let mut ws = ForwardWorkspace::default();
    window_forward_ws(eng, path, w, &mut ws);
    let mut out = vec![0.0; eng.out_dim()];
    eng.table.project(&ws.state, &mut out);
    out
}

/// Batched windowed signatures: `paths` `(B, M+1, d)`, same window list
/// for every path (the paper's API takes one `K×2` index tensor).
/// Returns row-major `(B, K, |I|)`. Parallel over `B × K` units; the
/// shared window list makes paths the lane axis, so each (window,
/// block) unit runs the lane-major kernel over a block of paths.
pub fn windowed_signatures_batch(
    eng: &SigEngine,
    paths: &[f64],
    batch: usize,
    windows: &[Window],
) -> Vec<f64> {
    let mut out = vec![0.0; batch * windows.len() * eng.out_dim()];
    windowed_signatures_batch_into(eng, paths, batch, windows, &mut out);
    out
}

/// [`windowed_signatures_batch`] writing into a caller-provided
/// `(B, K, |I|)` buffer — in-place rows, pooled workspaces, lane-major
/// kernel when `B` spans at least one lane block.
pub fn windowed_signatures_batch_into(
    eng: &SigEngine,
    paths: &[f64],
    batch: usize,
    windows: &[Window],
    out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 1, "bad path shape");
    let m1 = per_path / d;
    for w in windows {
        assert!(w.r < m1, "window right edge {} out of range (M={})", w.r, m1 - 1);
    }
    let odim = eng.out_dim();
    let kk = windows.len();
    assert_eq!(out.len(), batch * kk * odim, "output buffer has wrong size");
    if kk == 0 {
        return;
    }
    // Long paths with small batches: sweep the chunk grid once, share
    // its partial products across every window (heads/tails off the
    // grid are swept per window). Engaged only when (a) some window
    // actually spans ≥ 2 grid chunks and (b) the total window work
    // dominates the one full-path grid sweep the tree pays up front —
    // a few short windows on a huge path stay on the classic per-window
    // path, which never touches increments outside the windows. The
    // chunk snaps to the windows' start grid when one exists (see
    // `schedule::snap_chunk`).
    if let TimeMode::TimeParallel { chunk } = schedule::plan(eng, batch, m1 - 1) {
        let chunk = schedule::snap_chunk(chunk, windows);
        let total_len: usize = windows.iter().map(|w| w.r - w.l).sum();
        if total_len >= 2 * (m1 - 1) && windows.iter().any(|w| w.r - w.l >= 2 * chunk) {
            return super::tree::windowed_signatures_batch_tree_into(
                eng, paths, batch, windows, chunk, out,
            );
        }
    }
    let lanes = eng.lanes();

    if batch < lanes {
        // Scalar fallback: unit u = (path b, window k), row u written in
        // place (out is (B, K, |I|) row-major, so unit order == row order).
        let nw = eng.threads.min(batch * kk).max(1);
        let mut workers = eng.fwd_pool.take_at_least(nw);
        parallel_for_into(out, odim, &mut workers[..nw], |u, row, ws| {
            let (b, wi) = (u / kk, u % kk);
            window_forward_ws(eng, &paths[b * per_path..(b + 1) * per_path], windows[wi], ws);
            eng.table.project(&ws.state, row);
        });
        eng.fwd_pool.put(workers);
        return;
    }

    // Lane-major path: unit u = (lane block, window). A unit's rows are
    // strided in the (B, K, |I|) output — row (b0 + l, wi) for each
    // lane l — so they are scattered through a raw pointer; rows are
    // disjoint across units because each (b, wi) pair belongs to
    // exactly one unit.
    let n_blocks = batch.div_ceil(lanes);
    let nw = eng.threads.min(n_blocks * kk).max(1);
    let mut workers = eng.fwd_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure_lanes(eng);
    }
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for_ctx(n_blocks * kk, &mut workers[..nw], move |u, ws| {
        let blk = u / kk;
        let wi = u % kk;
        let win = windows[wi];
        let b0 = blk * lanes;
        let nb = (batch - b0).min(lanes);
        let block = &paths[b0 * per_path..(b0 + nb) * per_path];
        lane_forward_dispatch(eng, block, nb, per_path, win.l, win.r, ws);
        for l in 0..nb {
            let row_start = ((b0 + l) * kk + wi) * odim;
            // SAFETY: each (b, wi) row is written by exactly one unit
            // (see above); `out` outlives the scoped workers.
            let row =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(row_start), odim) };
            project_lane(eng, &ws.lane_state, lanes, l, row);
        }
    });
    eng.fwd_pool.put(workers);
}

/// Sliding windows of fixed `len` and `stride` over a path with `m1`
/// points (§5's `t ↦ S_{t-h,t}` viewpoint).
///
/// A window `[l, l+len]` needs `l + len ≤ m1 - 1` path points, so
/// windows exist **iff `len < m1`**; when the path is too short
/// (`len ≥ m1`, including the degenerate `m1 ∈ {0, 1}` with no
/// increments at all) the result is empty rather than a panic — the
/// streaming conformance suite relies on this for its empty-window
/// case, and [`crate::sig::StreamEngine`] mirrors it by reporting the
/// trivial signature until increments arrive.
pub fn sliding_windows(m1: usize, len: usize, stride: usize) -> Vec<Window> {
    assert!(len >= 1 && stride >= 1);
    let mut out = Vec::new();
    let mut l = 0;
    while l + len < m1 {
        out.push(Window::new(l, l + len));
        l += stride;
    }
    out
}

/// Expanding windows `[0, r)` for `r = 1..m1` (§5's `t ↦ S_{0,t}`).
pub fn expanding_windows(m1: usize) -> Vec<Window> {
    (1..m1).map(|r| Window::new(0, r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature, SigEngine};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, WordTable};

    fn eng(d: usize, n: usize) -> SigEngine {
        SigEngine::new(WordTable::build(d, &truncated_words(d, n)))
    }

    #[test]
    fn full_window_equals_global_signature() {
        let mut rng = Rng::new(300);
        let e = eng(2, 3);
        let path = rng.brownian_path(10, 2, 1.0);
        let out = windowed_signatures(&e, &path, &[Window::new(0, 10)]);
        let full = signature(&e, &path);
        assert_allclose(&out, &full, 1e-14, 1e-13, "full window");
    }

    #[test]
    fn window_equals_subpath_signature() {
        let mut rng = Rng::new(301);
        let d = 3;
        let e = eng(d, 3);
        let path = rng.brownian_path(20, d, 0.7);
        let w = Window::new(4, 13);
        let out = windowed_signatures(&e, &path, &[w]);
        let sub = signature(&e, &path[4 * d..=(13 * d + d - 1)]);
        assert_allclose(&out, &sub, 1e-14, 1e-13, "subpath");
    }

    #[test]
    fn chens_relation_across_adjacent_windows() {
        // S_{0,u} ⊗ S_{u,T} = S_{0,T} (Theorem 3.2) — verified through
        // the window API + dense tensor multiply.
        use crate::tensor::TruncTensor;
        let mut rng = Rng::new(302);
        let d = 2;
        let n = 4;
        let e = eng(d, n);
        let path = rng.brownian_path(12, d, 0.8);
        let parts = windowed_signatures(&e, &path, &[Window::new(0, 5), Window::new(5, 12)]);
        let odim = e.out_dim();
        let to_tensor = |flat: &[f64]| {
            let mut t = TruncTensor::one(d, n);
            let mut k = 0;
            for lvl in 1..=n {
                for c in 0..d.pow(lvl as u32) {
                    t.levels[lvl][c] = flat[k];
                    k += 1;
                }
            }
            t
        };
        let left = to_tensor(&parts[..odim]);
        let right = to_tensor(&parts[odim..]);
        let combined = left.mul(&right).flatten_nonscalar();
        let full = signature(&e, &path);
        assert_allclose(&combined, &full, 1e-12, 1e-11, "chen");
    }

    #[test]
    fn many_windows_match_individual_calls() {
        let mut rng = Rng::new(303);
        let d = 2;
        let e = eng(d, 2);
        let path = rng.brownian_path(30, d, 1.0);
        let wins: Vec<Window> = vec![
            Window::new(0, 3),
            Window::new(2, 17),
            Window::new(10, 30),
            Window::new(29, 30),
        ];
        let all = windowed_signatures(&e, &path, &wins);
        let odim = e.out_dim();
        for (k, w) in wins.iter().enumerate() {
            let single = window_signature(&e, &path, *w);
            assert_allclose(&all[k * odim..(k + 1) * odim], &single, 0.0, 0.0, "row");
        }
    }

    #[test]
    fn batch_windows_shape_and_content() {
        let mut rng = Rng::new(304);
        let d = 2;
        let e = eng(d, 2);
        let b = 3;
        let m = 8;
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
        }
        let wins = vec![Window::new(0, 4), Window::new(4, 8)];
        let out = windowed_signatures_batch(&e, &paths, b, &wins);
        let odim = e.out_dim();
        assert_eq!(out.len(), b * 2 * odim);
        let per = (m + 1) * d;
        for bi in 0..b {
            let single = windowed_signatures(&e, &paths[bi * per..(bi + 1) * per], &wins);
            assert_allclose(
                &out[bi * 2 * odim..(bi + 1) * 2 * odim],
                &single,
                0.0,
                0.0,
                "batch block",
            );
        }
    }

    #[test]
    fn batch_windows_lane_path_matches_scalar() {
        // Batch wide enough for the lane kernel, non-divisible by the
        // lane width, checked row-by-row against the scalar kernel.
        let mut rng = Rng::new(305);
        let d = 2;
        let e = eng(d, 3);
        let b = e.lanes() + 3;
        let m = 12;
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 0.9));
        }
        let wins = vec![Window::new(0, 5), Window::new(3, 12), Window::new(11, 12)];
        let out = windowed_signatures_batch(&e, &paths, b, &wins);
        let odim = e.out_dim();
        let per = (m + 1) * d;
        for bi in 0..b {
            for (k, w) in wins.iter().enumerate() {
                let single = window_signature(&e, &paths[bi * per..(bi + 1) * per], *w);
                assert_allclose(
                    &out[(bi * wins.len() + k) * odim..(bi * wins.len() + k + 1) * odim],
                    &single,
                    0.0,
                    0.0,
                    "lane window row",
                );
            }
        }
    }

    #[test]
    fn sliding_and_expanding_generators() {
        let s = sliding_windows(10, 4, 2);
        assert_eq!(s, vec![Window::new(0, 4), Window::new(2, 6), Window::new(4, 8)]);
        let e = expanding_windows(4);
        assert_eq!(e, vec![Window::new(0, 1), Window::new(0, 2), Window::new(0, 3)]);
    }

    #[test]
    fn sliding_windows_short_paths_are_empty() {
        // Windows exist iff len < m1 (documented contract): a path with
        // too few points yields no windows instead of panicking.
        assert!(sliding_windows(5, 5, 1).is_empty()); // len == m1
        assert!(sliding_windows(5, 9, 2).is_empty()); // len > m1
        assert!(sliding_windows(1, 1, 1).is_empty()); // single point
        assert!(sliding_windows(0, 3, 1).is_empty()); // no points at all
        // Boundary: len == m1 - 1 gives exactly one window.
        assert_eq!(sliding_windows(5, 4, 3), vec![Window::new(0, 4)]);
    }

    #[test]
    #[should_panic(expected = "window must satisfy l < r")]
    fn degenerate_window_rejected() {
        Window::new(3, 3);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn window_beyond_path_rejected() {
        let e = eng(2, 2);
        let path = vec![0.0; 10]; // 5 points
        windowed_signatures(&e, &path, &[Window::new(0, 5)]);
    }
}
