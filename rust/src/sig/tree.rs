//! Time-parallel signature engine — chunked Chen tree reduction and the
//! checkpointed backward pass (ISSUE 5).
//!
//! Every other kernel in this crate walks the time axis strictly
//! sequentially (one Chen update per increment), so a long path with a
//! small batch uses one SIMD lane of one core. Chen's identity is
//! associative — `S_{0,T} = S_{0,u} ⊗ S_{u,T}` — so the time axis can
//! be **chunked**:
//!
//! ```text
//!   increments:  |---c0---|---c1---|---c2---|--c3--|      (C each)
//!   phase 1:      E_0       E_1      E_2     E_3     chunk-local sigs,
//!                                                    swept CONCURRENTLY
//!   phase 2:        E_0⊗E_1     E_2⊗E_3               log-depth tree of
//!                       (E_0⊗E_1)⊗(E_2⊗E_3) = S_{0,T}  combine_lanes
//! ```
//!
//! Phase 1 packs the `(path × chunk)` units into the lane-major SIMD
//! kernel ([`chen_update_lanes`]): when `B < L` the lanes sweep `L`
//! different chunks at once (a single path still fills a whole SIMD
//! register), and when `B ≥ L` lanes stay packed over paths while the
//! spare threads take different chunks (the scheduler's hybrid mode —
//! see [`crate::sig::schedule`]). Phase 2 reduces each path's chunk
//! signatures pairwise with the factor-closure Chen combine of
//! [`StreamTable`], packing up to `L` independent pairs per
//! [`StreamTable::combine_lanes`] call — `O(log K)` levels, every level
//! SIMD over pairs.
//!
//! The combine reads **suffix** coordinates, so the reduction runs over
//! the factor closure of the engine's request: free (identical table)
//! for truncated/anisotropic/DAG sets, which are already suffix-closed,
//! and an automatic factor-closure fallback for general projected sets
//! (at most `|w|²/2` extra state entries per requested word — the same
//! table [`StreamTable`] builds for streaming, cached per engine).
//!
//! ## Checkpointed backward
//!
//! The forward scan's chunk-boundary prefix states `P_k = S_{0,kC}` are
//! free checkpoints. The backward pass becomes:
//!
//! 1. chunk-local signatures `E_k` (parallel, as above);
//! 2. a cheap sequential boundary scan per path: prefixes
//!    `P_{k+1} = P_k ⊗ E_k` and boundary cotangents
//!    `Λ_k = (· ⊗ E_k)ᵀ Λ_{k+1}` — `O(K)` combines instead of `O(M)`
//!    Chen steps;
//! 3. every chunk replays **independently, in parallel**: seeded with
//!    `(P_{k+1}, Λ_{k+1})`, it runs the standard group-inverse
//!    reconstruction + cotangent sweep over its own `C` increments.
//!
//! Group-inverse drift — the numerical hazard of the §4 reconstruction
//! on long paths — is now bounded to one chunk, because every chunk
//! restarts from an exactly-scanned boundary state.
//!
//! Because the tree reassociates floating-point sums, results match the
//! sequential kernels to ~1e-12 relative, **not bitwise** — asserted by
//! the conformance matrix in `tests/tree_properties.rs`. All scratch is
//! pooled on the engine, so warm calls perform zero heap allocations
//! (counted in `benches/fig8_longpath.rs`).

use super::forward::forward_sweep_range;
use super::lanes::{backward_step_lanes, chen_update_lanes, lane_dispatch};
use super::stream::StreamTable;
use super::windows::Window;
use super::{chen_update, SigEngine};
use crate::util::threadpool::{
    parallel_fill_rows, parallel_for_ctx, parallel_for_ctx_grained, parallel_for_into, SendPtr,
};

/// Chunk-grid geometry of one time-parallel call: how the `B × K`
/// (path, chunk) units map onto the unit axis the lane blocks sweep.
#[derive(Clone, Copy, Debug)]
struct Grid {
    batch: usize,
    /// Chunks per path, `ceil(steps / chunk)`.
    kk: usize,
    /// Chunk length in increments (last chunk of a path may be short).
    chunk: usize,
    /// Increments per path.
    steps: usize,
    /// `true` ⇒ `u = b·K + k` (lanes sweep chunks of the same path;
    /// chosen when `B < L`), else `u = k·B + b` (lanes sweep paths at
    /// the same chunk).
    path_major: bool,
}

impl Grid {
    #[inline]
    fn units(&self) -> usize {
        self.batch * self.kk
    }

    #[inline]
    fn unit(&self, b: usize, k: usize) -> usize {
        if self.path_major {
            b * self.kk + k
        } else {
            k * self.batch + b
        }
    }

    #[inline]
    fn split(&self, u: usize) -> (usize, usize) {
        if self.path_major {
            (u / self.kk, u % self.kk)
        } else {
            (u % self.batch, u / self.batch)
        }
    }

    /// Number of real increments in chunk `k` (the last chunk of a
    /// path is short when `chunk` does not divide `steps`).
    #[inline]
    fn chunk_len(&self, k: usize) -> usize {
        (self.steps - k * self.chunk).min(self.chunk)
    }
}

/// Shared per-call buffers of the time-parallel engine (chunk
/// signatures + the backward pass's boundary checkpoints/cotangents).
/// Pooled on the engine so warm calls of the same shape allocate
/// nothing.
#[derive(Debug, Default)]
pub(crate) struct TreeBuffers {
    /// Chunk-local signatures, unit-major: `E_u` at `u·state_len`.
    chunk_sigs: Vec<f64>,
    /// Boundary prefix states, `(B, K+1, state_len)`: `P_k = S_{0,kC}`.
    bound_states: Vec<f64>,
    /// Boundary cotangents, `(B, K+1, state_len)`: `Λ_k = ∂L/∂S_{0,kC}`.
    bound_lambda: Vec<f64>,
}

/// Per-worker scratch of the time-parallel engine (lane-major sweep
/// state, combine operands, the per-path reduction segment and scalar
/// window-fold states). Pooled on the engine.
#[derive(Clone, Debug, Default)]
pub(crate) struct TreeScratch {
    lane_state: Vec<f64>,
    lane_lambda: Vec<f64>,
    dx: Vec<f64>,
    neg_dx: Vec<f64>,
    right_prod: Vec<f64>,
    gdx: Vec<f64>,
    /// Lane-major combine operands/result (`state_len × L` each).
    ca: Vec<f64>,
    cb: Vec<f64>,
    cc: Vec<f64>,
    /// Contiguous copy of one path's `K` chunk signatures for the
    /// pairwise reduction.
    seg: Vec<f64>,
    /// Scalar fold states + step increment for the windowed path.
    s1: Vec<f64>,
    s2: Vec<f64>,
    sdx: Vec<f64>,
}

impl TreeScratch {
    /// Size every buffer for `tbl` (idempotent; steady state neither
    /// allocates nor writes). `reduce_kk` is the chunk count of the
    /// forward reduction — the only phase that reads `seg` — so the
    /// backward and windowed drivers pass 0 and never grow a
    /// `K × state_len` buffer they won't touch.
    fn ensure(&mut self, tbl: &StreamTable, reduce_kk: usize) {
        let kk = reduce_kk;
        let l = tbl.eng.lanes();
        let sl = tbl.state_len();
        let d = tbl.dim();
        let ml = tbl.eng.table.max_level;
        self.lane_state.resize(sl * l, 0.0);
        self.lane_lambda.resize(sl * l, 0.0);
        self.dx.resize(d * l, 0.0);
        self.neg_dx.resize(d * l, 0.0);
        self.right_prod.resize((ml + 1) * l, 0.0);
        self.gdx.resize(d * l, 0.0);
        self.ca.resize(sl * l, 0.0);
        self.cb.resize(sl * l, 0.0);
        self.cc.resize(sl * l, 0.0);
        self.seg.resize(kk.max(1) * sl, 0.0);
        self.s1.resize(sl, 0.0);
        self.s2.resize(sl, 0.0);
        self.sdx.resize(d, 0.0);
    }
}

// ------------------------------------------------------------------
// Phase 1 — concurrent chunk-local signatures
// ------------------------------------------------------------------

/// Sweep the chunk-local signatures of units `u0 .. u0 + rows.len()/sl`
/// lane-major and de-transpose them into consecutive unit-major `rows`.
/// Lanes whose chunk is short (a path's last chunk) carry zero
/// increments past their end; lanes beyond the unit count stay inert.
#[allow(clippy::too_many_arguments)]
fn chunk_block_forward<const L: usize>(
    tbl: &StreamTable,
    paths: &[f64],
    per_path: usize,
    grid: Grid,
    u0: usize,
    rows: &mut [f64],
    ws: &mut TreeScratch,
) {
    let ieng = &tbl.eng;
    let d = ieng.table.d;
    let sl = ieng.table.state_len;
    let nu = rows.len() / sl;
    debug_assert!(nu >= 1 && nu <= L);
    let lane_state = &mut ws.lane_state[..sl * L];
    let dx = &mut ws.dx[..d * L];
    lane_state.fill(0.0);
    lane_state[..L].fill(1.0); // ε row
    dx.fill(0.0);
    for s in 0..grid.chunk {
        for l in 0..nu {
            let (b, k) = grid.split(u0 + l);
            let len = grid.chunk_len(k);
            if s < len {
                let p = &paths[b * per_path..(b + 1) * per_path];
                let j = k * grid.chunk + s + 1;
                for i in 0..d {
                    dx[i * L + l] = p[j * d + i] - p[(j - 1) * d + i];
                }
            } else if s == len {
                // First padded step of a short chunk: zero this lane's
                // increment once; later steps keep it zero.
                for i in 0..d {
                    dx[i * L + l] = 0.0;
                }
            }
        }
        chen_update_lanes::<L>(ieng, lane_state, dx);
    }
    for (l, row) in rows.chunks_exact_mut(sl).enumerate() {
        for (w, slot) in row.iter_mut().enumerate() {
            *slot = lane_state[w * L + l];
        }
    }
}

// ------------------------------------------------------------------
// Phase 2 — log-depth pairwise reduction (forward)
// ------------------------------------------------------------------

/// Reduce one path's `K` chunk signatures to `S_{0,T}` with a pairwise
/// tree, packing up to `L` independent pairs into each
/// [`StreamTable::combine_lanes`] call. Copies the path's chunk rows
/// into `ws.seg` first (the shared `chunk_sigs` store stays read-only),
/// and leaves the result in `ws.seg[..state_len]`.
///
/// Adjacent pairs `(2p, 2p+1) → p` preserve chronological order (the
/// Chen product is associative but not commutative); an odd orphan is
/// carried to the end of the next level.
fn reduce_path<const L: usize>(
    tbl: &StreamTable,
    chunk_sigs: &[f64],
    grid: Grid,
    b: usize,
    ws: &mut TreeScratch,
) {
    let sl = tbl.state_len();
    for k in 0..grid.kk {
        let u = grid.unit(b, k);
        ws.seg[k * sl..(k + 1) * sl].copy_from_slice(&chunk_sigs[u * sl..(u + 1) * sl]);
    }
    let mut width = grid.kk;
    while width > 1 {
        let pairs = width / 2;
        let mut p0 = 0;
        while p0 < pairs {
            let np = (pairs - p0).min(L);
            for l in 0..np {
                let i = 2 * (p0 + l);
                for w in 0..sl {
                    ws.ca[w * L + l] = ws.seg[i * sl + w];
                    ws.cb[w * L + l] = ws.seg[(i + 1) * sl + w];
                }
            }
            tbl.combine_lanes::<L>(&ws.ca[..sl * L], &ws.cb[..sl * L], &mut ws.cc[..sl * L]);
            // Destination p < every pending source 2p' (p' ≥ p0), so
            // the compacting scatter never clobbers an unread operand.
            for l in 0..np {
                for w in 0..sl {
                    ws.seg[(p0 + l) * sl + w] = ws.cc[w * L + l];
                }
            }
            p0 += np;
        }
        if width % 2 == 1 {
            ws.seg.copy_within((width - 1) * sl..width * sl, pairs * sl);
        }
        width = pairs + width % 2;
    }
}

// ------------------------------------------------------------------
// Forward driver
// ------------------------------------------------------------------

fn tree_setup(
    eng: &SigEngine,
    batch: usize,
    steps: usize,
    chunk: usize,
) -> (std::sync::Arc<StreamTable>, Grid) {
    let tbl = eng.tree_table();
    let lanes = tbl.eng.lanes();
    let chunk = chunk.clamp(1, steps);
    let grid = Grid {
        batch,
        kk: steps.div_ceil(chunk),
        chunk,
        steps,
        path_major: batch < lanes,
    };
    (tbl, grid)
}

fn forward_impl<const L: usize>(
    eng: &SigEngine,
    tbl: &StreamTable,
    paths: &[f64],
    per_path: usize,
    grid: Grid,
    out: &mut [f64],
) {
    let sl = tbl.state_len();
    let odim = tbl.out_dim();
    let n_blocks = grid.units().div_ceil(L);
    let nw = eng.threads.min(n_blocks.max(grid.batch)).max(1);
    let mut bufs = eng.tree_pool.take_at_least(1);
    let mut workers = eng.tree_ctx_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure(tbl, grid.kk);
    }
    let buf = &mut bufs[0];
    buf.chunk_sigs.resize(grid.units() * sl, 0.0);
    parallel_for_into(&mut buf.chunk_sigs, L * sl, &mut workers[..nw], |blk, rows, ws| {
        chunk_block_forward::<L>(tbl, paths, per_path, grid, blk * L, rows, ws);
    });
    let chunk_sigs: &[f64] = &buf.chunk_sigs;
    parallel_for_into(out, odim, &mut workers[..nw], |b, row, ws| {
        reduce_path::<L>(tbl, chunk_sigs, grid, b, ws);
        tbl.project_into(&ws.seg[..sl], row);
    });
    eng.tree_ctx_pool.put(workers);
    eng.tree_pool.put(bufs);
}

/// Time-parallel batched forward: split each path's `M` increments into
/// `ceil(M/chunk)` chunks, sweep the chunks concurrently with the
/// lane-major Chen kernel, and reduce each path's chunk signatures in a
/// log-depth tree of factor-closure combines. Exact up to summation
/// reassociation (~1e-12 relative vs [`crate::sig::signature_batch`];
/// see the module docs). `signature_batch_into` routes here
/// automatically when [`crate::sig::schedule::plan`] picks the
/// time-parallel mode; call this directly to force a specific chunk.
///
/// # Examples
///
/// ```
/// use pathsig::sig::{signature_batch_scalar, signature_batch_tree_into, SigEngine};
/// use pathsig::words::{truncated_words, WordTable};
///
/// let eng = SigEngine::sequential(WordTable::build(2, &truncated_words(2, 3)));
/// let path: Vec<f64> = (0..97 * 2).map(|i| (i as f64 * 0.37).sin()).collect();
/// let mut out = vec![0.0; eng.out_dim()];
/// signature_batch_tree_into(&eng, &path, 1, 16, &mut out);
/// let want = signature_batch_scalar(&eng, &path, 1);
/// for (a, b) in out.iter().zip(&want) {
///     assert!((a - b).abs() < 1e-10 * (1.0 + b.abs()));
/// }
/// ```
pub fn signature_batch_tree_into(
    eng: &SigEngine,
    paths: &[f64],
    batch: usize,
    chunk: usize,
    out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(out.len(), batch * odim, "output buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 2, "bad path shape");
    let steps = per_path / d - 1;
    let (tbl, grid) = tree_setup(eng, batch, steps, chunk);
    lane_dispatch!(tbl.eng.lanes(), forward_impl(eng, &tbl, paths, per_path, grid, out));
}

// ------------------------------------------------------------------
// Checkpointed backward
// ------------------------------------------------------------------

/// Replay + differentiate one lane block of chunks, each seeded with
/// its boundary checkpoint `P_{k+1}` (chunk right-edge prefix state)
/// and boundary cotangent `Λ_{k+1}`. Within the chunk this is exactly
/// the §4 reverse sweep: group-inverse reconstruction, cotangent
/// transpose and ΔX gradient — but the drift of the inverse
/// reconstruction is bounded to one chunk, and every chunk runs
/// independently. Increment gradients land in each path's point-`j`
/// slot of `out` (converted to point gradients by the caller);
/// disjoint across units because every step belongs to exactly one
/// chunk.
#[allow(clippy::too_many_arguments)]
fn chunk_block_backward<const L: usize>(
    tbl: &StreamTable,
    paths: &[f64],
    per_path: usize,
    grid: Grid,
    bound_states: &[f64],
    bound_lambda: &[f64],
    u0: usize,
    out_ptr: SendPtr<f64>,
    ws: &mut TreeScratch,
) {
    let ieng = &tbl.eng;
    let d = ieng.table.d;
    let sl = ieng.table.state_len;
    let ml = ieng.table.max_level;
    let nu = (grid.units() - u0).min(L);
    let kk1 = grid.kk + 1;
    let lane_state = &mut ws.lane_state[..sl * L];
    let lane_lambda = &mut ws.lane_lambda[..sl * L];
    let dx = &mut ws.dx[..d * L];
    let neg_dx = &mut ws.neg_dx[..d * L];
    let right_prod = &mut ws.right_prod[..(ml + 1) * L];
    let gdx = &mut ws.gdx[..d * L];
    // Seed: inert lanes keep the identity state, zero λ and zero dx —
    // every contribution they touch is an exact zero.
    lane_state.fill(0.0);
    lane_state[..L].fill(1.0);
    lane_lambda.fill(0.0);
    dx.fill(0.0);
    neg_dx.fill(0.0);
    for l in 0..nu {
        let (b, k) = grid.split(u0 + l);
        let ps = &bound_states[(b * kk1 + k + 1) * sl..(b * kk1 + k + 2) * sl];
        let lm = &bound_lambda[(b * kk1 + k + 1) * sl..(b * kk1 + k + 2) * sl];
        for w in 0..sl {
            lane_state[w * L + l] = ps[w];
            lane_lambda[w * L + l] = lm[w];
        }
    }
    // Reverse sweep over local steps. A short chunk's padded positions
    // (s ≥ len) come FIRST in reverse order; their lanes still carry
    // the initial zero increments, so state and λ pass through
    // untouched until the real steps begin.
    for s in (0..grid.chunk).rev() {
        for l in 0..nu {
            let (b, k) = grid.split(u0 + l);
            if s < grid.chunk_len(k) {
                let p = &paths[b * per_path..(b + 1) * per_path];
                let j = k * grid.chunk + s + 1;
                for i in 0..d {
                    let v = p[j * d + i] - p[(j - 1) * d + i];
                    dx[i * L + l] = v;
                    neg_dx[i * L + l] = -v;
                }
            }
        }
        // Reconstruct S_{0,j-1} (Prop 4.6) for all lanes, then the
        // fused cotangent/ΔX-gradient sweep.
        chen_update_lanes::<L>(ieng, lane_state, neg_dx);
        gdx.fill(0.0);
        backward_step_lanes::<L>(ieng, lane_state, lane_lambda, dx, right_prod, gdx);
        for l in 0..nu {
            let (b, k) = grid.split(u0 + l);
            if s < grid.chunk_len(k) {
                let j = k * grid.chunk + s + 1;
                // SAFETY: each (path, step) slot belongs to exactly one
                // (path, chunk) unit, claimed by exactly one block; the
                // output buffer outlives the scoped workers.
                let row = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(b * per_path + j * d), d)
                };
                for i in 0..d {
                    row[i] = gdx[i * L + l];
                }
            }
        }
    }
}

/// Convert one path's increment gradients (stored in point slots
/// `j = 1..=steps`) to point gradients in place:
/// `∂L/∂X_0 = -g_1`, `∂L/∂X_j = g_j - g_{j+1}`, `∂L/∂X_M = g_M`.
/// Ascending `j` reads slot `j+1` before it is rewritten.
fn chain_rule_row(row: &mut [f64], d: usize, steps: usize) {
    if steps == 0 {
        return; // already zero
    }
    for i in 0..d {
        row[i] = -row[d + i];
    }
    for j in 1..steps {
        for i in 0..d {
            row[j * d + i] -= row[(j + 1) * d + i];
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn backward_impl<const L: usize>(
    eng: &SigEngine,
    tbl: &StreamTable,
    paths: &[f64],
    grads_out: &[f64],
    per_path: usize,
    grid: Grid,
    out: &mut [f64],
    mut sig_out: Option<&mut [f64]>,
) {
    let sl = tbl.state_len();
    let odim = tbl.out_dim();
    let d = tbl.dim();
    let kk1 = grid.kk + 1;
    let n_blocks = grid.units().div_ceil(L);
    let nw = eng.threads.min(n_blocks.max(grid.batch)).max(1);
    let mut bufs = eng.tree_pool.take_at_least(1);
    let mut workers = eng.tree_ctx_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure(tbl, 0); // no forward reduction here — seg stays small
    }
    let TreeBuffers {
        chunk_sigs,
        bound_states,
        bound_lambda,
    } = &mut bufs[0];
    chunk_sigs.resize(grid.units() * sl, 0.0);
    bound_states.resize(grid.batch * kk1 * sl, 0.0);
    bound_lambda.resize(grid.batch * kk1 * sl, 0.0);

    // Phase 1: chunk-local signatures (parallel lane blocks).
    parallel_for_into(chunk_sigs, L * sl, &mut workers[..nw], |blk, rows, ws| {
        chunk_block_forward::<L>(tbl, paths, per_path, grid, blk * L, rows, ws);
    });
    let chunk_sigs: &[f64] = chunk_sigs;

    // Phase 2a: boundary prefix scan P_{k+1} = P_k ⊗ E_k (per path).
    parallel_fill_rows(bound_states, kk1 * sl, nw, |b, seg| {
        seg[..sl].fill(0.0);
        seg[0] = 1.0;
        for k in 0..grid.kk {
            let u = grid.unit(b, k);
            let (lo, hi) = seg.split_at_mut((k + 1) * sl);
            tbl.combine(&lo[k * sl..], &chunk_sigs[u * sl..(u + 1) * sl], &mut hi[..sl]);
        }
    });
    let bound_states: &[f64] = bound_states;

    // Phase 2b: boundary cotangent scan Λ_k = (· ⊗ E_k)ᵀ Λ_{k+1}.
    parallel_fill_rows(bound_lambda, kk1 * sl, nw, |b, seg| {
        seg[grid.kk * sl..].fill(0.0);
        tbl.scatter_into(&grads_out[b * odim..(b + 1) * odim], &mut seg[grid.kk * sl..]);
        for k in (0..grid.kk).rev() {
            let u = grid.unit(b, k);
            seg.copy_within((k + 1) * sl..(k + 2) * sl, k * sl);
            tbl.combine_transpose_right(
                &chunk_sigs[u * sl..(u + 1) * sl],
                &mut seg[k * sl..(k + 1) * sl],
            );
        }
    });
    let bound_lambda: &[f64] = bound_lambda;

    // Phase 3: chunk replays, parallel over lane blocks; increment
    // gradients land in disjoint point slots of `out`.
    out.fill(0.0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for_ctx(n_blocks, &mut workers[..nw], move |blk, ws| {
        // Capture the SendPtr wrapper by value (edition-2021 disjoint
        // capture would otherwise grab the raw field and lose Send).
        let out_ptr = out_ptr;
        chunk_block_backward::<L>(
            tbl,
            paths,
            per_path,
            grid,
            bound_states,
            bound_lambda,
            blk * L,
            out_ptr,
            ws,
        );
    });

    // Phase 4: increment → point gradients, in place per path.
    parallel_fill_rows(out, per_path, nw, |_, row| chain_rule_row(row, d, grid.steps));

    // Fused entry: the terminal boundary state IS the signature.
    if let Some(sig) = sig_out.as_deref_mut() {
        for b in 0..grid.batch {
            tbl.project_into(
                &bound_states[(b * kk1 + grid.kk) * sl..(b * kk1 + grid.kk + 1) * sl],
                &mut sig[b * odim..(b + 1) * odim],
            );
        }
    }
    eng.tree_ctx_pool.put(workers);
    eng.tree_pool.put(bufs);
}

fn backward_entry_checks(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
    out: &[f64],
) -> (usize, usize) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let odim = eng.out_dim();
    assert_eq!(grads_out.len(), batch * odim);
    assert_eq!(out.len(), paths.len(), "gradient buffer has wrong size");
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 2, "bad path shape");
    (per_path, per_path / d - 1)
}

/// Time-parallel batched backward: the checkpointed form of
/// [`crate::sig::sig_backward_batch_into`] (see the module docs —
/// boundary scans + independent chunk replays).
/// `sig_backward_batch_into` routes here automatically when the
/// scheduler picks time-parallel mode; call this directly to force a
/// specific chunk.
pub fn sig_backward_batch_tree_into(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
    chunk: usize,
    out: &mut [f64],
) {
    let (per_path, steps) = backward_entry_checks(eng, paths, grads_out, batch, out);
    let (tbl, grid) = tree_setup(eng, batch, steps, chunk);
    lane_dispatch!(
        tbl.eng.lanes(),
        backward_impl(eng, &tbl, paths, grads_out, per_path, grid, out, None)
    );
}

/// Fused time-parallel forward + backward: signatures come from the
/// boundary prefix scan the backward pass needs anyway, so the full
/// training-step primitive costs one chunk sweep + one replay.
/// `signature_and_backward_batch_into` routes here automatically when
/// the scheduler picks time-parallel mode.
pub fn signature_and_backward_batch_tree_into(
    eng: &SigEngine,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
    chunk: usize,
    sig_out: &mut [f64],
    grad_out: &mut [f64],
) {
    let (per_path, steps) = backward_entry_checks(eng, paths, grads_out, batch, grad_out);
    assert_eq!(sig_out.len(), batch * eng.out_dim(), "signature buffer has wrong size");
    let (tbl, grid) = tree_setup(eng, batch, steps, chunk);
    lane_dispatch!(
        tbl.eng.lanes(),
        backward_impl(eng, &tbl, paths, grads_out, per_path, grid, grad_out, Some(sig_out))
    );
}

// ------------------------------------------------------------------
// Windowed signatures over the shared chunk grid
// ------------------------------------------------------------------

/// One window's signature from the shared chunk grid: sweep the
/// unaligned head (`l → c0·C`), fold the full grid chunks inside the
/// window with the Chen combine, then extend through the unaligned
/// tail (`c1·C → r`) one Chen update at a time. Windows too short to
/// contain a full grid chunk fall back to a direct sweep.
#[allow(clippy::too_many_arguments)]
fn window_from_grid(
    tbl: &StreamTable,
    chunk_sigs: &[f64],
    path: &[f64],
    grid: Grid,
    b: usize,
    w: Window,
    ws: &mut TreeScratch,
    row: &mut [f64],
) {
    let ieng = &tbl.eng;
    let sl = tbl.state_len();
    let d = tbl.dim();
    let c0 = w.l.div_ceil(grid.chunk);
    let c1 = w.r / grid.chunk;
    if c1 <= c0 {
        // No full grid chunk inside the window: direct sweep.
        forward_sweep_range(ieng, path, w.l, w.r, &mut ws.s1, &mut ws.sdx);
    } else {
        // Head (identity when the window start is grid-aligned).
        forward_sweep_range(ieng, path, w.l, c0 * grid.chunk, &mut ws.s1, &mut ws.sdx);
        for k in c0..c1 {
            let u = grid.unit(b, k);
            ws.s2.resize(sl, 0.0);
            tbl.combine(&ws.s1[..sl], &chunk_sigs[u * sl..(u + 1) * sl], &mut ws.s2[..sl]);
            std::mem::swap(&mut ws.s1, &mut ws.s2);
        }
        // Tail: right-multiplying by exp(dx) is one Chen update.
        for j in (c1 * grid.chunk + 1)..=w.r {
            for i in 0..d {
                ws.sdx[i] = path[j * d + i] - path[(j - 1) * d + i];
            }
            chen_update(ieng, &mut ws.s1[..sl], &ws.sdx[..d]);
        }
    }
    tbl.project_into(&ws.s1[..sl], row);
}

fn windows_impl<const L: usize>(
    eng: &SigEngine,
    tbl: &StreamTable,
    paths: &[f64],
    per_path: usize,
    grid: Grid,
    windows: &[Window],
    out: &mut [f64],
) {
    let sl = tbl.state_len();
    let odim = tbl.out_dim();
    let kw = windows.len();
    let n_blocks = grid.units().div_ceil(L);
    let units = grid.batch * kw;
    let nw = eng.threads.min(n_blocks.max(units)).max(1);
    let mut bufs = eng.tree_pool.take_at_least(1);
    let mut workers = eng.tree_ctx_pool.take_at_least(nw);
    for w in workers.iter_mut().take(nw) {
        w.ensure(tbl, 0); // window folds never touch seg
    }
    let buf = &mut bufs[0];
    buf.chunk_sigs.resize(grid.units() * sl, 0.0);
    parallel_for_into(&mut buf.chunk_sigs, L * sl, &mut workers[..nw], |blk, rows, ws| {
        chunk_block_forward::<L>(tbl, paths, per_path, grid, blk * L, rows, ws);
    });
    let chunk_sigs: &[f64] = &buf.chunk_sigs;
    // One unit per (path, window) pair; unit u writes row u of the
    // (B, K, |I|) output. Grained claims keep the shared counter cold —
    // window folds are much cheaper than chunk sweeps.
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for_ctx_grained(units, 4, &mut workers[..nw], move |u, ws| {
        let out_ptr = out_ptr; // capture the wrapper, not its field
        let (b, wi) = (u / kw, u % kw);
        // SAFETY: each row index u is claimed exactly once; `out`
        // outlives the scoped workers.
        let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(u * odim), odim) };
        window_from_grid(
            tbl,
            chunk_sigs,
            &paths[b * per_path..(b + 1) * per_path],
            grid,
            b,
            windows[wi],
            ws,
            row,
        );
    });
    eng.tree_ctx_pool.put(workers);
    eng.tree_pool.put(bufs);
}

/// Time-parallel batched windowed signatures: the chunk grid is swept
/// once per call and shared by every window, so `K` overlapping
/// windows of length `w` cost `O(M + K·(C + w/C))` Chen-step
/// equivalents instead of `O(K·w)`. Window edges that don't land on
/// the grid are handled by per-window head/tail sweeps (the scheduler
/// snaps the chunk to the windows' start grid when one exists — see
/// [`crate::sig::schedule`]). `windowed_signatures_batch_into` routes
/// here automatically for long paths with small batches.
pub fn windowed_signatures_batch_tree_into(
    eng: &SigEngine,
    paths: &[f64],
    batch: usize,
    windows: &[Window],
    chunk: usize,
    out: &mut [f64],
) {
    assert!(batch > 0);
    assert_eq!(paths.len() % batch, 0);
    let per_path = paths.len() / batch;
    let d = eng.table.d;
    assert!(per_path % d == 0 && per_path / d >= 2, "bad path shape");
    let m1 = per_path / d;
    for w in windows {
        assert!(w.r < m1, "window right edge {} out of range (M={})", w.r, m1 - 1);
    }
    let odim = eng.out_dim();
    assert_eq!(out.len(), batch * windows.len() * odim, "output buffer has wrong size");
    if windows.is_empty() {
        return;
    }
    let (tbl, grid) = tree_setup(eng, batch, m1 - 1, chunk);
    lane_dispatch!(
        tbl.eng.lanes(),
        windows_impl(eng, &tbl, paths, per_path, grid, windows, out)
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{
        sig_backward_batch_scalar, signature, signature_batch_scalar, window_signature,
    };
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, Word, WordTable};

    fn trunc_engine(d: usize, n: usize) -> SigEngine {
        SigEngine::sequential(WordTable::build(d, &truncated_words(d, n)))
    }

    #[test]
    fn single_chunk_tree_is_bitwise_scalar() {
        // chunk ≥ steps ⇒ one chunk per path: the chunk sweep IS the
        // sequential lane sweep, and the reduction is a no-op.
        let mut rng = Rng::new(500);
        let eng = trunc_engine(2, 3);
        let path = rng.brownian_path(9, 2, 0.7);
        let mut out = vec![0.0; eng.out_dim()];
        signature_batch_tree_into(&eng, &path, 1, 100, &mut out);
        let want = signature(&eng, &path);
        assert_eq!(out, want, "single-chunk tree must be bitwise-sequential");
    }

    #[test]
    fn tree_forward_small_grid_matches_sequential() {
        let mut rng = Rng::new(501);
        let d = 3;
        let eng = trunc_engine(d, 3);
        let m = 23;
        for b in [1usize, 2, 5] {
            let mut paths = Vec::new();
            for _ in 0..b {
                paths.extend(rng.brownian_path(m, d, 0.5));
            }
            let want = signature_batch_scalar(&eng, &paths, b);
            for chunk in [1usize, 3, 7, 23] {
                let mut out = vec![0.0; b * eng.out_dim()];
                signature_batch_tree_into(&eng, &paths, b, chunk, &mut out);
                assert_allclose(&out, &want, 1e-12, 1e-12, &format!("B={b} C={chunk}"));
            }
        }
    }

    #[test]
    fn tree_forward_projected_set_uses_factor_closure() {
        // A sparse projected request whose prefix closure is NOT
        // suffix-closed: the combine must run over the factor closure
        // and still project back to the requested coordinates.
        let mut rng = Rng::new(502);
        let d = 3;
        let request = vec![Word(vec![2, 0, 1]), Word(vec![1, 1]), Word(vec![0, 2, 2, 1])];
        let eng = SigEngine::sequential(WordTable::build(d, &request));
        let path = rng.brownian_path(17, d, 0.6);
        let want = signature(&eng, &path);
        let mut out = vec![0.0; eng.out_dim()];
        signature_batch_tree_into(&eng, &path, 1, 4, &mut out);
        assert_allclose(&out, &want, 1e-12, 1e-12, "projected tree");
    }

    #[test]
    fn tree_backward_matches_scalar() {
        let mut rng = Rng::new(503);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let m = 19;
        for b in [1usize, 3] {
            let mut paths = Vec::new();
            let mut grads = Vec::new();
            for _ in 0..b {
                paths.extend(rng.brownian_path(m, d, 0.5));
                grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
            }
            let want = sig_backward_batch_scalar(&eng, &paths, &grads, b);
            for chunk in [1usize, 4, 19] {
                let mut out = vec![0.0; paths.len()];
                sig_backward_batch_tree_into(&eng, &paths, &grads, b, chunk, &mut out);
                assert_allclose(&out, &want, 1e-10, 1e-10, &format!("bwd B={b} C={chunk}"));
            }
        }
    }

    #[test]
    fn fused_tree_matches_two_phase_tree() {
        let mut rng = Rng::new(504);
        let d = 2;
        let eng = trunc_engine(d, 4);
        let b = 2;
        let m = 15;
        let mut paths = Vec::new();
        let mut grads = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 0.6));
            grads.extend((0..eng.out_dim()).map(|_| rng.gaussian()));
        }
        let mut sig = vec![0.0; b * eng.out_dim()];
        let mut grad = vec![0.0; paths.len()];
        signature_and_backward_batch_tree_into(&eng, &paths, &grads, b, 5, &mut sig, &mut grad);
        let mut grad_want = vec![0.0; paths.len()];
        sig_backward_batch_tree_into(&eng, &paths, &grads, b, 5, &mut grad_want);
        assert_eq!(grad, grad_want, "fused grad must equal backward-only grad");
        // The fused signature comes from the boundary prefix scan.
        let want = signature_batch_scalar(&eng, &paths, b);
        assert_allclose(&sig, &want, 1e-12, 1e-12, "fused sig");
    }

    #[test]
    fn tree_windows_match_direct_windows() {
        let mut rng = Rng::new(505);
        let d = 2;
        let eng = trunc_engine(d, 3);
        let m = 40;
        let path = rng.brownian_path(m, d, 0.5);
        // Aligned, unaligned, short (direct-sweep fallback) and
        // full-path windows.
        let wins = vec![
            Window::new(0, 40),
            Window::new(8, 24),
            Window::new(3, 29),
            Window::new(17, 19),
            Window::new(39, 40),
        ];
        let mut out = vec![0.0; wins.len() * eng.out_dim()];
        windowed_signatures_batch_tree_into(&eng, &path, 1, &wins, 8, &mut out);
        let odim = eng.out_dim();
        for (k, w) in wins.iter().enumerate() {
            let want = window_signature(&eng, &path, *w);
            assert_allclose(
                &out[k * odim..(k + 1) * odim],
                &want,
                1e-12,
                1e-12,
                &format!("window {k}"),
            );
        }
    }

    #[test]
    fn grid_split_roundtrips_both_packings() {
        for path_major in [true, false] {
            let grid = Grid {
                batch: 5,
                kk: 7,
                chunk: 4,
                steps: 26,
                path_major,
            };
            for b in 0..grid.batch {
                for k in 0..grid.kk {
                    assert_eq!(grid.split(grid.unit(b, k)), (b, k));
                }
            }
            // Last chunk is short: 26 - 6·4 = 2.
            assert_eq!(grid.chunk_len(6), 2);
            assert_eq!(grid.chunk_len(0), 4);
        }
    }
}
