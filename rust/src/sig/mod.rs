//! The pathsig core engine: batched forward/backward signature
//! computation in the word basis (paper §3–§4) and windowed signatures
//! (§5).
//!
//! The engine operates on a *state vector* indexed by the prefix closure
//! of the requested word set (state index 0 = ε, always 1.0), applying
//! Chen's relation once per time step with Horner's method
//! (Algorithm 1). Levels are processed **top-down** within a step so the
//! update is in-place: a level-`n` word reads only strictly shorter
//! prefixes, which still hold their step-`j-1` values.
//!
//! Parallelism mirrors the paper's CUDA mapping (§3.2) on two axes:
//! independent computational units are (path × window) pairs across the
//! thread pool, and **within** a unit the batch is cut into lane blocks
//! whose state matrices are lane-major, so the Horner inner loop is a
//! SIMD sweep over paths (see [`lanes`] and DESIGN.md's "Memory layout
//! & vectorization"). The backward pass batches the same way: the
//! cotangent sweep, group-inverse reconstruction, and ΔX-gradient all
//! run lane-major per block (see [`backward_step_lanes`]). Batch entry
//! points draw per-worker scratch from engine-owned pools, making
//! steady-state calls allocation-free.

mod backward;
mod forward;
mod kernel;
pub mod lanes;
pub mod schedule;
mod simd;
mod stream;
mod tree;
mod windows;

pub use backward::{
    sig_backward, sig_backward_batch, sig_backward_batch_from_states_into,
    sig_backward_batch_into, sig_backward_batch_scalar, sig_backward_into, sig_backward_ws,
    signature_and_backward_batch, signature_and_backward_batch_into,
    signature_batch_states_into, BackwardWorkspace,
};
pub(crate) use forward::forward_sweep_range;
pub use kernel::{gram, gram_cross, gram_cross_into, gram_into, RandomWords};
pub use forward::{
    chen_update, sig_forward_state, signature, signature_batch, signature_batch_into,
    signature_batch_scalar, signature_stream, signature_stream_into,
};
pub use lanes::{backward_step_lanes, chen_update_lanes, ForwardWorkspace, DEFAULT_LANE_WIDTH};
pub use schedule::{plan, ChunkPolicy, TimeMode, MIN_TIME_STEPS};
pub use simd::{Isa, Precision};
pub use stream::{MultiStream, StreamCheckpoint, StreamEngine, StreamScratch, StreamTable};
pub use tree::{
    sig_backward_batch_tree_into, signature_and_backward_batch_tree_into,
    signature_batch_tree_into, windowed_signatures_batch_tree_into,
};
pub use windows::{
    expanding_windows, sliding_windows, window_signature, windowed_signatures,
    windowed_signatures_batch, windowed_signatures_batch_into, windowed_signatures_into, Window,
};

use crate::util::envknob::warn_knob_once;
use crate::util::pool::Pool;
use crate::util::threadpool::default_threads;
use crate::words::WordTable;
use std::sync::{Arc, OnceLock};

/// Parse a raw `PATHSIG_LANES` value: a valid lane width (4/8/16/32)
/// passes through, everything else comes back as
/// [`DEFAULT_LANE_WIDTH`] plus the warning message [`SigEngine::new`]
/// prints (once). Pure — unit-testable per rejection path without
/// touching the process environment.
fn lane_width_from(env: Option<&str>) -> (usize, Option<String>) {
    let Some(raw) = env else {
        return (DEFAULT_LANE_WIDTH, None);
    };
    match raw.trim().parse::<usize>() {
        Ok(l @ (4 | 8 | 16 | 32)) => (l, None),
        _ => (
            DEFAULT_LANE_WIDTH,
            Some(format!(
                "ignoring invalid PATHSIG_LANES={raw:?} \
                 (supported: 4, 8, 16, 32); using {DEFAULT_LANE_WIDTH}"
            )),
        ),
    }
}

/// A word table bundled with the small precomputed constant tables the
/// kernels need (`1/k` and `1/k!`), the parallelism configuration, and
/// pooled per-worker scratch. Build once, reuse across calls.
#[derive(Clone, Debug)]
pub struct SigEngine {
    /// The prefix-closed word table driving the recursion.
    pub table: WordTable,
    /// `recip[k] = 1/k` for `k = 0..=N` (`recip[0]` unused).
    pub recip: Vec<f64>,
    /// `inv_fact[k] = 1/k!` for `k = 0..=N`.
    pub inv_fact: Vec<f64>,
    /// Worker threads for batch entry points (1 = sequential). Default:
    /// the `PATHSIG_THREADS` environment variable if set, else
    /// `available_parallelism` capped at 16.
    pub threads: usize,
    /// Lane width `L` of the lane-major batch kernel — how many paths
    /// one SIMD block carries. Valid values are 4, 8, 16 or 32 (other
    /// values fall back to [`DEFAULT_LANE_WIDTH`]); settable via the
    /// `PATHSIG_LANES` environment variable (a rejected value warns
    /// once on stderr). Batches with `B < L` use the scalar per-path
    /// kernel. The f32 inference path runs `2L` lanes per block
    /// ([`SigEngine::lanes_f32`]).
    pub lane_width: usize,
    /// Instruction set the lane kernels dispatch to (`PATHSIG_SIMD`):
    /// resolved to the best available ISA at construction, re-validated
    /// per kernel call, bitwise-equal to [`Isa::Scalar`] at any
    /// setting. Hand-set values that this CPU cannot run silently
    /// downgrade (AVX-512 → AVX2 → scalar, NEON → scalar).
    pub simd: Isa,
    /// Element precision of the *forward inference* path
    /// (`PATHSIG_PRECISION`): [`Precision::F32`] doubles effective
    /// SIMD lanes at single-precision accuracy (within 1e-5 of f64 on
    /// the conformance matrix). The backward pass, streaming and the
    /// time-parallel tree always run f64.
    pub precision: Precision,
    /// Time-axis chunking policy (`PATHSIG_TIME_CHUNK`): whether and
    /// how batch entry points may split long paths into concurrently
    /// swept chunks — see [`schedule`].
    pub time_chunk: ChunkPolicy,
    /// Pooled forward workspaces (one per worker, reused across calls).
    pub(crate) fwd_pool: Pool<ForwardWorkspace>,
    /// Pooled backward workspaces.
    pub(crate) bwd_pool: Pool<BackwardWorkspace>,
    /// Lazily built factor-closed combine table for the time-parallel
    /// tree (shared by clones — it is immutable once built).
    pub(crate) tree_tbl: OnceLock<Arc<StreamTable>>,
    /// Pooled shared buffers of the time-parallel engine.
    pub(crate) tree_pool: Pool<tree::TreeBuffers>,
    /// Pooled per-worker scratch of the time-parallel engine.
    pub(crate) tree_ctx_pool: Pool<tree::TreeScratch>,
    /// Pooled feature-matrix scratch of the Gram kernel ([`gram_into`]).
    pub(crate) gram_pool: Pool<kernel::GramScratch>,
}

impl SigEngine {
    /// Build an engine over a word table, sized to the machine's
    /// available parallelism (see [`default_threads`] — override with
    /// `PATHSIG_THREADS`).
    pub fn new(table: WordTable) -> SigEngine {
        let n = table.max_level;
        let recip: Vec<f64> = (0..=n + 1).map(|k| if k == 0 { 0.0 } else { 1.0 / k as f64 }).collect();
        let mut inv_fact = vec![1.0; n + 2];
        for k in 1..inv_fact.len() {
            inv_fact[k] = inv_fact[k - 1] / k as f64;
        }
        let (lane_width, lanes_warn) =
            lane_width_from(std::env::var("PATHSIG_LANES").ok().as_deref());
        if let Some(msg) = lanes_warn {
            warn_knob_once("PATHSIG_LANES", &msg);
        }
        let (time_chunk, chunk_warn) = schedule::chunk_policy_from_checked(
            std::env::var("PATHSIG_TIME_CHUNK").ok().as_deref(),
        );
        if let Some(msg) = chunk_warn {
            warn_knob_once("PATHSIG_TIME_CHUNK", &msg);
        }
        let (simd, simd_warn) = Isa::pick(std::env::var("PATHSIG_SIMD").ok().as_deref());
        if let Some(msg) = simd_warn {
            warn_knob_once("PATHSIG_SIMD", &msg);
        }
        let (precision, prec_warn) =
            simd::precision_from(std::env::var("PATHSIG_PRECISION").ok().as_deref());
        if let Some(msg) = prec_warn {
            warn_knob_once("PATHSIG_PRECISION", &msg);
        }
        SigEngine {
            table,
            recip,
            inv_fact,
            threads: default_threads(),
            lane_width,
            simd,
            precision,
            time_chunk,
            fwd_pool: Pool::default(),
            bwd_pool: Pool::default(),
            tree_tbl: OnceLock::new(),
            tree_pool: Pool::default(),
            tree_ctx_pool: Pool::default(),
            gram_pool: Pool::default(),
        }
    }

    /// Sequential engine (used by benches to isolate single-core cost).
    pub fn sequential(table: WordTable) -> SigEngine {
        let mut e = SigEngine::new(table);
        e.threads = 1;
        e
    }

    /// Engine with an explicit worker count (min 1).
    pub fn with_threads(table: WordTable, threads: usize) -> SigEngine {
        let mut e = SigEngine::new(table);
        e.threads = threads.max(1);
        e
    }

    /// Effective lane width: [`SigEngine::lane_width`] if valid
    /// (4/8/16/32), else [`DEFAULT_LANE_WIDTH`].
    #[inline]
    pub fn lanes(&self) -> usize {
        match self.lane_width {
            4 | 8 | 16 | 32 => self.lane_width,
            _ => DEFAULT_LANE_WIDTH,
        }
    }

    /// Effective f32 lane width: twice [`SigEngine::lanes`] — a
    /// [`Precision::F32`] block packs `2L` paths into the same
    /// register budget.
    #[inline]
    pub fn lanes_f32(&self) -> usize {
        2 * self.lanes()
    }

    /// The factor-closed combine table the time-parallel tree runs on,
    /// built lazily from the engine's requested words on first use and
    /// cached for the engine's lifetime (clones share it). Free — an
    /// identical table — for suffix-closed requests (truncated,
    /// anisotropic, DAG); general projected sets grow by at most
    /// `|w|²/2` state entries per requested word (see
    /// [`StreamTable`]).
    pub(crate) fn tree_table(&self) -> Arc<StreamTable> {
        self.tree_tbl
            .get_or_init(|| {
                let mut st = StreamTable::new(self.table.d, &self.table.requested);
                st.eng.threads = self.threads;
                st.eng.lane_width = self.lane_width;
                st.eng.simd = self.simd;
                st.eng.precision = self.precision;
                Arc::new(st)
            })
            .clone()
    }

    /// Output dimension `|I|`.
    pub fn out_dim(&self) -> usize {
        self.table.out_dim()
    }

    /// Closure state length (including ε).
    pub fn state_len(&self) -> usize {
        self.table.state_len
    }
}

/// Compute per-step increments of a row-major `(M+1, d)` path into `out`
/// (`(M, d)`).
pub fn increments(path: &[f64], d: usize, out: &mut [f64]) {
    let m1 = path.len() / d;
    debug_assert_eq!(path.len(), m1 * d);
    debug_assert_eq!(out.len(), (m1 - 1) * d);
    for j in 1..m1 {
        for i in 0..d {
            out[(j - 1) * d + i] = path[j * d + i] - path[(j - 1) * d + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::words::{truncated_words, WordTable};

    #[test]
    fn engine_constant_tables() {
        let e = SigEngine::new(WordTable::build(2, &truncated_words(2, 4)));
        assert!((e.recip[2] - 0.5).abs() < 1e-15);
        assert!((e.inv_fact[3] - 1.0 / 6.0).abs() < 1e-15);
        assert_eq!(e.out_dim(), 2 + 4 + 8 + 16);
        assert_eq!(e.state_len(), 1 + 30);
    }

    #[test]
    fn lane_width_normalisation() {
        let mut e = SigEngine::new(WordTable::build(2, &truncated_words(2, 2)));
        for valid in [4usize, 8, 16, 32] {
            e.lane_width = valid;
            assert_eq!(e.lanes(), valid);
            assert_eq!(e.lanes_f32(), 2 * valid);
        }
        e.lane_width = 7; // invalid → default
        assert_eq!(e.lanes(), DEFAULT_LANE_WIDTH);
        assert_eq!(e.lanes_f32(), 2 * DEFAULT_LANE_WIDTH);
    }

    #[test]
    fn lane_width_env_parsing() {
        // Valid widths and unset are warning-free (`lanes()` can then
        // only ever see 4/8/16/32 — the `lane_dispatch!` contract)…
        assert_eq!(lane_width_from(None), (DEFAULT_LANE_WIDTH, None));
        for valid in [4usize, 8, 16, 32] {
            assert_eq!(lane_width_from(Some(&valid.to_string())), (valid, None));
        }
        assert_eq!(lane_width_from(Some(" 16 ")), (16, None));
        // …every rejection path — wrong width, zero, negative, garbage,
        // empty — names the rejected value and the default used.
        for bad in ["5", "abc", "0", "-8", "", "8.0", "33"] {
            let (l, warn) = lane_width_from(Some(bad));
            assert_eq!(l, DEFAULT_LANE_WIDTH, "{bad}");
            let msg = warn.expect("rejected PATHSIG_LANES must warn");
            assert!(
                msg.contains("PATHSIG_LANES")
                    && msg.contains(bad)
                    && msg.contains(&DEFAULT_LANE_WIDTH.to_string()),
                "{msg}"
            );
        }
    }

    #[test]
    fn engine_simd_and_precision_defaults() {
        // Without env overrides the engine resolves to an ISA this
        // machine can actually run, at f64 (the training default) —
        // and clones/tree tables inherit both.
        let mut e = SigEngine::new(WordTable::build(2, &truncated_words(2, 3)));
        assert!(e.simd.available());
        if std::env::var("PATHSIG_PRECISION").is_err() {
            assert_eq!(e.precision, Precision::F64);
        }
        e.simd = Isa::Scalar;
        e.precision = Precision::F32;
        assert_eq!(e.clone().simd, Isa::Scalar);
        let tt = e.tree_table();
        assert_eq!(tt.eng.simd, Isa::Scalar);
        assert_eq!(tt.eng.precision, Precision::F32);
    }

    #[test]
    fn thread_count_configuration() {
        // Engines pick up `default_threads()` (which honours
        // `PATHSIG_THREADS` — its parsing is unit-tested in
        // `util::threadpool` without touching the process environment,
        // since `set_var` races parallel tests) and accept explicit
        // overrides.
        let e = SigEngine::new(WordTable::build(2, &truncated_words(2, 2)));
        assert!(e.threads >= 1);
        assert_eq!(e.threads, crate::util::threadpool::default_threads());
        let e5 = SigEngine::with_threads(WordTable::build(2, &truncated_words(2, 2)), 5);
        assert_eq!(e5.threads, 5);
        let clamped = SigEngine::with_threads(WordTable::build(2, &truncated_words(2, 2)), 0);
        assert_eq!(clamped.threads, 1);
    }

    #[test]
    fn engine_clone_has_fresh_pools() {
        let e = SigEngine::sequential(WordTable::build(2, &truncated_words(2, 3)));
        // Populate the pool via a batch call, then clone.
        let paths = vec![0.0; 2 * 4 * 2];
        let _ = signature_batch(&e, &paths, 2);
        let c = e.clone();
        assert_eq!(c.threads, e.threads);
        assert_eq!(c.table.state_len, e.table.state_len);
        // The clone computes correctly with its own (empty) pools.
        let _ = signature_batch(&c, &paths, 2);
    }

    #[test]
    fn increments_of_linear_path() {
        let path = [0.0, 0.0, 1.0, 2.0, 2.0, 4.0]; // (3,2)
        let mut dx = [0.0; 4];
        increments(&path, 2, &mut dx);
        assert_eq!(dx, [1.0, 2.0, 1.0, 2.0]);
    }
}
