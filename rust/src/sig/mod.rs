//! The pathsig core engine: batched forward/backward signature
//! computation in the word basis (paper §3–§4) and windowed signatures
//! (§5).
//!
//! The engine operates on a *state vector* indexed by the prefix closure
//! of the requested word set (state index 0 = ε, always 1.0), applying
//! Chen's relation once per time step with Horner's method
//! (Algorithm 1). Levels are processed **top-down** within a step so the
//! update is in-place: a level-`n` word reads only strictly shorter
//! prefixes, which still hold their step-`j-1` values.
//!
//! Parallelism mirrors the paper's CUDA mapping (§3.2): independent
//! computational units are (path × window) pairs; within a unit the word
//! table is swept sequentially with perfect locality. See
//! [`crate::util::threadpool`].

mod backward;
mod forward;
mod windows;

pub use backward::{sig_backward, sig_backward_batch, BackwardWorkspace};
pub use forward::{chen_update, sig_forward_state, signature, signature_batch, signature_stream};
pub use windows::{
    expanding_windows, sliding_windows, window_signature, windowed_signatures,
    windowed_signatures_batch, Window,
};

use crate::words::WordTable;

/// A word table bundled with the small precomputed constant tables the
/// kernels need (`1/k` and `1/k!`). Build once, reuse across calls.
#[derive(Clone, Debug)]
pub struct SigEngine {
    /// The prefix-closed word table driving the recursion.
    pub table: WordTable,
    /// `recip[k] = 1/k` for `k = 0..=N` (`recip[0]` unused).
    pub recip: Vec<f64>,
    /// `inv_fact[k] = 1/k!` for `k = 0..=N`.
    pub inv_fact: Vec<f64>,
    /// Worker threads for batch entry points (1 = sequential).
    pub threads: usize,
}

impl SigEngine {
    /// Build an engine over a word table, sized to the machine's
    /// available parallelism (capped at 16 workers).
    pub fn new(table: WordTable) -> SigEngine {
        let n = table.max_level;
        let recip: Vec<f64> = (0..=n + 1).map(|k| if k == 0 { 0.0 } else { 1.0 / k as f64 }).collect();
        let mut inv_fact = vec![1.0; n + 2];
        for k in 1..inv_fact.len() {
            inv_fact[k] = inv_fact[k - 1] / k as f64;
        }
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        SigEngine {
            table,
            recip,
            inv_fact,
            threads,
        }
    }

    /// Sequential engine (used by benches to isolate single-core cost).
    pub fn sequential(table: WordTable) -> SigEngine {
        let mut e = SigEngine::new(table);
        e.threads = 1;
        e
    }

    /// Engine with an explicit worker count (min 1).
    pub fn with_threads(table: WordTable, threads: usize) -> SigEngine {
        let mut e = SigEngine::new(table);
        e.threads = threads.max(1);
        e
    }

    /// Output dimension `|I|`.
    pub fn out_dim(&self) -> usize {
        self.table.out_dim()
    }

    /// Closure state length (including ε).
    pub fn state_len(&self) -> usize {
        self.table.state_len
    }
}

/// Compute per-step increments of a row-major `(M+1, d)` path into `out`
/// (`(M, d)`).
pub fn increments(path: &[f64], d: usize, out: &mut [f64]) {
    let m1 = path.len() / d;
    debug_assert_eq!(path.len(), m1 * d);
    debug_assert_eq!(out.len(), (m1 - 1) * d);
    for j in 1..m1 {
        for i in 0..d {
            out[(j - 1) * d + i] = path[j * d + i] - path[(j - 1) * d + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::words::{truncated_words, WordTable};

    #[test]
    fn engine_constant_tables() {
        let e = SigEngine::new(WordTable::build(2, &truncated_words(2, 4)));
        assert!((e.recip[2] - 0.5).abs() < 1e-15);
        assert!((e.inv_fact[3] - 1.0 / 6.0).abs() < 1e-15);
        assert_eq!(e.out_dim(), 2 + 4 + 8 + 16);
        assert_eq!(e.state_len(), 1 + 30);
    }

    #[test]
    fn increments_of_linear_path() {
        let path = [0.0, 0.0, 1.0, 2.0, 2.0, 4.0]; // (3,2)
        let mut dx = [0.0; 4];
        increments(&path, 2, &mut dx);
        assert_eq!(dx, [1.0, 2.0, 1.0, 2.0]);
    }
}
