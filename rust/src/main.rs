//! pathsig CLI — L3 entrypoint.
//!
//! ```text
//! pathsig serve        [--addr 127.0.0.1:7717] [--artifacts artifacts/]
//!                      [--max-batch 32] [--max-wait-ms 2]
//!                      [--shards 0] [--mailbox-cap 256] [--session-ttl-s 300]
//!                      [--journal-dir DIR] [--checkpoint-every 256] [--fsync]
//!                      [--sig-cache-cap 0] [--precision f64|f32]
//!                      [--durability strict|degraded] [--max-conns 0]
//!                      [--conn-timeout-s 0]
//! pathsig compute      --dim D --depth N [--steps M] [--seed S]
//!                      [--projection trunc|lyndon] [--json]
//! pathsig logsig       --dim D --depth N [--steps M] [--seed S]
//! pathsig windows      --dim D --depth N --steps M --win-len L --stride S
//! pathsig gen-fbm      --dim D --steps M --hurst H [--seed S] [--out f.json]
//! pathsig train-hurst  [--epochs E] [--train N] [--val N] [--variant trunc|sparse|fnn]
//! pathsig info         [--artifacts artifacts/]
//! ```

use pathsig::coordinator::{serve, BatcherConfig, ServerConfig, SigService};
use pathsig::fbm::{fbm_dataset, FbmMethod};
use pathsig::logsig::LogSigEngine;
use pathsig::runtime::Runtime;
use pathsig::sig::{signature, sliding_windows, Precision, SigEngine};
use pathsig::util::cli::Args;
use pathsig::util::json::Json;
use pathsig::util::rng::Rng;
use pathsig::words::{lyndon_words, truncated_words, WordTable};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("compute") => cmd_compute(&args),
        Some("logsig") => cmd_logsig(&args),
        Some("windows") => cmd_windows(&args),
        Some("gen-fbm") => cmd_gen_fbm(&args),
        Some("train-hurst") => cmd_train_hurst(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("pathsig {} — path signatures, word-basis engine + PJRT runtime", pathsig::VERSION);
            eprintln!("commands: serve | compute | logsig | windows | gen-fbm | train-hurst | info");
            2
        }
    };
    std::process::exit(code);
}

fn load_runtime(args: &Args) -> Option<Arc<Runtime>> {
    let dir = args.str_or("artifacts", "artifacts");
    match Runtime::new(Path::new(dir)) {
        Ok(rt) => {
            if rt.backend_available() {
                eprintln!(
                    "[pathsig] PJRT runtime up ({}, {} artifacts)",
                    rt.platform(),
                    rt.manifest.entries.len()
                );
            } else {
                eprintln!(
                    "[pathsig] artifact manifest loaded ({} artifacts) but no PJRT \
                     backend attached — native engine serves all requests",
                    rt.manifest.entries.len()
                );
            }
            Some(Arc::new(rt))
        }
        Err(e) => {
            eprintln!("[pathsig] no PJRT artifacts ({e}); native engine only");
            None
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let runtime = load_runtime(args);
    let mut service = SigService::new(runtime);
    // Sharded session table: 0 = auto (available parallelism, capped
    // at 8). The shard set spins up on the first stream op.
    service.shard_count = args.usize("shards", 0);
    service.mailbox_capacity = args.usize("mailbox-cap", 256);
    service.session_ttl = std::time::Duration::from_secs(args.u64("session-ttl-s", 300));
    service.max_sessions = args.usize("max-sessions", 1024);
    // Durability (off unless --journal-dir is given): shard workers
    // journal session ops and checkpoint engine state there, and a
    // restart on the same directory recovers every live session.
    service.journal_dir = args.get("journal-dir").map(std::path::PathBuf::from);
    service.checkpoint_every = args.u64("checkpoint-every", 256);
    service.fsync = args.flag("fsync");
    // Content-addressed cache of terminal signatures for the batch
    // `signature` verb (entries; 0 = disabled).
    service.sig_cache_cap = args.usize("sig-cache-cap", 0);
    // Inference precision of the batch forward path (overrides the
    // PATHSIG_PRECISION env default; training/streaming stay f64).
    service.precision = match args.get("precision") {
        None => None,
        Some(p) if p.eq_ignore_ascii_case("f64") => Some(Precision::F64),
        Some(p) if p.eq_ignore_ascii_case("f32") => Some(Precision::F32),
        Some(other) => {
            eprintln!("pathsig serve: invalid --precision {other:?} (expected f64 or f32)");
            return 2;
        }
    };
    // Durability policy: strict refuses to ack a session op whose
    // journal append failed; degraded (default) keeps serving from
    // memory and flips the sticky `degraded` health bit.
    service.durability = match args.get("durability") {
        None => pathsig::coordinator::DurabilityMode::Degraded,
        Some(m) if m.eq_ignore_ascii_case("strict") => pathsig::coordinator::DurabilityMode::Strict,
        Some(m) if m.eq_ignore_ascii_case("degraded") => {
            pathsig::coordinator::DurabilityMode::Degraded
        }
        Some(other) => {
            eprintln!("pathsig serve: invalid --durability {other:?} (expected strict or degraded)");
            return 2;
        }
    };
    let service = Arc::new(service);
    // Connection lifecycle: admission cap (0 = unlimited) and per-
    // connection read/write/idle deadline (0 = none).
    let conn_timeout_s = args.u64("conn-timeout-s", 0);
    let config = ServerConfig {
        addr: args.str_or("addr", "127.0.0.1:7717").to_string(),
        batcher: BatcherConfig {
            max_batch: args.usize("max-batch", 32),
            max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 2)),
            long_path_points: args.usize("long-path-points", 2048),
        },
        max_conns: args.usize("max-conns", 0),
        conn_timeout: (conn_timeout_s > 0)
            .then(|| std::time::Duration::from_secs(conn_timeout_s)),
    };
    match serve(service, config) {
        Ok(handle) => {
            println!("pathsig feature server listening on {}", handle.addr);
            // Keep running until killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("failed to bind: {e}");
            1
        }
    }
}

fn demo_path(args: &Args, d: usize) -> Vec<f64> {
    let steps = args.usize("steps", 64);
    let mut rng = Rng::new(args.u64("seed", 42));
    rng.brownian_path(steps, d, 1.0 / (steps as f64).sqrt())
}

fn cmd_compute(args: &Args) -> i32 {
    let d = args.usize("dim", 2);
    let n = args.usize("depth", 3);
    let path = demo_path(args, d);
    let words = match args.str_or("projection", "trunc") {
        "lyndon" => lyndon_words(d, n),
        _ => truncated_words(d, n),
    };
    let eng = SigEngine::new(WordTable::build(d, &words));
    let sig = signature(&eng, &path);
    if args.flag("json") {
        println!(
            "{}",
            Json::obj(vec![
                ("dim", Json::Num(d as f64)),
                ("depth", Json::Num(n as f64)),
                ("signature", Json::arr_f64(&sig)),
            ])
            .to_string()
        );
    } else {
        println!("signature of a random path (d={d}, N={n}, {} coords):", sig.len());
        for (w, v) in eng.table.requested.iter().zip(&sig).take(args.usize("show", 12)) {
            println!("  S({:<12}) = {v:+.6}", w.pretty());
        }
        if sig.len() > args.usize("show", 12) {
            println!("  … ({} more)", sig.len() - args.usize("show", 12));
        }
    }
    0
}

fn cmd_logsig(args: &Args) -> i32 {
    let d = args.usize("dim", 2);
    let n = args.usize("depth", 3);
    let path = demo_path(args, d);
    let eng = LogSigEngine::new(d, n);
    let out = eng.logsig(&path);
    println!("log-signature (Lyndon basis, d={d}, N={n}, {} coords):", out.len());
    for (w, v) in eng.lyndon.iter().zip(&out).take(args.usize("show", 12)) {
        println!("  logS({:<12}) = {v:+.6}", w.pretty());
    }
    0
}

fn cmd_windows(args: &Args) -> i32 {
    let d = args.usize("dim", 2);
    let n = args.usize("depth", 2);
    let path = demo_path(args, d);
    let m1 = path.len() / d;
    let wins = sliding_windows(m1, args.usize("win-len", 16), args.usize("stride", 8));
    let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, n)));
    let out = pathsig::sig::windowed_signatures(&eng, &path, &wins);
    println!(
        "{} sliding windows (len {}, stride {}), {} features each",
        wins.len(),
        args.usize("win-len", 16),
        args.usize("stride", 8),
        eng.out_dim()
    );
    for (k, w) in wins.iter().enumerate().take(args.usize("show", 6)) {
        let row = &out[k * eng.out_dim()..(k + 1) * eng.out_dim()];
        println!("  [{:>3}, {:>3})  ‖S‖₁ = {:.4}", w.l, w.r, row.iter().map(|x| x.abs()).sum::<f64>());
    }
    0
}

fn cmd_gen_fbm(args: &Args) -> i32 {
    let d = args.usize("dim", 1);
    let steps = args.usize("steps", 250);
    let h = args.f64("hurst", 0.5);
    let mut rng = Rng::new(args.u64("seed", 1));
    let path = pathsig::fbm::fbm_path(&mut rng, steps, d, h, FbmMethod::DaviesHarte);
    let j = Json::obj(vec![
        ("dim", Json::Num(d as f64)),
        ("steps", Json::Num(steps as f64)),
        ("hurst", Json::Num(h)),
        ("path", Json::arr_f64(&path)),
    ]);
    match args.get("out") {
        Some(f) => {
            if let Err(e) = std::fs::write(f, j.to_string()) {
                eprintln!("write failed: {e}");
                return 1;
            }
            println!("wrote {} points to {f}", steps + 1);
        }
        None => println!("{}", j.to_string()),
    }
    0
}

fn cmd_train_hurst(args: &Args) -> i32 {
    use pathsig::nn::{DeepSigModel, DeepSigSpec, Mlp};
    use pathsig::words::generate::{concat_generated_words, sparse_leadlag_generators};
    let dim = args.usize("dim", 5);
    let steps = args.usize("steps", 64);
    let depth = args.usize("depth", 3);
    let n_train = args.usize("train", 512);
    let n_val = args.usize("val", 128);
    let epochs = args.usize("epochs", 10);
    let batch = args.usize("batch", 32);
    let lr = args.f64("lr", 1e-3);
    let variant = args.str_or("variant", "sparse").to_string();
    let mut rng = Rng::new(args.u64("seed", 7));

    eprintln!("[train-hurst] generating {n_train}+{n_val} fBM paths (dim {dim}, {steps} steps)…");
    let (train_x, train_y) = fbm_dataset(&mut rng, n_train, steps, dim, 0.25, 0.75);
    let (val_x, val_y) = fbm_dataset(&mut rng, n_val, steps, dim, 0.25, 0.75);
    let per = (steps + 1) * dim;

    if variant == "fnn" {
        let mut mlp = Mlp::new(&mut rng, &[per, 128, 64, 1]);
        let mut t = 0;
        for epoch in 1..=epochs {
            let mut loss_acc = 0.0;
            let nb = n_train / batch;
            for bi in 0..nb {
                t += 1;
                let xs = &train_x[bi * batch * per..(bi + 1) * batch * per];
                let ys = &train_y[bi * batch..(bi + 1) * batch];
                loss_acc += mlp.train_step(xs, ys, batch, lr, t);
            }
            let val_pred = mlp.forward(&val_x, n_val);
            let val_mse = pathsig::nn::mse_loss(&val_pred, &val_y).0;
            println!("epoch {epoch:>3}  train {:.5}  val {val_mse:.5}", loss_acc / nb as f64);
        }
        return 0;
    }

    let words = if variant == "sparse" {
        concat_generated_words(2 * dim, depth, &sparse_leadlag_generators(dim))
    } else {
        truncated_words(2 * dim, depth)
    };
    eprintln!(
        "[train-hurst] variant {variant}: {} signature features (depth {depth})",
        words.len()
    );
    let spec = DeepSigSpec {
        dim,
        words,
        hidden: vec![64],
        lr,
    };
    let mut model = DeepSigModel::new(&mut rng, spec);
    for epoch in 1..=epochs {
        let mut loss_acc = 0.0;
        let nb = n_train / batch;
        for bi in 0..nb {
            let xs = &train_x[bi * batch * per..(bi + 1) * batch * per];
            let ys = &train_y[bi * batch..(bi + 1) * batch];
            loss_acc += model.train_step(xs, ys, batch);
        }
        let val_mse = model.mse(&val_x, &val_y, n_val);
        println!("epoch {epoch:>3}  train {:.5}  val {val_mse:.5}", loss_acc / nb as f64);
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    println!("pathsig {}", pathsig::VERSION);
    println!("threads available: {:?}", std::thread::available_parallelism());
    if let Some(rt) = load_runtime(args) {
        println!("PJRT platform: {}", rt.platform());
        for e in &rt.manifest.entries {
            println!(
                "  artifact {:<36} kind {:<12} in {:?} out {:?}",
                e.name,
                e.kind,
                e.inputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
                e.outputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>()
            );
        }
    }
    0
}
