//! Tensor exponential and logarithm as truncated power series (§3.3).
//!
//! On the group-like elements `G_{≤N}` these are mutually inverse
//! bijections with the free nilpotent Lie algebra `g_{≤N}`; `pathsig`
//! uses [`tensor_log_series`] to produce log-signatures.

use super::TruncTensor;

/// Truncated tensor logarithm `log(a)` for `a` with scalar part 1:
/// `log(1+y) = Σ_{m=1}^{N} (-1)^{m+1} y^{⊗m} / m`, evaluated with a
/// tensor-algebra Horner scheme (all terms are powers of the same `y`,
/// so one-sided Horner is exact).
pub fn tensor_log_series(a: &TruncTensor) -> TruncTensor {
    assert!(
        (a.levels[0][0] - 1.0).abs() < 1e-9,
        "tensor log needs scalar part 1 (group-like input)"
    );
    let mut y = a.clone();
    y.levels[0][0] = 0.0;
    let n = a.depth;
    if n == 0 {
        return TruncTensor::zero(a.d, 0);
    }
    // Horner: log = y ⊗ (c_1 + y ⊗ (c_2 + … )) with c_m = (-1)^{m+1}/m…
    // rearranged as P_N = c_N·1; P_m = c_m·1 + y ⊗ P_{m+1}; log = y ⊗ P_1.
    let mut p = TruncTensor::one(a.d, a.depth).scale(coef_log(n));
    for m in (1..n).rev() {
        p = TruncTensor::one(a.d, a.depth)
            .scale(coef_log(m))
            .add(&y.mul(&p));
    }
    y.mul(&p)
}

#[inline]
fn coef_log(m: usize) -> f64 {
    let s = if m % 2 == 1 { 1.0 } else { -1.0 };
    s / m as f64
}

/// Truncated tensor exponential `exp(a)` for `a` with scalar part 0:
/// `exp(y) = Σ_{m=0}^{N} y^{⊗m}/m!` via Horner.
pub fn tensor_exp_series(a: &TruncTensor) -> TruncTensor {
    assert!(
        a.levels[0][0].abs() < 1e-9,
        "tensor exp needs scalar part 0 (primitive-ish input)"
    );
    let n = a.depth;
    // Horner: exp = 1 + y(1/1! + y(1/2! + …)) ⇒ P_N = 1/N!·1;
    // P_m = 1/m!·1 + y ⊗ P_{m+1}; exp = 1 + y ⊗ P_1 … equivalently
    // exp = P_0 with P_m = 1/m!·1 + y⊗P_{m+1}? That telescopes wrong;
    // use the clean recursion: E = 1; for m = N..1: E = 1 + y⊗E/m.
    let mut e = TruncTensor::one(a.d, n);
    for m in (1..=n).rev() {
        e = TruncTensor::one(a.d, n).add(&a.mul(&e).scale(1.0 / m as f64));
    }
    e
}

/// Adjoint of the truncated product `C = A ⊗ B`: given cotangents `Ĉ`,
/// accumulate `Â(u) += Σ_v Ĉ(u∘v)·B(v)` and `B̂(v) += Σ_u A(u)·Ĉ(u∘v)`.
/// Reverse-mode building block for anything differentiating through
/// tensor products (log-signature backward, keras_sig-style baseline).
pub fn mul_adjoint(
    a: &TruncTensor,
    b: &TruncTensor,
    gc: &TruncTensor,
    ga: &mut TruncTensor,
    gb: &mut TruncTensor,
) {
    let depth = a.depth;
    for cn in 0..=depth {
        for k in 0..=cn {
            let (al, bl) = (a.levels[k].len(), b.levels[cn - k].len());
            let gcl = &gc.levels[cn];
            for i in 0..al {
                let ai = a.levels[k][i];
                let gai = &mut ga.levels[k][i];
                let base = i * bl;
                for j in 0..bl {
                    let g = gcl[base + j];
                    if g != 0.0 {
                        *gai += g * b.levels[cn - k][j];
                        gb.levels[cn - k][j] += ai * g;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_grouplike(rng: &mut Rng, d: usize, depth: usize, steps: usize) -> TruncTensor {
        // Product of per-step exponentials = signature of a random
        // piecewise-linear path ⇒ group-like by construction.
        let mut s = TruncTensor::one(d, depth);
        let mut scratch = Vec::new();
        for _ in 0..steps {
            let x: Vec<f64> = (0..d).map(|_| 0.5 * rng.gaussian()).collect();
            s.mul_assign(&TruncTensor::exp_level1(&x, depth), &mut scratch);
        }
        s
    }

    #[test]
    fn log_exp_roundtrip() {
        let mut rng = Rng::new(31);
        for depth in 1..=4 {
            let a = random_grouplike(&mut rng, 3, depth, 4);
            let log = tensor_log_series(&a);
            assert!(log.levels[0][0].abs() < 1e-12);
            let back = tensor_exp_series(&log);
            assert!(
                back.max_abs_diff(&a) < 1e-10,
                "depth={depth} diff={}",
                back.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn exp_log_roundtrip() {
        let mut rng = Rng::new(32);
        for _ in 0..5 {
            let mut a = TruncTensor::zero(2, 4);
            for n in 1..=4 {
                for x in &mut a.levels[n] {
                    *x = 0.3 * rng.gaussian();
                }
            }
            let e = tensor_exp_series(&a);
            let back = tensor_log_series(&e);
            assert!(back.max_abs_diff(&a) < 1e-10);
        }
    }

    #[test]
    fn exp_level1_consistency() {
        // tensor_exp_series of a level-1 embedding == exp_level1.
        let x = [0.7, -0.2, 0.1];
        let a = TruncTensor::from_level1(&x, 4);
        let e1 = tensor_exp_series(&a);
        let e2 = TruncTensor::exp_level1(&x, 4);
        assert!(e1.max_abs_diff(&e2) < 1e-12);
    }

    #[test]
    fn log_of_linear_path_signature_is_level1() {
        // The signature of a single linear segment is exp(Δx); its log
        // must be exactly the level-1 embedding of Δx (primitivity).
        let x = [1.2, -0.4];
        let sig = TruncTensor::exp_level1(&x, 5);
        let log = tensor_log_series(&sig);
        let want = TruncTensor::from_level1(&x, 5);
        assert!(log.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn log_level2_antisymmetric_for_grouplike() {
        // For group-like elements, log lives in the free Lie algebra;
        // at level 2 that means antisymmetry: log[i,j] = -log[j,i].
        let mut rng = Rng::new(33);
        let a = random_grouplike(&mut rng, 3, 3, 6);
        let log = tensor_log_series(&a);
        let d = 3;
        for i in 0..d {
            for j in 0..d {
                let lij = log.levels[2][i * d + j];
                let lji = log.levels[2][j * d + i];
                assert!(
                    (lij + lji).abs() < 1e-10,
                    "level-2 log not antisymmetric at ({i},{j})"
                );
            }
        }
    }
}
