//! Dense truncated tensor algebra `T_{≤N}(R^d)` (paper §2.1).
//!
//! This is the substrate the *comparator libraries* organise around
//! (§3.1: "existing methods organise around operations in the tensor
//! algebra on the graded sequence of tensors") — and therefore what our
//! [`crate::baselines`] are built on. The pathsig engines themselves work
//! in the word basis ([`crate::sig`]) and only use this module for the
//! tensor logarithm and cross-validation.
//!
//! A [`TruncTensor`] stores one dense coefficient vector per level,
//! `levels[n].len() == d^n`, index = the Appendix-A base-`d` word code.

mod ops;

pub use ops::{mul_adjoint, tensor_exp_series, tensor_log_series};

/// Element of the truncated tensor algebra `T_{≤N}(R^d)`.
#[derive(Clone, Debug, PartialEq)]
pub struct TruncTensor {
    /// Alphabet size / path dimension `d`.
    pub d: usize,
    /// Truncation depth `N`.
    pub depth: usize,
    /// `levels[n][code]` = coefficient of the word with base-`d`
    /// encoding `code` at level `n`; `levels[0]` is the scalar part.
    pub levels: Vec<Vec<f64>>,
}

impl TruncTensor {
    /// The multiplicative unit `1 ∈ T_{≤N}(R^d)`.
    pub fn one(d: usize, depth: usize) -> TruncTensor {
        let mut t = TruncTensor::zero(d, depth);
        t.levels[0][0] = 1.0;
        t
    }

    /// The zero element.
    pub fn zero(d: usize, depth: usize) -> TruncTensor {
        let levels = (0..=depth).map(|n| vec![0.0; d.pow(n as u32)]).collect();
        TruncTensor { d, depth, levels }
    }

    /// Embed a vector `x ∈ R^d` at level 1.
    pub fn from_level1(x: &[f64], depth: usize) -> TruncTensor {
        let mut t = TruncTensor::zero(x.len(), depth);
        t.levels[1].copy_from_slice(x);
        t
    }

    /// Total number of coefficients `Σ_{n=0}^N d^n`.
    pub fn len(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// Never empty — `levels[0]` always holds the scalar slot (paired
    /// with [`TruncTensor::len`] to satisfy the usual slice-like API).
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Flatten levels `1..=N` into one vector (canonical signature
    /// layout, level-major then lexicographic — matches
    /// [`crate::words::truncated_words`] order).
    pub fn flatten_nonscalar(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.len() - 1);
        for n in 1..=self.depth {
            out.extend_from_slice(&self.levels[n]);
        }
        out
    }

    /// Read a coefficient by word (letters, 0-based).
    pub fn coeff(&self, word: &[u16]) -> f64 {
        let n = word.len();
        assert!(n <= self.depth);
        let code = crate::words::encode::word_code(word, self.d) as usize;
        self.levels[n][code]
    }

    /// `self + other`.
    pub fn add(&self, other: &TruncTensor) -> TruncTensor {
        self.zip(other, |a, b| a + b)
    }

    /// `self - other`.
    pub fn sub(&self, other: &TruncTensor) -> TruncTensor {
        self.zip(other, |a, b| a - b)
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f64) -> TruncTensor {
        let mut out = self.clone();
        for lvl in &mut out.levels {
            for x in lvl {
                *x *= s;
            }
        }
        out
    }

    fn zip(&self, other: &TruncTensor, f: impl Fn(f64, f64) -> f64) -> TruncTensor {
        assert_eq!(self.d, other.d);
        assert_eq!(self.depth, other.depth);
        let levels = self
            .levels
            .iter()
            .zip(&other.levels)
            .map(|(a, b)| a.iter().zip(b).map(|(x, y)| f(*x, *y)).collect())
            .collect();
        TruncTensor {
            d: self.d,
            depth: self.depth,
            levels,
        }
    }

    /// Reset to the zero element of shape `(d, depth)`, reusing the
    /// level storage when the shape already matches — the
    /// allocation-free way to recycle a tensor across calls.
    pub fn reset_zero(&mut self, d: usize, depth: usize) {
        if self.d == d && self.depth == depth && self.levels.len() == depth + 1 {
            for lvl in &mut self.levels {
                lvl.fill(0.0);
            }
        } else {
            *self = TruncTensor::zero(d, depth);
        }
    }

    /// Overwrite `self` with a copy of `other`, reusing storage when
    /// shapes match (unlike the derived `clone_from`, which reallocates
    /// the level vectors).
    pub fn copy_from(&mut self, other: &TruncTensor) {
        self.reset_zero(other.d, other.depth);
        for (dst, src) in self.levels.iter_mut().zip(&other.levels) {
            dst.copy_from_slice(src);
        }
    }

    /// `self ← a ⊗ b`, overwriting — allocation-free when `self`
    /// already has the `(a.d, a.depth)` shape. Same Cauchy product as
    /// [`TruncTensor::mul`].
    pub fn mul_into(&mut self, a: &TruncTensor, b: &TruncTensor) {
        assert_eq!(a.d, b.d);
        assert_eq!(a.depth, b.depth);
        self.reset_zero(a.d, a.depth);
        for n in 0..=a.depth {
            let cn = &mut self.levels[n];
            for k in 0..=n {
                let av = &a.levels[k];
                let bv = &b.levels[n - k];
                let bl = bv.len();
                for (i, &ai) in av.iter().enumerate() {
                    if ai == 0.0 {
                        continue;
                    }
                    let base = i * bl;
                    for (j, &bj) in bv.iter().enumerate() {
                        cn[base + j] += ai * bj;
                    }
                }
            }
        }
    }

    /// Truncated tensor product `self ⊗ other` (Cauchy product, §2.1):
    /// `c_n = Σ_{k=0}^n a_k ⊗ b_{n-k}`, with
    /// `(a_k ⊗ b_m)[u∘v] = a_k[u]·b_m[v]` — an outer product in the flat
    /// base-`d` indexing (Proposition A.3 makes the index math a
    /// multiply-add).
    pub fn mul(&self, other: &TruncTensor) -> TruncTensor {
        assert_eq!(self.d, other.d);
        assert_eq!(self.depth, other.depth);
        let d = self.d;
        let mut out = TruncTensor::zero(d, self.depth);
        for n in 0..=self.depth {
            let cn = &mut out.levels[n];
            for k in 0..=n {
                let a = &self.levels[k];
                let b = &other.levels[n - k];
                if a.iter().all(|&x| x == 0.0) {
                    continue;
                }
                let bl = b.len();
                for (i, &ai) in a.iter().enumerate() {
                    if ai == 0.0 {
                        continue;
                    }
                    let base = i * bl;
                    for (j, &bj) in b.iter().enumerate() {
                        cn[base + j] += ai * bj;
                    }
                }
            }
        }
        out
    }

    /// In-place `self ← self ⊗ other` reusing a scratch buffer the size of
    /// the largest level (hot path of the baselines).
    pub fn mul_assign(&mut self, other: &TruncTensor, scratch: &mut Vec<f64>) {
        assert_eq!(self.d, other.d);
        assert_eq!(self.depth, other.depth);
        // Process levels top-down so lower levels of self (still "old")
        // feed higher outputs.
        for n in (0..=self.depth).rev() {
            scratch.clear();
            scratch.resize(self.levels[n].len(), 0.0);
            for k in 0..=n {
                let a = &self.levels[k];
                let b = &other.levels[n - k];
                let bl = b.len();
                for (i, &ai) in a.iter().enumerate() {
                    if ai == 0.0 {
                        continue;
                    }
                    let base = i * bl;
                    for (j, &bj) in b.iter().enumerate() {
                        scratch[base + j] += ai * bj;
                    }
                }
            }
            std::mem::swap(&mut self.levels[n], scratch);
        }
    }

    /// Tensor exponential of a level-1 element (Proposition 3.1):
    /// `exp(x) = Σ x^{⊗n}/n!` — the per-interval signature of a linear
    /// path with increment `x`.
    pub fn exp_level1(x: &[f64], depth: usize) -> TruncTensor {
        let d = x.len();
        let mut t = TruncTensor::one(d, depth);
        for n in 1..=depth {
            // levels[n] = levels[n-1] ⊗ x / n.
            let prev = t.levels[n - 1].clone();
            let inv_n = 1.0 / n as f64;
            let ln = &mut t.levels[n];
            for (i, &p) in prev.iter().enumerate() {
                for (j, &xj) in x.iter().enumerate() {
                    ln[i * d + j] = p * xj * inv_n;
                }
            }
        }
        t
    }

    /// Group inverse of a group-like element (`a_0 = 1`):
    /// `a^{-1} = Σ_m (-1)^m y^{⊗m}` with `y = a - 1` (used by the §5
    /// Chen-based windowing baseline and tests of Lemma 4.5).
    pub fn group_inverse(&self) -> TruncTensor {
        assert!(
            (self.levels[0][0] - 1.0).abs() < 1e-9,
            "group inverse needs scalar part 1"
        );
        let mut y = self.clone();
        y.levels[0][0] = 0.0;
        // Horner: inv = 1 - y(1 - y(1 - …)).
        let mut acc = TruncTensor::one(self.d, self.depth);
        for _ in 0..self.depth {
            acc = TruncTensor::one(self.d, self.depth).sub(&y.mul(&acc));
        }
        acc
    }

    /// Maximum absolute coefficient difference (diagnostics in tests).
    pub fn max_abs_diff(&self, other: &TruncTensor) -> f64 {
        self.levels
            .iter()
            .zip(&other.levels)
            .flat_map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y).abs()))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_is_identity() {
        let x = TruncTensor::exp_level1(&[0.3, -0.7], 3);
        let one = TruncTensor::one(2, 3);
        assert!(x.mul(&one).max_abs_diff(&x) < 1e-15);
        assert!(one.mul(&x).max_abs_diff(&x) < 1e-15);
    }

    #[test]
    fn mul_associative() {
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..10 {
            let a = TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian()], 4);
            let b = TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian()], 4);
            let c = TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian()], 4);
            let lhs = a.mul(&b).mul(&c);
            let rhs = a.mul(&b.mul(&c));
            assert!(lhs.max_abs_diff(&rhs) < 1e-12);
        }
    }

    #[test]
    fn mul_assign_matches_mul() {
        let mut rng = crate::util::rng::Rng::new(8);
        let a = TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian(), rng.gaussian()], 3);
        let b = TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian(), rng.gaussian()], 3);
        let want = a.mul(&b);
        let mut got = a.clone();
        let mut scratch = Vec::new();
        got.mul_assign(&b, &mut scratch);
        assert!(got.max_abs_diff(&want) < 1e-14);
    }

    #[test]
    fn exp_level1_coefficients() {
        // exp(x) at word (i1,…,in) = Π x_i / n!.
        let x = [2.0, -1.0];
        let e = TruncTensor::exp_level1(&x, 3);
        assert_eq!(e.levels[0][0], 1.0);
        assert_eq!(e.coeff(&[0]), 2.0);
        assert_eq!(e.coeff(&[1]), -1.0);
        assert!((e.coeff(&[0, 1]) - (2.0 * -1.0) / 2.0).abs() < 1e-15);
        assert!((e.coeff(&[0, 0, 1]) - (4.0 * -1.0) / 6.0).abs() < 1e-15);
    }

    #[test]
    fn exp_is_group_homomorphism_on_parallel_increments() {
        // exp(x) ⊗ exp(y) = exp(x+y) iff x ∥ y (same direction ⇒ the
        // BCH correction vanishes).
        let x = [0.4, 0.8];
        let y = [0.2, 0.4];
        let lhs = TruncTensor::exp_level1(&x, 4).mul(&TruncTensor::exp_level1(&y, 4));
        let rhs = TruncTensor::exp_level1(&[0.6, 1.2], 4);
        assert!(lhs.max_abs_diff(&rhs) < 1e-12);
    }

    #[test]
    fn group_inverse_inverts() {
        let mut rng = crate::util::rng::Rng::new(9);
        let a = TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian()], 4)
            .mul(&TruncTensor::exp_level1(&[rng.gaussian(), rng.gaussian()], 4));
        let inv = a.group_inverse();
        let prod = a.mul(&inv);
        assert!(prod.max_abs_diff(&TruncTensor::one(2, 4)) < 1e-12);
    }

    #[test]
    fn flatten_matches_word_order() {
        let e = TruncTensor::exp_level1(&[1.0, 2.0], 2);
        let flat = e.flatten_nonscalar();
        // Order: (0), (1), (0,0), (0,1), (1,0), (1,1).
        assert_eq!(flat.len(), 6);
        assert_eq!(flat[0], 1.0);
        assert_eq!(flat[1], 2.0);
        assert!((flat[3] - 1.0).abs() < 1e-15); // (0,1): 1·2/2
    }
}
