//! PJRT runtime — loads the AOT artifact manifest emitted by
//! `python/compile/aot.py` and executes artifacts through a pluggable
//! execution backend.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`):
//! jax ≥ 0.5 serialized `HloModuleProto`s carry 64-bit instruction ids
//! that older PJRT plugins reject, while the text parser reassigns ids
//! and round-trips cleanly (see DESIGN.md). Each artifact is described by
//! `artifacts/manifest.json`; executables are compiled once on first use
//! and cached by the backend.
//!
//! ## Backend plumbing
//!
//! The crate itself has no compiled-in XLA dependency — a concrete
//! PJRT client (e.g. the vendored `xla` crate's CPU client) is injected
//! through the [`PjrtBackend`] trait via [`Runtime::with_backend`].
//! PJRT client wrappers are typically `Rc`-based and not `Send`, so the
//! backend is **constructed inside a dedicated executor thread** (the
//! factory closure is `Send`; the backend itself need not be) and all
//! calls are serialized through a channel. The CPU client runs its own
//! intra-op thread pool, so one dispatcher thread is not a throughput
//! bottleneck; it just provides the `Send + Sync` boundary the server
//! needs.
//!
//! [`Runtime::new`] opens a manifest **without** a backend: artifact
//! metadata is queryable (the coordinator uses it for routing decisions)
//! but [`Runtime::run_f32`] reports the backend as unavailable and the
//! caller falls back to the native engine. This keeps the crate building
//! and testing with no AOT artifacts and no PJRT plugin present.
//!
//! Python never runs on this path — the Rust binary is self-contained
//! once `make artifacts` has produced the files.

use crate::util::json::Json;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Error type of the runtime layer (manifest I/O, validation, backend).
#[derive(Debug, Clone)]
pub struct RtError {
    msg: String,
}

impl RtError {
    /// Create an error from a message.
    pub fn new(msg: impl Into<String>) -> RtError {
        RtError { msg: msg.into() }
    }
}

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for RtError {}

/// Result alias used throughout the runtime layer.
pub type RtResult<T> = std::result::Result<T, RtError>;

fn err<T>(msg: impl Into<String>) -> RtResult<T> {
    Err(RtError::new(msg))
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Element type tag (currently always `"f32"`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total number of elements (product of the shape).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> TensorSpec {
        TensorSpec {
            shape: j.usize_vec("shape"),
            dtype: j.get("dtype").as_str().unwrap_or("f32").to_string(),
        }
    }
}

/// One manifest entry (a compiled computation).
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Unique artifact name (manifest key, used in requests and logs).
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Kind tag, e.g. `sig_fwd`, `sig_vjp`, `logsig_fwd`, `train_step`,
    /// `predict`, `windowed`.
    pub kind: String,
    /// Free-form metadata (batch/steps/dim/depth/wordset…).
    pub meta: Json,
    /// Input tensor specs, positional.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, positional (the AOT path lowers with
    /// `return_tuple=True`).
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All artifact entries, manifest order.
    pub entries: Vec<ManifestEntry>,
    /// Directory the manifest (and the HLO files) live in.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> RtResult<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| RtError::new(format!("reading {}: {e}", path.display())))?;
        let j = Json::parse(&text).map_err(|e| RtError::new(format!("parsing manifest: {e}")))?;
        let mut entries = Vec::new();
        for e in j.get("entries").as_arr().unwrap_or(&[]) {
            let inputs = e
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect();
            let outputs = e
                .get("outputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect();
            let name = match e.get("name").as_str() {
                Some(n) => n.to_string(),
                None => return err("manifest entry missing 'name'"),
            };
            let file = match e.get("file").as_str() {
                Some(f) => f.to_string(),
                None => return err(format!("manifest entry '{name}' missing 'file'")),
            };
            entries.push(ManifestEntry {
                name,
                file,
                kind: e.get("kind").as_str().unwrap_or("").to_string(),
                meta: e.get("meta").clone(),
                inputs,
                outputs,
            });
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Look an artifact up by name.
    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Entries of a given kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&ManifestEntry> {
        self.entries.iter().filter(|e| e.kind == kind).collect()
    }
}

/// One validated, shaped input buffer handed to a backend.
pub struct ArtifactInput<'a> {
    /// Flat row-major element data.
    pub data: &'a [f32],
    /// Row-major dimensions (matches the manifest spec).
    pub shape: &'a [usize],
}

/// A concrete PJRT (or PJRT-like) execution backend.
///
/// Implementations own the device client and the compiled-executable
/// cache. They are constructed *inside* the runtime's executor thread
/// (see the module docs), so they do not need to be `Send`. Inputs are
/// pre-validated against the manifest by [`Runtime::run_f32`]; outputs
/// are re-validated against the manifest after [`PjrtBackend::execute`]
/// returns.
pub trait PjrtBackend {
    /// Device platform name (e.g. `"cpu"`, `"cuda"`).
    fn platform(&self) -> String;

    /// Compile (and cache) the artifact stored as HLO text at `hlo_path`
    /// under the key `name`. Idempotent.
    fn compile(&mut self, name: &str, hlo_path: &Path) -> RtResult<()>;

    /// Execute a previously compiled (or compilable) artifact on `f32`
    /// inputs, returning one flat `f32` vector per output, in manifest
    /// order.
    fn execute(
        &mut self,
        name: &str,
        inputs: &[ArtifactInput<'_>],
        outputs: &[TensorSpec],
    ) -> RtResult<Vec<Vec<f32>>>;
}

/// Factory that builds a backend on the executor thread. The factory
/// must be `Send`; the backend it returns need not be.
pub type BackendFactory = Box<dyn FnOnce() -> RtResult<Box<dyn PjrtBackend>> + Send>;

enum RtMsg {
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: std::sync::mpsc::Sender<RtResult<Vec<Vec<f32>>>>,
    },
    Warm {
        name: String,
        reply: std::sync::mpsc::Sender<RtResult<()>>,
    },
    Shutdown,
}

struct Executor {
    tx: Mutex<std::sync::mpsc::Sender<RtMsg>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// `Send + Sync` handle to an artifact manifest plus (optionally) a
/// backend living on its own executor thread. See the module docs for
/// the two construction modes.
pub struct Runtime {
    /// Parsed artifact manifest (always available).
    pub manifest: Manifest,
    platform: String,
    exec: Option<Executor>,
}

impl Runtime {
    /// Open an artifact directory **without** an execution backend:
    /// metadata queries work, execution reports the backend as
    /// unavailable (callers fall back to the native engine).
    pub fn new(artifacts_dir: &Path) -> RtResult<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime {
            manifest,
            platform: "none".to_string(),
            exec: None,
        })
    }

    /// Open an artifact directory and spawn an executor thread running
    /// the backend produced by `factory` (see [`BackendFactory`]).
    pub fn with_backend(artifacts_dir: &Path, factory: BackendFactory) -> RtResult<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let thread_manifest = manifest.clone();
        let (tx, rx) = std::sync::mpsc::channel::<RtMsg>();
        let (init_tx, init_rx) = std::sync::mpsc::channel::<RtResult<String>>();
        let thread = std::thread::spawn(move || {
            let mut backend = match factory() {
                Ok(b) => {
                    let _ = init_tx.send(Ok(b.platform()));
                    b
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    RtMsg::Run {
                        name,
                        inputs,
                        reply,
                    } => {
                        let _ = reply.send(execute_on(
                            backend.as_mut(),
                            &thread_manifest,
                            &name,
                            &inputs,
                        ));
                    }
                    RtMsg::Warm { name, reply } => {
                        let _ = reply.send(warm_on(backend.as_mut(), &thread_manifest, &name));
                    }
                    RtMsg::Shutdown => break,
                }
            }
        });
        let platform = match init_rx.recv() {
            Ok(Ok(p)) => p,
            Ok(Err(e)) => return Err(e),
            Err(_) => return err("runtime executor thread died during init"),
        };
        Ok(Runtime {
            manifest,
            platform,
            exec: Some(Executor {
                tx: Mutex::new(tx),
                thread: Some(thread),
            }),
        })
    }

    /// Whether an execution backend is attached (false ⇒ metadata only).
    pub fn backend_available(&self) -> bool {
        self.exec.is_some()
    }

    /// Backend platform name; `"none"` when no backend is attached.
    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Pre-compile an artifact (e.g. at server start).
    pub fn warm(&self, name: &str) -> RtResult<()> {
        let exec = match &self.exec {
            Some(e) => e,
            None => return err(no_backend_msg(name)),
        };
        let (reply, rx) = std::sync::mpsc::channel();
        exec.tx
            .lock()
            .unwrap()
            .send(RtMsg::Warm {
                name: name.to_string(),
                reply,
            })
            .map_err(|_| RtError::new("runtime executor thread gone"))?;
        rx.recv()
            .map_err(|_| RtError::new("runtime executor thread gone"))?
    }

    /// Execute an artifact on `f32` inputs. Inputs are validated against
    /// the manifest specs; outputs come back as flat `f32` vectors in
    /// manifest order.
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> RtResult<Vec<Vec<f32>>> {
        // Validate eagerly so shape errors surface on the caller thread
        // even before touching the backend.
        let entry = match self.manifest.find(name) {
            Some(e) => e,
            None => return err(format!("no artifact named '{name}' in manifest")),
        };
        validate_inputs(entry, inputs)?;
        let exec = match &self.exec {
            Some(e) => e,
            None => return err(no_backend_msg(name)),
        };
        let (reply, rx) = std::sync::mpsc::channel();
        exec.tx
            .lock()
            .unwrap()
            .send(RtMsg::Run {
                name: name.to_string(),
                inputs: inputs.iter().map(|s| s.to_vec()).collect(),
                reply,
            })
            .map_err(|_| RtError::new("runtime executor thread gone"))?;
        rx.recv()
            .map_err(|_| RtError::new("runtime executor thread gone"))?
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        if let Some(exec) = &mut self.exec {
            let _ = exec.tx.lock().unwrap().send(RtMsg::Shutdown);
            if let Some(h) = exec.thread.take() {
                let _ = h.join();
            }
        }
    }
}

fn no_backend_msg(name: &str) -> String {
    format!(
        "cannot execute '{name}': no PJRT backend attached — construct the \
         runtime with Runtime::with_backend (see runtime module docs and \
         DESIGN.md); the native engine serves every request shape"
    )
}

fn validate_inputs(entry: &ManifestEntry, inputs: &[&[f32]]) -> RtResult<()> {
    if inputs.len() != entry.inputs.len() {
        return err(format!(
            "{}: expected {} inputs, got {}",
            entry.name,
            entry.inputs.len(),
            inputs.len()
        ));
    }
    for (k, (data, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
        if data.len() != spec.numel() {
            return err(format!(
                "{} input {k}: expected {} elements (shape {:?}), got {}",
                entry.name,
                spec.numel(),
                spec.shape,
                data.len()
            ));
        }
    }
    Ok(())
}

/// Executor-thread body of one `run_f32` call: re-resolve the entry,
/// ensure compilation, execute, validate outputs.
fn execute_on(
    backend: &mut dyn PjrtBackend,
    manifest: &Manifest,
    name: &str,
    inputs: &[Vec<f32>],
) -> RtResult<Vec<Vec<f32>>> {
    let entry = match manifest.find(name) {
        Some(e) => e,
        None => return err(format!("no artifact named '{name}' in manifest")),
    };
    backend.compile(name, &manifest.dir.join(&entry.file))?;
    let shaped: Vec<ArtifactInput<'_>> = inputs
        .iter()
        .zip(&entry.inputs)
        .map(|(data, spec)| ArtifactInput {
            data: data.as_slice(),
            shape: spec.shape.as_slice(),
        })
        .collect();
    let out = backend.execute(name, &shaped, &entry.outputs)?;
    if out.len() != entry.outputs.len() {
        return err(format!(
            "{name}: manifest promises {} outputs, backend returned {}",
            entry.outputs.len(),
            out.len()
        ));
    }
    for (k, (v, spec)) in out.iter().zip(&entry.outputs).enumerate() {
        if v.len() != spec.numel() {
            return err(format!(
                "{name} output {k}: expected {} elements, got {}",
                spec.numel(),
                v.len()
            ));
        }
    }
    Ok(out)
}

fn warm_on(backend: &mut dyn PjrtBackend, manifest: &Manifest, name: &str) -> RtResult<()> {
    let entry = match manifest.find(name) {
        Some(e) => e,
        None => return err(format!("no artifact named '{name}' in manifest")),
    };
    backend.compile(name, &manifest.dir.join(&entry.file))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("pathsig_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "entries": [
                {"name": "sig_fwd_a", "file": "a.hlo.txt", "kind": "sig_fwd",
                 "meta": {"depth": 3, "dim": 2},
                 "inputs": [{"shape": [4, 17, 2], "dtype": "f32"}],
                 "outputs": [{"shape": [4, 14], "dtype": "f32"}]}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("sig_fwd_a").unwrap();
        assert_eq!(e.kind, "sig_fwd");
        assert_eq!(e.inputs[0].numel(), 4 * 17 * 2);
        assert_eq!(e.meta.get("depth").as_usize(), Some(3));
        assert!(m.find("nope").is_none());
        assert_eq!(m.by_kind("sig_fwd").len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("pathsig_definitely_missing_dir_xyz");
        assert!(Manifest::load(&dir).is_err());
    }

    fn write_test_manifest(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pathsig_runtime_test_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries": [
                {"name": "double", "file": "double.hlo.txt", "kind": "demo",
                 "meta": {},
                 "inputs": [{"shape": [2, 3], "dtype": "f32"}],
                 "outputs": [{"shape": [2, 3], "dtype": "f32"}]}
            ]}"#,
        )
        .unwrap();
        std::fs::write(dir.join("double.hlo.txt"), "HloModule double\n").unwrap();
        dir
    }

    /// Mock backend: "executes" by doubling every input element.
    struct DoublingBackend {
        compiled: Vec<String>,
    }

    impl PjrtBackend for DoublingBackend {
        fn platform(&self) -> String {
            "mock".to_string()
        }

        fn compile(&mut self, name: &str, hlo_path: &Path) -> RtResult<()> {
            if !hlo_path.exists() {
                return err(format!("missing HLO file {}", hlo_path.display()));
            }
            if !self.compiled.iter().any(|n| n == name) {
                self.compiled.push(name.to_string());
            }
            Ok(())
        }

        fn execute(
            &mut self,
            _name: &str,
            inputs: &[ArtifactInput<'_>],
            _outputs: &[TensorSpec],
        ) -> RtResult<Vec<Vec<f32>>> {
            Ok(inputs
                .iter()
                .map(|i| i.data.iter().map(|x| 2.0 * x).collect())
                .collect())
        }
    }

    #[test]
    fn backendless_runtime_reads_metadata_but_cannot_execute() {
        let dir = write_test_manifest("meta");
        let rt = Runtime::new(&dir).unwrap();
        assert!(!rt.backend_available());
        assert_eq!(rt.platform(), "none");
        assert_eq!(rt.manifest.entries.len(), 1);
        let e = rt.run_f32("double", &[&[0.0; 6]]).unwrap_err();
        assert!(e.to_string().contains("no PJRT backend"), "{e}");
        assert!(rt.warm("double").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mock_backend_executes_through_the_executor_thread() {
        let dir = write_test_manifest("exec");
        let rt = Runtime::with_backend(
            &dir,
            Box::new(|| {
                Ok(Box::new(DoublingBackend {
                    compiled: Vec::new(),
                }) as Box<dyn PjrtBackend>)
            }),
        )
        .unwrap();
        assert!(rt.backend_available());
        assert_eq!(rt.platform(), "mock");
        rt.warm("double").unwrap();
        let input = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = rt.run_f32("double", &[&input]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let dir = write_test_manifest("shape");
        let rt = Runtime::with_backend(
            &dir,
            Box::new(|| {
                Ok(Box::new(DoublingBackend {
                    compiled: Vec::new(),
                }) as Box<dyn PjrtBackend>)
            }),
        )
        .unwrap();
        // Wrong element count.
        assert!(rt.run_f32("double", &[&[1.0f32; 5]]).is_err());
        // Wrong input arity.
        assert!(rt
            .run_f32("double", &[&[1.0f32; 6], &[1.0f32; 6]])
            .is_err());
        // Unknown artifact.
        assert!(rt.run_f32("nope", &[&[1.0f32; 6]]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failing_backend_factory_surfaces_the_error() {
        let dir = write_test_manifest("fail");
        let got = Runtime::with_backend(&dir, Box::new(|| err("plugin not found")));
        assert!(got.is_err());
        assert!(got.err().unwrap().to_string().contains("plugin not found"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
