//! PJRT runtime — loads and executes the AOT artifacts emitted by
//! `python/compile/aot.py`.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`): the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized
//! `HloModuleProto`s (64-bit instruction ids), while the text parser
//! reassigns ids and round-trips cleanly (see `/opt/xla-example` and
//! DESIGN.md). Each artifact is described by `artifacts/manifest.json`;
//! executables are compiled once on first use and cached.
//!
//! Python never runs on this path — the Rust binary is self-contained
//! once `make artifacts` has produced the files.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j.usize_vec("shape"),
            dtype: j.get("dtype").as_str().unwrap_or("f32").to_string(),
        })
    }
}

/// One manifest entry (a compiled computation).
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    /// Kind tag, e.g. `sig_fwd`, `sig_vjp`, `logsig_fwd`, `train_step`,
    /// `predict`, `windowed`.
    pub kind: String,
    /// Free-form metadata (batch/steps/dim/depth/wordset…).
    pub meta: Json,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let mut entries = Vec::new();
        for e in j.get("entries").as_arr().unwrap_or(&[]) {
            let inputs = e
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry {
                name: e
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                file: e
                    .get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                kind: e.get("kind").as_str().unwrap_or("").to_string(),
                meta: e.get("meta").clone(),
                inputs,
                outputs,
            });
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Entries of a given kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&ManifestEntry> {
        self.entries.iter().filter(|e| e.kind == kind).collect()
    }
}

/// PJRT client + compiled-executable cache. **Not `Send`** — the `xla`
/// crate's wrappers are `Rc`-based — so the shared-server entry point is
/// [`Runtime`] (a channel handle to a dedicated executor thread); this
/// inner type is what that thread owns. Single-threaded binaries
/// (examples, benches) may use it directly.
pub struct RuntimeInner {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl RuntimeInner {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<RuntimeInner> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(RuntimeInner {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by manifest name.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}' in manifest"))?
            .clone();
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on `f32` inputs. Inputs are validated against
    /// the manifest specs; outputs come back as flat `f32` vectors in
    /// manifest order (the AOT path lowers with `return_tuple=True`).
    pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (data, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if data.len() != spec.numel() {
                bail!(
                    "{name} input {k}: expected {} elements (shape {:?}), got {}",
                    spec.numel(),
                    spec.shape,
                    data.len()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&s| s as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {k}: {e:?}"))?;
            literals.push(lit);
        }
        self.ensure_compiled(name)?;
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "{name}: manifest promises {} outputs, executable returned {}",
                entry.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (k, (p, spec)) in parts.iter().zip(&entry.outputs).enumerate() {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output {k} to_vec: {e:?}"))?;
            if v.len() != spec.numel() {
                bail!(
                    "{name} output {k}: expected {} elements, got {}",
                    spec.numel(),
                    v.len()
                );
            }
            out.push(v);
        }
        Ok(out)
    }
}

// ------------------------------------------------------------------
// Thread-confined runtime handle
// ------------------------------------------------------------------

enum RtMsg {
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: std::sync::mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Warm {
        name: String,
        reply: std::sync::mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// `Send + Sync` handle to a PJRT runtime living on its own executor
/// thread. All PJRT calls are serialized through a channel — the CPU
/// client runs its own intra-op thread pool, so one dispatcher thread is
/// not a throughput bottleneck; it just provides the `Send` boundary the
/// `Rc`-based wrappers need.
pub struct Runtime {
    pub manifest: Manifest,
    platform: String,
    tx: Mutex<std::sync::mpsc::Sender<RtMsg>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// Spawn the executor thread over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = std::sync::mpsc::channel::<RtMsg>();
        let (init_tx, init_rx) = std::sync::mpsc::channel::<Result<(Manifest, String)>>();
        let thread = std::thread::spawn(move || {
            let mut inner = match RuntimeInner::new(&dir) {
                Ok(i) => {
                    let _ = init_tx.send(Ok((i.manifest.clone(), i.platform())));
                    i
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    RtMsg::Run {
                        name,
                        inputs,
                        reply,
                    } => {
                        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
                        let _ = reply.send(inner.run_f32(&name, &refs));
                    }
                    RtMsg::Warm { name, reply } => {
                        let _ = reply.send(inner.ensure_compiled(&name));
                    }
                    RtMsg::Shutdown => break,
                }
            }
        });
        let (manifest, platform) = init_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during init"))??;
        Ok(Runtime {
            manifest,
            platform,
            tx: Mutex::new(tx),
            thread: Some(thread),
        })
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Pre-compile an artifact (e.g. at server start).
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(RtMsg::Warm {
                name: name.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread gone"))?
    }

    /// Execute an artifact (see [`RuntimeInner::run_f32`]).
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(RtMsg::Run {
                name: name.to_string(),
                inputs: inputs.iter().map(|s| s.to_vec()).collect(),
                reply,
            })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread gone"))?
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(RtMsg::Shutdown);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("pathsig_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "entries": [
                {"name": "sig_fwd_a", "file": "a.hlo.txt", "kind": "sig_fwd",
                 "meta": {"depth": 3, "dim": 2},
                 "inputs": [{"shape": [4, 17, 2], "dtype": "f32"}],
                 "outputs": [{"shape": [4, 14], "dtype": "f32"}]}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("sig_fwd_a").unwrap();
        assert_eq!(e.kind, "sig_fwd");
        assert_eq!(e.inputs[0].numel(), 4 * 17 * 2);
        assert_eq!(e.meta.get("depth").as_usize(), Some(3));
        assert!(m.find("nope").is_none());
        assert_eq!(m.by_kind("sig_fwd").len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("pathsig_definitely_missing_dir_xyz");
        assert!(Manifest::load(&dir).is_err());
    }
}
