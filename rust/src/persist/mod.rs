//! Durability subsystem: crash-safe session journals, checkpointed
//! recovery, and a content-addressed signature cache.
//!
//! The coordinator's streaming sessions (see [`crate::coordinator`])
//! are long-lived sliding windows whose state is expensive to rebuild
//! from raw ticks. This module makes that state survive process death
//! with zero new dependencies:
//!
//! * [`codec`] — length-prefixed binary records in the wire-v2 idiom,
//!   each carrying a kind byte, a monotone sequence number and a
//!   CRC-32 checksum (zlib-compatible, so the Python golden generator
//!   mirrors it byte-for-byte);
//! * [`journal`] — per-shard append-only journals plus atomically
//!   renamed checkpoints of the two-stack
//!   [`crate::sig::StreamEngine`] state, and the boot-time recovery
//!   scan (checkpoint load + short tail replay, torn tails truncated,
//!   tombstones honored);
//! * [`cache`] — a bounded content-addressed cache of terminal
//!   signatures keyed by (word-set manifest sha256, path-increments
//!   hash), consulted by the batch `signature` verb;
//! * [`sha256`] — the from-scratch SHA-256 backing those keys.
//!
//! Durability is **off by default**: without `--journal-dir` the
//! coordinator touches no files and every existing code path is
//! bitwise unchanged.

pub mod cache;
pub mod codec;
pub mod journal;
pub mod sha256;

pub use cache::{cache_key, CacheStats, SigCache};
pub use journal::{
    ckpt_path, journal_path, recover_dir, repartition, write_checkpoint, JournalWriter,
    Recovery, RecoveredSession, RecoveryStats,
};

use std::path::PathBuf;

/// What a shard worker does when a journal append (or its fsync)
/// fails while durability is on (CLI: `--durability=strict|degraded`).
///
/// This is *policy made explicit*: before ISSUE 10, an append failure
/// was counted in `journal_errors` and the op was acked anyway — the
/// "durable" coordinator silently became non-durable. Now the operator
/// chooses:
///
/// * [`Strict`](DurabilityMode::Strict) — never ack non-durable work.
///   The op is rejected with an error reply (the engine state is not
///   advanced), so everything a client ever saw acked has a journal
///   record and survives a crash.
/// * [`Degraded`](DurabilityMode::Degraded) — keep serving from
///   memory (today's behavior), but flip a sticky, *visible* degraded
///   bit surfaced in v1 `stats` and the v2 `health` verb so monitoring
///   can page a human instead of discovering the gap after the crash.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DurabilityMode {
    /// Reject (never ack) any op whose journal record cannot be made
    /// durable.
    Strict,
    /// Ack from memory on journal failure, but announce the loss of
    /// durability via the degraded health bit. The default — matches
    /// the pre-ISSUE-10 behavior, now visible.
    #[default]
    Degraded,
}

/// Coordinator durability knobs (CLI: `--journal-dir`,
/// `--checkpoint-every`, `--fsync`, `--durability`). Carried inside
/// [`crate::coordinator::ShardConfig`]; `None` there means durability
/// is off and no persistence code runs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DurabilityConfig {
    /// Directory holding `shard-{i}.journal` / `shard-{i}.ckpt`.
    pub dir: PathBuf,
    /// Write a checkpoint (and truncate the journal) every this many
    /// journaled ops per shard.
    pub checkpoint_every: u64,
    /// `fdatasync` after every journal append (slower, but a crash
    /// loses at most the record being written).
    pub fsync: bool,
    /// Per-session float budget recovery must respect when
    /// re-admitting sessions (mirrors the service's
    /// `max_session_floats`; `usize::MAX` = unbounded).
    pub max_session_floats: usize,
    /// Journal-failure policy: strict (shed non-durable work) or
    /// degraded (ack + flip the health bit).
    pub mode: DurabilityMode,
}

impl DurabilityConfig {
    /// Defaults matching the CLI: checkpoint every 256 ops, no fsync,
    /// unbounded per-session floats, degraded-mode failure policy.
    pub fn new(dir: PathBuf) -> DurabilityConfig {
        DurabilityConfig {
            dir,
            checkpoint_every: 256,
            fsync: false,
            max_session_floats: usize::MAX,
            mode: DurabilityMode::Degraded,
        }
    }
}
