//! Bounded content-addressed cache of terminal signatures.
//!
//! The batch `signature` verb is frequently replayed with identical
//! inputs (retries, fan-out duplication, idempotent pipelines). The
//! cache keys each result by content, never by request identity:
//!
//! ```text
//! key = SHA-256( manifest_digest ‖ path_digest )
//! manifest_digest = SHA-256("pathsig-manifest v1\ndim {d}\nspec {spec}\n")
//! path_digest     = SHA-256(increments x_{j} − x_{j−1}, f64 LE bytes)
//! ```
//!
//! The manifest is a tiny self-describing text block (the
//! manifest+sha256 idiom), so two requests hit the same entry iff they
//! agree on the word-set configuration *and* on the path increments —
//! hashing increments rather than samples means a translated path
//! (which has the same signature) shares the entry. Eviction is FIFO
//! by insertion order, bounded by entry count; hits, misses and
//! evictions are counted for `stats_json` and the v2 `stats` verb.

use super::sha256::Sha256;
use std::collections::{HashMap, VecDeque};

/// Point-in-time cache counters (also carried in v2 `stats` frames).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to a real computation.
    pub misses: u64,
    /// Entries displaced by the capacity bound.
    pub evictions: u64,
}

/// Compute the content key for a `(dim, spec, path)` request. `spec_id`
/// is the coordinator's canonical spec-identity string; `path` is the
/// flat row-major sample buffer.
pub fn cache_key(dim: usize, spec_id: &str, path: &[f64]) -> [u8; 32] {
    let mut manifest = Sha256::new();
    manifest.update(format!("pathsig-manifest v1\ndim {dim}\nspec {spec_id}\n").as_bytes());
    let mut incr = Sha256::new();
    if dim > 0 {
        let mut buf = [0u8; 8];
        for j in 1..path.len() / dim {
            for i in 0..dim {
                let dx = path[j * dim + i] - path[(j - 1) * dim + i];
                buf.copy_from_slice(&dx.to_le_bytes());
                incr.update(&buf);
            }
        }
    }
    let mut key = Sha256::new();
    key.update(&manifest.finish());
    key.update(&incr.finish());
    key.finish()
}

/// Bounded FIFO map from content keys to terminal signature vectors.
/// Capacity 0 disables the cache entirely (every lookup misses without
/// counting — the durability-off configuration stays bitwise-silent).
#[derive(Debug, Default)]
pub struct SigCache {
    capacity: usize,
    map: HashMap<[u8; 32], Vec<f64>>,
    order: VecDeque<[u8; 32]>,
    stats: CacheStats,
}

impl SigCache {
    /// Cache bounded to `capacity` entries (0 = disabled).
    pub fn new(capacity: usize) -> SigCache {
        SigCache {
            capacity,
            map: HashMap::new(),
            order: VecDeque::new(),
            stats: CacheStats::default(),
        }
    }

    /// Whether inserts/lookups do anything at all.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries are held.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Look up a key, counting the hit or miss.
    pub fn get(&mut self, key: &[u8; 32]) -> Option<&[f64]> {
        if !self.enabled() {
            return None;
        }
        match self.map.get(key) {
            Some(v) => {
                self.stats.hits += 1;
                Some(v.as_slice())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert a computed signature, evicting the oldest entry when at
    /// capacity. Re-inserting an existing key refreshes the value
    /// without growing the order queue.
    pub fn insert(&mut self, key: [u8; 32], value: Vec<f64>) {
        if !self.enabled() {
            return;
        }
        if self.map.insert(key, value).is_some() {
            return;
        }
        self.order.push_back(key);
        while self.map.len() > self.capacity {
            if let Some(old) = self.order.pop_front() {
                if self.map.remove(&old).is_some() {
                    self.stats.evictions += 1;
                }
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_depend_on_increments_not_offsets() {
        let a = cache_key(2, "trunc:3", &[0.0, 0.0, 1.0, 2.0, 3.0, 5.0]);
        let b = cache_key(2, "trunc:3", &[10.0, -4.0, 11.0, -2.0, 13.0, 1.0]);
        assert_eq!(a, b, "translated paths share a signature, hence a key");
        let c = cache_key(2, "trunc:3", &[0.0, 0.0, 1.0, 2.0, 3.0, 5.5]);
        assert_ne!(a, c);
        let d = cache_key(2, "trunc:2", &[0.0, 0.0, 1.0, 2.0, 3.0, 5.0]);
        assert_ne!(a, d, "manifest digest separates configurations");
    }

    #[test]
    fn bounded_fifo_with_counters() {
        let mut c = SigCache::new(2);
        let k = |n: u8| {
            let mut k = [0u8; 32];
            k[0] = n;
            k
        };
        assert!(c.get(&k(1)).is_none());
        c.insert(k(1), vec![1.0]);
        c.insert(k(2), vec![2.0]);
        assert_eq!(c.get(&k(1)), Some(&[1.0][..]));
        c.insert(k(3), vec![3.0]); // evicts k(1), the oldest
        assert!(c.get(&k(1)).is_none());
        assert_eq!(c.get(&k(3)), Some(&[3.0][..]));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 2, 1));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_zero_is_inert() {
        let mut c = SigCache::new(0);
        c.insert([0u8; 32], vec![1.0]);
        assert!(c.get(&[0u8; 32]).is_none());
        assert_eq!(c.stats(), CacheStats::default());
        assert!(c.is_empty());
    }
}
