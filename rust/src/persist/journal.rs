//! Append-only shard journals, atomic checkpoints, and boot-time
//! recovery.
//!
//! Each shard worker owns two files under the journal directory:
//!
//! * `shard-{i}.journal` — append-only [`super::codec`] records, one
//!   per session op (`OPEN`/`PUSH`/`CLOSE`/`EVICT`), written before the
//!   op's reply is sent. A warm append reuses the writer's encode
//!   buffer and issues one `write(2)` — zero steady-state heap
//!   allocations (asserted in `benches/fig6_durability.rs`).
//! * `shard-{i}.ckpt` — the latest checkpoint: a `CKPT_HEAD` carrying
//!   the journal-sequence watermark it covers, then one `SNAP` per live
//!   session. Checkpoints are written to a `.tmp` sibling, fsynced,
//!   atomically renamed into place, and the directory is fsynced (an
//!   unsynced rename can be reordered after the journal truncate by a
//!   power loss); only then is the journal truncated — so every
//!   instant of a crash leaves either the old (checkpoint, long
//!   journal) pair or the new (checkpoint, short or stale journal)
//!   pair, never a half state. Journal records with `seq ≤ watermark`
//!   are skipped on replay, which makes the rename-then-truncate crash
//!   window harmless.
//!
//! Boot-time rewrites that move sessions *between* files (the shard
//! count changed, or recovery dropped sessions) go through
//! [`repartition`]: the whole new generation is staged under
//! `shard-{i}.ckpt.new` names and committed with a single atomic
//! rename of a `repartition.commit` marker, which [`recover_dir`]
//! knows how to resume — so even multi-file rewrites are
//! crash-anywhere safe.
//!
//! Recovery ([`recover_dir`]) loads the checkpoint (discarding it
//! wholesale if corrupt), replays the journal tail on top, physically
//! truncates a torn journal tail at the last clean record, and applies
//! tombstones: a session that was ever `CLOSE`d or `EVICT`ed never
//! resurrects, even from a spliced or reordered file.

use super::codec::{self, Record, RecordReader};
use crate::sig::{StreamEngine, StreamScratch, StreamTable};
use crate::words::WordSpec;
use std::collections::{HashMap, HashSet};
use std::fs::{self, File, OpenOptions};
use std::io::{self, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Journal file path for shard `i`.
pub fn journal_path(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard-{shard}.journal"))
}

/// Checkpoint file path for shard `i`.
pub fn ckpt_path(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard-{shard}.ckpt"))
}

/// Staged (not yet committed) checkpoint path for shard `i`, used by
/// the boot-time [`repartition`] protocol.
fn staged_ckpt_path(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard-{shard}.ckpt.new"))
}

/// The repartition commit marker (see [`repartition`]). Its existence
/// is the single atomic commit point for a boot-time rewrite; its body
/// is the ASCII shard count of the new generation.
fn repart_marker_path(dir: &Path) -> PathBuf {
    dir.join("repartition.commit")
}

/// Durably sync the directory entry metadata (file creations, renames,
/// and deletions) of `dir`. An atomic `rename` only survives power
/// loss once the *parent directory* is fsynced — `sync_data` on the
/// renamed file is not enough — and nothing else orders the rename
/// against a later journal truncate. No-op on platforms where a
/// directory cannot be opened as a file (e.g. Windows).
fn sync_dir(dir: &Path) -> io::Result<()> {
    #[cfg(unix)]
    {
        File::open(dir)?.sync_all()
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
        Ok(())
    }
}

/// Append-only record writer over one shard's journal file.
///
/// Holds a reusable encode buffer so warm appends allocate nothing;
/// every append is a single `write_all` of a complete record, followed
/// by `sync_data` when `fsync` is on.
#[derive(Debug)]
pub struct JournalWriter {
    file: File,
    buf: Vec<u8>,
    seq: u64,
    fsync: bool,
}

impl JournalWriter {
    /// Create (truncating) the journal at `path`. `start_seq` is the
    /// last sequence number already covered by the current checkpoint;
    /// the first appended record gets `start_seq + 1`.
    pub fn create(path: &Path, fsync: bool, start_seq: u64) -> io::Result<JournalWriter> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        // Make the journal's directory entry durable before any record
        // is acked against it — a file that vanishes with the page
        // cache on power loss would silently void every appended op.
        if let Some(dir) = path.parent() {
            sync_dir(dir)?;
        }
        Ok(JournalWriter {
            file,
            buf: Vec::with_capacity(256),
            fsync,
            seq: start_seq,
        })
    }

    /// Sequence number of the last appended record.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    fn commit(&mut self) -> io::Result<usize> {
        // Chaos hooks (no-ops unless the `failpoints` feature is on):
        // `journal.append` models the write itself failing (ENOSPC,
        // EIO); `journal.fsync` models a write that reached the page
        // cache but could not be made durable. Either way the record
        // is not acked — the caller decides shed-vs-degrade.
        crate::failpoint!("journal.append");
        self.file.write_all(&self.buf)?;
        if self.fsync {
            crate::failpoint!("journal.fsync");
            self.file.sync_data()?;
        }
        Ok(self.buf.len())
    }

    /// Append an `OPEN` record; returns the bytes written.
    pub fn append_open(
        &mut self,
        id: u64,
        dim: usize,
        window: usize,
        spec: &WordSpec,
    ) -> io::Result<usize> {
        self.seq += 1;
        self.buf.clear();
        codec::encode_open(&mut self.buf, self.seq, id, dim, window, spec);
        self.commit()
    }

    /// Append a `PUSH` record; returns the bytes written.
    pub fn append_push(&mut self, id: u64, samples: &[f64]) -> io::Result<usize> {
        self.seq += 1;
        self.buf.clear();
        codec::encode_push(&mut self.buf, self.seq, id, samples);
        self.commit()
    }

    /// Append a `CLOSE` record; returns the bytes written.
    pub fn append_close(&mut self, id: u64) -> io::Result<usize> {
        self.seq += 1;
        self.buf.clear();
        codec::encode_close(&mut self.buf, self.seq, id);
        self.commit()
    }

    /// Append an `EVICT` tombstone; returns the bytes written.
    pub fn append_evict(&mut self, id: u64) -> io::Result<usize> {
        self.seq += 1;
        self.buf.clear();
        codec::encode_evict(&mut self.buf, self.seq, id);
        self.commit()
    }

    /// Drop everything the checkpoint now covers: truncate the file to
    /// zero and rewind the write position (sequence numbering continues
    /// upward, so replay ordering stays monotone).
    pub fn truncate(&mut self) -> io::Result<()> {
        crate::failpoint!("journal.truncate");
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        Ok(())
    }
}

/// Write shard `i`'s checkpoint atomically: encode `CKPT_HEAD` +
/// `SNAP`s into a `.tmp` sibling, `sync_data`, then rename over the
/// live checkpoint. The caller truncates the journal afterwards (the
/// order matters — see the module docs).
pub fn write_checkpoint(
    dir: &Path,
    shard: usize,
    watermark: u64,
    sessions: &[(u64, &WordSpec, &StreamEngine)],
) -> io::Result<()> {
    let mut buf = Vec::with_capacity(1024);
    codec::encode_ckpt_head(&mut buf, watermark, sessions.len());
    for (id, spec, stream) in sessions {
        let ck = stream.checkpoint();
        codec::encode_snap(&mut buf, watermark, *id, stream.dim(), spec, &ck);
    }
    let tmp = dir.join(format!("shard-{shard}.ckpt.tmp"));
    // `ckpt.write` = disk full while staging the tmp (the live
    // checkpoint must survive untouched); `ckpt.rename` = crash window
    // between a complete tmp and its promotion.
    crate::failpoint!("ckpt.write");
    let mut f = File::create(&tmp)?;
    f.write_all(&buf)?;
    f.sync_data()?;
    drop(f);
    crate::failpoint!("ckpt.rename");
    fs::rename(&tmp, ckpt_path(dir, shard))?;
    // Order the rename against everything that follows (in particular
    // the caller's journal truncate): without a directory fsync, power
    // loss can persist the truncate while the rename is still only in
    // the page cache — leaving the OLD checkpoint next to an EMPTY
    // journal, i.e. silent loss of every op since that old checkpoint.
    sync_dir(dir)
}

/// Re-persist a fully recovered generation of sessions under a
/// (possibly changed) shard count, crash-safely.
///
/// A naive rewrite — delete the old files, then write the new ones —
/// loses every session if the process dies in between, and even
/// "write new, then delete old" is unsafe here because sessions move
/// *between* files when the shard count changes: renaming a new
/// checkpoint over `shard-0.ckpt` destroys the only durable copy of a
/// session whose new home (`shard-1.ckpt`) has not been written yet.
///
/// So the rewrite is staged behind a single atomic commit point:
///
/// 1. **Stage** — every new checkpoint is written (and synced) to
///    `shard-{i}.ckpt.new`. Old files are untouched; a crash leaves
///    strays that the next [`recover_dir`] deletes.
/// 2. **Commit** — `repartition.commit` (body: the ASCII shard count)
///    is written to a tmp, synced, and renamed into place, then the
///    directory is synced. This one rename flips which generation is
///    authoritative.
/// 3. **Finish** ([`finish_repartition`]) — staged checkpoints are
///    renamed over the live ones, every journal (whose content the
///    staged generation already folds in) and every file for a shard
///    index `>= n` is deleted, and the marker is removed.
///
/// A crash before step 2 recovers the old generation; a crash after it
/// makes [`recover_dir`] resume step 3 before scanning. At no instant
/// does the directory's authoritative generation hold less than every
/// recovered session.
pub fn repartition(
    dir: &Path,
    shards: &[Vec<(u64, &WordSpec, &StreamEngine)>],
) -> io::Result<()> {
    let n = shards.len();
    // Phase 1 — stage.
    for (i, sessions) in shards.iter().enumerate() {
        let mut buf = Vec::with_capacity(1024);
        codec::encode_ckpt_head(&mut buf, 0, sessions.len());
        for (id, spec, stream) in sessions {
            let ck = stream.checkpoint();
            codec::encode_snap(&mut buf, 0, *id, stream.dim(), spec, &ck);
        }
        let mut f = File::create(staged_ckpt_path(dir, i))?;
        f.write_all(&buf)?;
        f.sync_data()?;
    }
    sync_dir(dir)?;
    // Phase 2 — commit.
    let tmp = dir.join("repartition.commit.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(n.to_string().as_bytes())?;
        f.sync_data()?;
    }
    fs::rename(&tmp, repart_marker_path(dir))?;
    sync_dir(dir)?;
    // Phase 3 — finish.
    finish_repartition(dir, n)
}

/// Promote a committed repartition generation: rename each staged
/// checkpoint over its live sibling, delete every journal plus every
/// `shard-*` file for an index `>= n`, then drop the marker. Safe to
/// re-run after a crash at any point (every step is idempotent).
fn finish_repartition(dir: &Path, n: usize) -> io::Result<()> {
    for i in 0..n {
        match fs::rename(staged_ckpt_path(dir, i), ckpt_path(dir, i)) {
            Ok(()) => {}
            // Already promoted by the run that crashed mid-finish.
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(e) => return Err(e),
        }
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(rest) = name.strip_prefix("shard-") else {
            continue;
        };
        let stale = if rest.ends_with(".journal") || rest.ends_with(".ckpt.tmp") {
            // Journals predate the committed generation (their records
            // are folded into the staged checkpoints); tmps are debris.
            true
        } else if let Some(k) = rest
            .strip_suffix(".ckpt")
            .or_else(|| rest.strip_suffix(".ckpt.new"))
            .and_then(|k| k.parse::<usize>().ok())
        {
            k >= n
        } else {
            false
        };
        if stale {
            fs::remove_file(entry.path())?;
        }
    }
    match fs::remove_file(repart_marker_path(dir)) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::NotFound => {}
        Err(e) => return Err(e),
    }
    sync_dir(dir)
}

/// One session rebuilt by recovery, ready to hand to a shard worker.
#[derive(Debug)]
pub struct RecoveredSession {
    /// Session id (recovery also feeds the max back into the id
    /// allocator so new sessions never collide).
    pub id: u64,
    /// Alphabet size.
    pub dim: usize,
    /// Sliding-window length.
    pub window: usize,
    /// Word-set specification (kept for future checkpoints).
    pub spec: WordSpec,
    /// The rebuilt engine, checkpoint-restored and tail-replayed.
    pub stream: StreamEngine,
}

/// Counters describing what recovery found (surfaced via
/// [`crate::coordinator::Metrics`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Journal/checkpoint file pairs scanned.
    pub shards_scanned: u64,
    /// Tail records replayed on top of checkpoints.
    pub records_replayed: u64,
    /// Journals that ended in a torn or corrupt record and were
    /// truncated back to their clean prefix.
    pub torn_tails: u64,
    /// Bytes dropped by those truncations.
    pub dropped_bytes: u64,
    /// Checkpoint files discarded as corrupt, plus individual
    /// snapshots rejected by engine validation.
    pub corrupt_checkpoints: u64,
    /// `OPEN` records ignored because the id was tombstoned (or seen
    /// in another shard file).
    pub tombstone_hits: u64,
}

/// Everything [`recover_dir`] rebuilt from a journal directory.
#[derive(Debug, Default)]
pub struct Recovery {
    /// Recovered sessions, ascending id order.
    pub sessions: Vec<RecoveredSession>,
    /// Highest session id seen anywhere (live or tombstoned).
    pub max_id: u64,
    /// What the scan encountered.
    pub stats: RecoveryStats,
}

/// Resolves `(dim, spec)` to a shared streaming table. Recovery calls
/// it once per distinct configuration; callers should memoize (the
/// coordinator shares tables across sessions the same way).
pub type TableResolver<'a> = dyn FnMut(usize, &WordSpec) -> Arc<StreamTable> + 'a;

/// Cheap structural validation of a journaled `(dim, window, spec)`
/// before building tables from it: the word generators `assert!` on
/// malformed specs (wrong γ length, out-of-range letters), and replay
/// must degrade to "count + skip", never panic, on a forged or spliced
/// record that passed its checksum.
fn admissible(dim: usize, window: usize, spec: &WordSpec) -> bool {
    if dim == 0 || dim > u16::MAX as usize + 1 || window == 0 {
        return false;
    }
    let depth_ok = |n: usize| n >= 1 && n <= 64;
    let letters_ok = |w: &[u16]| w.iter().all(|&l| (l as usize) < dim);
    match spec {
        WordSpec::Truncated { depth } | WordSpec::Lyndon { depth } => depth_ok(*depth),
        WordSpec::Anisotropic { gamma, cutoff } => {
            gamma.len() == dim && gamma.iter().all(|&g| g > 0.0) && cutoff.is_finite()
        }
        WordSpec::Dag { depth, edges } => {
            depth_ok(*depth) && edges.len() == dim && edges.iter().all(|r| letters_ok(r))
        }
        WordSpec::ConcatGenerated { depth, generators } => {
            depth_ok(*depth) && generators.iter().all(|w| letters_ok(&w.0))
        }
        WordSpec::Custom { words } => words.iter().all(|w| letters_ok(&w.0)),
    }
}

struct ReplaySession {
    dim: usize,
    window: usize,
    spec: WordSpec,
    stream: StreamEngine,
}

/// Scan a journal directory and rebuild every live session.
///
/// Reads each `shard-{k}.{ckpt,journal}` pair (whatever shard count the
/// previous run used — sessions are re-partitioned by the caller), and
/// for each pair: restores checkpointed sessions, replays the journal
/// tail with `seq > watermark`, honors tombstones, truncates torn
/// tails in place, and skips — with a counter, never a panic — any
/// record that fails structural validation.
pub fn recover_dir(dir: &Path, resolve: &mut TableResolver) -> io::Result<Recovery> {
    let mut out = Recovery::default();
    if !dir.exists() {
        return Ok(out);
    }
    // Settle any repartition interrupted by a crash before scanning: a
    // committed marker means the staged `.ckpt.new` generation is
    // authoritative (every staged file was written and synced before
    // the marker's atomic rename), so finish promoting it; no marker
    // means staged files are uncommitted phase-1 residue and the old
    // generation still rules, so drop them.
    match fs::read_to_string(repart_marker_path(dir)) {
        Ok(body) => {
            let n = body.trim().parse::<usize>().map_err(|_| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    "corrupt repartition.commit marker",
                )
            })?;
            finish_repartition(dir, n)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            for entry in fs::read_dir(dir)? {
                let entry = entry?;
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if name.starts_with("shard-")
                    && (name.ends_with(".ckpt.new") || name.ends_with(".ckpt.tmp"))
                {
                    fs::remove_file(entry.path())?;
                }
            }
            let _ = fs::remove_file(dir.join("repartition.commit.tmp"));
        }
        Err(e) => return Err(e),
    }
    let mut shards: Vec<usize> = Vec::new();
    for entry in fs::read_dir(dir)? {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(k) = name
            .strip_prefix("shard-")
            .and_then(|rest| {
                rest.strip_suffix(".journal")
                    .or_else(|| rest.strip_suffix(".ckpt"))
            })
            .and_then(|k| k.parse::<usize>().ok())
        {
            if !shards.contains(&k) {
                shards.push(k);
            }
        }
    }
    shards.sort_unstable();
    let mut seen: HashSet<u64> = HashSet::new();
    for k in shards {
        recover_shard(dir, k, resolve, &mut seen, &mut out)?;
        out.stats.shards_scanned += 1;
    }
    out.sessions.sort_by_key(|s| s.id);
    Ok(out)
}

fn recover_shard(
    dir: &Path,
    shard: usize,
    resolve: &mut TableResolver,
    seen: &mut HashSet<u64>,
    out: &mut Recovery,
) -> io::Result<()> {
    // `recover.read` models an unreadable shard file at boot; the
    // error surfaces through recover_dir instead of silently starting
    // empty (which would ack new work against a directory that still
    // holds the old sessions).
    crate::failpoint!("recover.read");
    let mut live: HashMap<u64, ReplaySession> = HashMap::new();
    let mut tombstones: HashSet<u64> = HashSet::new();
    let mut note_id = |out: &mut Recovery, id: u64| {
        if id > out.max_id {
            out.max_id = id;
        }
    };

    // Checkpoint: all-or-nothing per snapshot, whole file gated by a
    // valid CKPT_HEAD.
    let mut watermark = 0u64;
    let cpath = ckpt_path(dir, shard);
    if let Ok(bytes) = fs::read(&cpath) {
        let mut r = RecordReader::new(&bytes);
        match r.next() {
            Some((wm, Record::CkptHead { n_sessions })) => {
                watermark = wm;
                let mut got = 0usize;
                while let Some((_, rec)) = r.next() {
                    got += 1;
                    let (id, dim, spec, ck) = match rec {
                        Record::Snap { id, dim, spec, ck } => (id, dim, spec, ck),
                        _ => {
                            out.stats.corrupt_checkpoints += 1;
                            continue;
                        }
                    };
                    note_id(out, id);
                    if !admissible(dim, ck.window, &spec) {
                        out.stats.corrupt_checkpoints += 1;
                        continue;
                    }
                    let tbl = resolve(dim, &spec);
                    match StreamEngine::from_checkpoint(tbl, &ck, StreamScratch::default()) {
                        Ok(stream) => {
                            live.insert(
                                id,
                                ReplaySession {
                                    dim,
                                    window: ck.window,
                                    spec,
                                    stream,
                                },
                            );
                        }
                        Err(_) => out.stats.corrupt_checkpoints += 1,
                    }
                }
                if r.error().is_some() || got != n_sessions {
                    out.stats.corrupt_checkpoints += 1;
                }
            }
            Some(_) | None => {
                if !bytes.is_empty() {
                    out.stats.corrupt_checkpoints += 1;
                    live.clear();
                    watermark = 0;
                }
            }
        }
    }

    // Journal tail.
    let jpath = journal_path(dir, shard);
    if let Ok(bytes) = fs::read(&jpath) {
        let mut r = RecordReader::new(&bytes);
        while let Some((seq, rec)) = r.next() {
            if seq <= watermark {
                continue; // Covered by the checkpoint (rename-then-truncate crash window).
            }
            out.stats.records_replayed += 1;
            match rec {
                Record::Open {
                    id,
                    dim,
                    window,
                    spec,
                } => {
                    note_id(out, id);
                    if tombstones.contains(&id) || seen.contains(&id) || live.contains_key(&id) {
                        out.stats.tombstone_hits += 1;
                    } else if !admissible(dim, window, &spec) {
                        out.stats.corrupt_checkpoints += 1;
                    } else {
                        let tbl = resolve(dim, &spec);
                        live.insert(
                            id,
                            ReplaySession {
                                dim,
                                window,
                                spec,
                                stream: StreamEngine::new(tbl, window),
                            },
                        );
                    }
                }
                Record::Push { id, samples } => {
                    if let Some(sess) = live.get_mut(&id) {
                        for row in samples.chunks_exact(sess.dim) {
                            sess.stream.push(row);
                        }
                    }
                }
                Record::Close { id } | Record::Evict { id } => {
                    note_id(out, id);
                    live.remove(&id);
                    tombstones.insert(id);
                }
                Record::Snap { .. } | Record::CkptHead { .. } => {
                    // Checkpoint-only kinds in a journal: forged or
                    // spliced. Ignore, but leave a trace.
                    out.stats.corrupt_checkpoints += 1;
                }
            }
        }
        if r.error().is_some() {
            out.stats.torn_tails += 1;
            out.stats.dropped_bytes += (bytes.len() - r.good_len()) as u64;
            // Clean truncation: cut the file back to its valid prefix
            // so the next boot replays without rescanning the garbage.
            if let Ok(f) = OpenOptions::new().write(true).open(&jpath) {
                let _ = f.set_len(r.good_len() as u64);
            }
        }
    }

    for (id, sess) in live {
        seen.insert(id);
        out.sessions.push(RecoveredSession {
            id,
            dim: sess.dim,
            window: sess.window,
            spec: sess.spec,
            stream: sess.stream,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::words::truncated_words;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_N: AtomicU64 = AtomicU64::new(0);

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pathsig-journal-{}-{}",
            std::process::id(),
            DIR_N.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn resolver() -> impl FnMut(usize, &WordSpec) -> Arc<StreamTable> {
        let mut memo: HashMap<String, Arc<StreamTable>> = HashMap::new();
        move |dim, spec| {
            memo.entry(format!("{dim}:{spec:?}"))
                .or_insert_with(|| Arc::new(StreamTable::new(dim, &spec.words(dim))))
                .clone()
        }
    }

    #[test]
    fn journal_only_replay_rebuilds_sessions() {
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 2 };
        let mut w = JournalWriter::create(&journal_path(&dir, 0), false, 0).unwrap();
        w.append_open(1, 1, 4, &spec).unwrap();
        w.append_push(1, &[0.0, 1.0, 3.0]).unwrap();
        w.append_open(2, 1, 4, &spec).unwrap();
        w.append_push(2, &[5.0]).unwrap();
        w.append_close(2).unwrap();
        drop(w);
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.sessions.len(), 1, "closed session must not return");
        assert_eq!(rec.max_id, 2);
        let mut s = rec.sessions.into_iter().next().unwrap();
        assert_eq!(s.id, 1);
        // Same samples through a fresh engine: identical window.
        let tbl = Arc::new(StreamTable::new(1, &truncated_words(1, 2)));
        let mut reference = StreamEngine::new(tbl, 4);
        for x in [0.0, 1.0, 3.0] {
            reference.push(&[x]);
        }
        assert_eq!(s.stream.window_signature(), reference.window_signature());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_plus_tail_beats_full_replay_torn_write() {
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 3 };
        let tbl = Arc::new(StreamTable::new(2, &truncated_words(2, 3)));
        let mut stream = StreamEngine::new(Arc::clone(&tbl), 3);
        let mut w = JournalWriter::create(&journal_path(&dir, 0), false, 0).unwrap();
        w.append_open(7, 2, 3, &spec).unwrap();
        for j in 0..6 {
            let x = [j as f64, (j * j) as f64 * 0.25];
            stream.push(&x);
            w.append_push(7, &x).unwrap();
        }
        // Checkpoint now, then keep journaling a tail.
        write_checkpoint(&dir, 0, w.seq(), &[(7, &spec, &stream)]).unwrap();
        w.truncate().unwrap();
        for j in 6..9 {
            let x = [j as f64, (j * j) as f64 * 0.25];
            stream.push(&x);
            w.append_push(7, &x).unwrap();
        }
        drop(w);
        // Simulate a torn final record: chop 3 bytes off the journal.
        let jp = journal_path(&dir, 0);
        let len = fs::metadata(&jp).unwrap().len();
        OpenOptions::new()
            .write(true)
            .open(&jp)
            .unwrap()
            .set_len(len - 3)
            .unwrap();
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.stats.torn_tails, 1);
        assert!(rec.stats.dropped_bytes > 0);
        assert_eq!(rec.sessions.len(), 1);
        let mut got = rec.sessions.into_iter().next().unwrap();
        // Clean prefix = checkpoint + pushes 6,7 (the push of j=8 was
        // torn): compare against a fresh engine over samples 0..8.
        let mut reference = StreamEngine::new(Arc::clone(&tbl), 3);
        for j in 0..8 {
            reference.push(&[j as f64, (j * j) as f64 * 0.25]);
        }
        let w_got = got.stream.window_signature();
        let w_ref = reference.window_signature();
        for (a, b) in w_got.iter().zip(&w_ref) {
            assert!((a - b).abs() < 1e-12, "{w_got:?} vs {w_ref:?}");
        }
        // The torn file was physically truncated: a second recovery is
        // clean.
        let rec2 = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec2.stats.torn_tails, 0);
        assert_eq!(rec2.sessions.len(), 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tombstones_survive_splices() {
        // An OPEN spliced *after* its session's EVICT must not
        // resurrect it.
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 2 };
        let mut buf = Vec::new();
        codec::encode_open(&mut buf, 1, 3, 1, 2, &spec);
        codec::encode_evict(&mut buf, 2, 3);
        codec::encode_open(&mut buf, 3, 3, 1, 2, &spec); // forged resurrect
        fs::write(journal_path(&dir, 0), &buf).unwrap();
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert!(rec.sessions.is_empty());
        assert_eq!(rec.stats.tombstone_hits, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_checkpoint_falls_back_to_journal() {
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 2 };
        fs::write(ckpt_path(&dir, 0), b"not a checkpoint at all").unwrap();
        let mut w = JournalWriter::create(&journal_path(&dir, 0), false, 0).unwrap();
        w.append_open(1, 1, 2, &spec).unwrap();
        w.append_push(1, &[0.0, 2.0]).unwrap();
        drop(w);
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.stats.corrupt_checkpoints, 1);
        assert_eq!(rec.sessions.len(), 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repartition_rewrites_topology_atomically() {
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 2 };
        // Old generation: one shard file holding two sessions.
        let mut w = JournalWriter::create(&journal_path(&dir, 0), false, 0).unwrap();
        w.append_open(1, 1, 4, &spec).unwrap();
        w.append_push(1, &[0.5, 1.5]).unwrap();
        w.append_open(2, 1, 4, &spec).unwrap();
        w.append_push(2, &[2.0]).unwrap();
        drop(w);
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.sessions.len(), 2);
        let golden: Vec<Vec<f64>> = rec
            .sessions
            .iter()
            .map(|s| s.stream.window_signature())
            .collect();
        // Re-persist across two shards (one session each).
        let groups: Vec<Vec<(u64, &WordSpec, &StreamEngine)>> = rec
            .sessions
            .iter()
            .map(|s| vec![(s.id, &s.spec, &s.stream)])
            .collect();
        repartition(&dir, &groups).unwrap();
        // Clean final state: two checkpoints, no journals, no marker,
        // no staged files.
        assert!(ckpt_path(&dir, 0).exists());
        assert!(ckpt_path(&dir, 1).exists());
        assert!(!journal_path(&dir, 0).exists());
        assert!(!repart_marker_path(&dir).exists());
        assert!(!staged_ckpt_path(&dir, 0).exists());
        // A fresh recovery sees the same sessions with identical state.
        let rec2 = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec2.sessions.len(), 2);
        for (s, g) in rec2.sessions.iter().zip(&golden) {
            assert_eq!(&s.stream.window_signature(), g);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn uncommitted_stage_is_rolled_back() {
        // Phase-1 crash: staged `.ckpt.new` files exist but the marker
        // was never committed — the old generation must win and the
        // strays must be deleted.
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 2 };
        let mut w = JournalWriter::create(&journal_path(&dir, 0), false, 0).unwrap();
        w.append_open(1, 1, 4, &spec).unwrap();
        w.append_push(1, &[1.0]).unwrap();
        drop(w);
        fs::write(staged_ckpt_path(&dir, 0), b"half-written stage").unwrap();
        fs::write(staged_ckpt_path(&dir, 5), b"more debris").unwrap();
        fs::write(dir.join("repartition.commit.tmp"), b"2").unwrap();
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.sessions.len(), 1);
        assert_eq!(rec.sessions[0].id, 1);
        assert!(!staged_ckpt_path(&dir, 0).exists());
        assert!(!staged_ckpt_path(&dir, 5).exists());
        assert!(!dir.join("repartition.commit.tmp").exists());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn committed_marker_resumes_finish() {
        // Post-commit crash: the marker exists, the staged generation
        // is complete, the old journal was never deleted. Recovery
        // must promote the staged checkpoints and ignore the old
        // journal entirely (its records are already folded in).
        let dir = tmpdir();
        let spec = WordSpec::Truncated { depth: 2 };
        let tbl = Arc::new(StreamTable::new(1, &truncated_words(1, 2)));
        // Old generation: session 1 with ONE push.
        let mut w = JournalWriter::create(&journal_path(&dir, 0), false, 0).unwrap();
        w.append_open(1, 1, 4, &spec).unwrap();
        w.append_push(1, &[1.0]).unwrap();
        drop(w);
        // Old-generation checkpoint beyond the new shard count.
        fs::write(ckpt_path(&dir, 3), b"stale old-generation file").unwrap();
        // Staged new generation: the same session with TWO pushes.
        let mut staged = StreamEngine::new(Arc::clone(&tbl), 4);
        staged.push(&[1.0]);
        staged.push(&[2.0]);
        let mut buf = Vec::new();
        codec::encode_ckpt_head(&mut buf, 0, 1);
        codec::encode_snap(&mut buf, 0, 1, 1, &spec, &staged.checkpoint());
        fs::write(staged_ckpt_path(&dir, 0), &buf).unwrap();
        fs::write(repart_marker_path(&dir), b"1").unwrap();
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.sessions.len(), 1);
        // The committed generation wins over the stale journal.
        assert_eq!(
            rec.sessions[0].stream.window_signature(),
            staged.window_signature()
        );
        assert!(ckpt_path(&dir, 0).exists());
        assert!(!staged_ckpt_path(&dir, 0).exists());
        assert!(!journal_path(&dir, 0).exists());
        assert!(!ckpt_path(&dir, 3).exists());
        assert!(!repart_marker_path(&dir).exists());
        // Idempotent: a second recovery is clean and identical.
        let rec2 = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec2.sessions.len(), 1);
        assert_eq!(rec2.stats.corrupt_checkpoints, 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn inadmissible_specs_are_skipped_not_panicked() {
        let dir = tmpdir();
        // Anisotropic with the wrong γ arity would assert inside the
        // word generator; replay must skip it.
        let bad = WordSpec::Anisotropic {
            gamma: vec![1.0],
            cutoff: 2.0,
        };
        let mut buf = Vec::new();
        codec::encode_open(&mut buf, 1, 1, 3, 2, &bad);
        codec::encode_open(&mut buf, 2, 2, 1, 2, &WordSpec::Truncated { depth: 2 });
        fs::write(journal_path(&dir, 0), &buf).unwrap();
        let mut res = resolver();
        let rec = recover_dir(&dir, &mut res).unwrap();
        assert_eq!(rec.sessions.len(), 1);
        assert_eq!(rec.sessions[0].id, 2);
        assert_eq!(rec.stats.corrupt_checkpoints, 1);
        fs::remove_dir_all(&dir).unwrap();
    }
}
