//! Length-prefixed, checksummed binary records — the on-disk idiom of
//! the durability layer (journal + checkpoint files share it).
//!
//! ## Record framing
//!
//! ```text
//! [u32 LE len] [payload = u8 kind · u64 LE seq · body] [u32 LE crc]
//! ```
//!
//! `len` counts payload bytes only; `crc` is CRC-32 (IEEE/zlib
//! polynomial, reflected — byte-compatible with Python's `zlib.crc32`,
//! which is what `scripts/gen_goldens.py` uses to emit the byte-exact
//! goldens in `rust/tests/golden/persist_records.hex`). `seq` is the
//! writer's monotonically increasing record number; checkpoint records
//! reuse the field as the journal-sequence watermark they cover.
//!
//! ## Record kinds
//!
//! | kind | name        | body |
//! |------|-------------|------|
//! | 1    | `OPEN`      | `u64 id · u32 dim · u32 window · spec` |
//! | 2    | `PUSH`      | `u64 id · f64s samples` |
//! | 3    | `CLOSE`     | `u64 id` |
//! | 4    | `EVICT`     | `u64 id` (tombstone — identical replay semantics to `CLOSE`) |
//! | 5    | `SNAP`      | `u64 id · u32 dim · spec · stream checkpoint` |
//! | 6    | `CKPT_HEAD` | `u32 n_sessions` (seq field = watermark) |
//!
//! Repeated scalar encodings follow wire v2: `f64s` = `u32 count` +
//! count little-endian doubles, `u16s` = `u32 count` + count `u16`s.
//! A [`WordSpec`] is a `u8` tag (0 truncated, 1 lyndon, 2 anisotropic,
//! 3 dag, 4 concat-generated, 5 custom) followed by the variant fields,
//! and a [`StreamCheckpoint`] is its four counters followed by the five
//! buffers (see [`encode_snap`]).
//!
//! ## Reading and the torn-tail rule
//!
//! [`RecordReader`] iterates records, validating the length prefix,
//! remaining bytes, checksum, kind and a non-decreasing `seq` before
//! yielding anything. At the **first** invalid record it stops and
//! reports the byte offset of the end of the last good record
//! ([`RecordReader::good_len`]) — recovery truncates the file there and
//! replays only the clean prefix (the crash-mid-write contract).

use crate::sig::StreamCheckpoint;
use crate::words::{Word, WordSpec};

/// Fixed per-record byte overhead (length prefix + checksum).
pub const RECORD_OVERHEAD: usize = 8;

/// Hard cap on a single record's payload length (64 MiB) — a corrupt
/// length prefix must not drive a giant allocation.
pub const MAX_RECORD_LEN: usize = 1 << 26;

/// Record kind bytes (see the module table).
pub mod kind {
    /// Session opened: id, dim, window, word spec.
    pub const OPEN: u8 = 1;
    /// Samples pushed into a session.
    pub const PUSH: u8 = 2;
    /// Session closed by the client.
    pub const CLOSE: u8 = 3;
    /// Session evicted by the TTL sweeper (tombstone).
    pub const EVICT: u8 = 4;
    /// Per-session engine snapshot inside a checkpoint file.
    pub const SNAP: u8 = 5;
    /// Checkpoint header: journal watermark + session count.
    pub const CKPT_HEAD: u8 = 6;
}

const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 (IEEE 802.3 polynomial, reflected, init/xorout `!0`) —
/// bit-for-bit the checksum `zlib.crc32` computes.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(buf: &mut Vec<u8>, vs: &[f64]) {
    put_u32(buf, vs.len() as u32);
    for &v in vs {
        put_f64(buf, v);
    }
}

fn put_u16s(buf: &mut Vec<u8>, vs: &[u16]) {
    put_u32(buf, vs.len() as u32);
    for &v in vs {
        put_u16(buf, v);
    }
}

fn put_spec(buf: &mut Vec<u8>, spec: &WordSpec) {
    match spec {
        WordSpec::Truncated { depth } => {
            buf.push(0);
            put_u32(buf, *depth as u32);
        }
        WordSpec::Lyndon { depth } => {
            buf.push(1);
            put_u32(buf, *depth as u32);
        }
        WordSpec::Anisotropic { gamma, cutoff } => {
            buf.push(2);
            put_f64s(buf, gamma);
            put_f64(buf, *cutoff);
        }
        WordSpec::Dag { depth, edges } => {
            buf.push(3);
            put_u32(buf, *depth as u32);
            put_u32(buf, edges.len() as u32);
            for row in edges {
                put_u16s(buf, row);
            }
        }
        WordSpec::ConcatGenerated { depth, generators } => {
            buf.push(4);
            put_u32(buf, *depth as u32);
            put_u32(buf, generators.len() as u32);
            for w in generators {
                put_u16s(buf, &w.0);
            }
        }
        WordSpec::Custom { words } => {
            buf.push(5);
            put_u32(buf, words.len() as u32);
            for w in words {
                put_u16s(buf, &w.0);
            }
        }
    }
}

/// Frame `payload`-building closure output as a complete record
/// (`len · kind · seq · body · crc`) appended to `buf`. Returns the
/// record's total byte length.
fn frame_record(buf: &mut Vec<u8>, kind: u8, seq: u64, body: impl FnOnce(&mut Vec<u8>)) -> usize {
    let len_at = buf.len();
    put_u32(buf, 0); // patched below
    let payload_at = buf.len();
    buf.push(kind);
    put_u64(buf, seq);
    body(buf);
    let payload_len = buf.len() - payload_at;
    buf[len_at..len_at + 4].copy_from_slice(&(payload_len as u32).to_le_bytes());
    let crc = crc32(&buf[payload_at..]);
    put_u32(buf, crc);
    buf.len() - len_at
}

/// Append an `OPEN` record. Returns its encoded length in bytes.
pub fn encode_open(
    buf: &mut Vec<u8>,
    seq: u64,
    id: u64,
    dim: usize,
    window: usize,
    spec: &WordSpec,
) -> usize {
    frame_record(buf, kind::OPEN, seq, |b| {
        put_u64(b, id);
        put_u32(b, dim as u32);
        put_u32(b, window as u32);
        put_spec(b, spec);
    })
}

/// Append a `PUSH` record. Returns its encoded length in bytes.
pub fn encode_push(buf: &mut Vec<u8>, seq: u64, id: u64, samples: &[f64]) -> usize {
    frame_record(buf, kind::PUSH, seq, |b| {
        put_u64(b, id);
        put_f64s(b, samples);
    })
}

/// Append a `CLOSE` record. Returns its encoded length in bytes.
pub fn encode_close(buf: &mut Vec<u8>, seq: u64, id: u64) -> usize {
    frame_record(buf, kind::CLOSE, seq, |b| put_u64(b, id))
}

/// Append an `EVICT` tombstone record. Returns its encoded length.
pub fn encode_evict(buf: &mut Vec<u8>, seq: u64, id: u64) -> usize {
    frame_record(buf, kind::EVICT, seq, |b| put_u64(b, id))
}

/// Append a `CKPT_HEAD` record (`watermark` rides in the seq field).
pub fn encode_ckpt_head(buf: &mut Vec<u8>, watermark: u64, n_sessions: usize) -> usize {
    frame_record(buf, kind::CKPT_HEAD, watermark, |b| {
        put_u32(b, n_sessions as u32)
    })
}

/// Append a `SNAP` record carrying one session's full engine state.
pub fn encode_snap(
    buf: &mut Vec<u8>,
    watermark: u64,
    id: u64,
    dim: usize,
    spec: &WordSpec,
    ck: &StreamCheckpoint,
) -> usize {
    frame_record(buf, kind::SNAP, watermark, |b| {
        put_u64(b, id);
        put_u32(b, dim as u32);
        put_spec(b, spec);
        put_u32(b, ck.window as u32);
        put_u64(b, ck.n_seen as u64);
        put_u32(b, ck.back_len as u32);
        put_u32(b, ck.front_len as u32);
        put_f64s(b, &ck.last);
        put_f64s(b, &ck.total);
        put_f64s(b, &ck.back_agg);
        put_f64s(b, &ck.back_dx);
        put_f64s(b, &ck.front);
    })
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// A decoded record body (seq is reported alongside by the reader).
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    /// Session opened.
    Open {
        /// Session id.
        id: u64,
        /// Alphabet size.
        dim: usize,
        /// Sliding-window length in increments.
        window: usize,
        /// Word-set specification.
        spec: WordSpec,
    },
    /// Samples pushed (flat row-major, `k·dim` values).
    Push {
        /// Session id.
        id: u64,
        /// The pushed samples.
        samples: Vec<f64>,
    },
    /// Session closed.
    Close {
        /// Session id.
        id: u64,
    },
    /// Session evicted (tombstone).
    Evict {
        /// Session id.
        id: u64,
    },
    /// One session's engine snapshot (checkpoint files only).
    Snap {
        /// Session id.
        id: u64,
        /// Alphabet size.
        dim: usize,
        /// Word-set specification.
        spec: WordSpec,
        /// Serialized two-stack engine state.
        ck: StreamCheckpoint,
    },
    /// Checkpoint header (checkpoint files only).
    CkptHead {
        /// Number of `SNAP` records that follow.
        n_sessions: usize,
    },
}

/// Bounds-checked byte cursor (the wire-v2 `Cur` idiom).
struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.b.len() < n {
            return Err(format!("record body short: need {n}, have {}", self.b.len()));
        }
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.u32()? as usize;
        if self.b.len() < n * 8 {
            return Err(format!("f64s count {n} exceeds remaining bytes"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn u16s(&mut self) -> Result<Vec<u16>, String> {
        let n = self.u32()? as usize;
        if self.b.len() < n * 2 {
            return Err(format!("u16s count {n} exceeds remaining bytes"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(2)?;
            out.push(u16::from_le_bytes([b[0], b[1]]));
        }
        Ok(out)
    }

    fn spec(&mut self) -> Result<WordSpec, String> {
        match self.u8()? {
            0 => Ok(WordSpec::Truncated {
                depth: self.u32()? as usize,
            }),
            1 => Ok(WordSpec::Lyndon {
                depth: self.u32()? as usize,
            }),
            2 => {
                let gamma = self.f64s()?;
                let cutoff = self.f64()?;
                Ok(WordSpec::Anisotropic { gamma, cutoff })
            }
            3 => {
                let depth = self.u32()? as usize;
                let n = self.u32()? as usize;
                if n > self.b.len() {
                    return Err(format!("dag row count {n} exceeds remaining bytes"));
                }
                let mut edges = Vec::with_capacity(n);
                for _ in 0..n {
                    edges.push(self.u16s()?);
                }
                Ok(WordSpec::Dag { depth, edges })
            }
            4 => {
                let depth = self.u32()? as usize;
                let n = self.u32()? as usize;
                if n > self.b.len() {
                    return Err(format!("generator count {n} exceeds remaining bytes"));
                }
                let mut generators = Vec::with_capacity(n);
                for _ in 0..n {
                    generators.push(Word(self.u16s()?));
                }
                Ok(WordSpec::ConcatGenerated { depth, generators })
            }
            5 => {
                let n = self.u32()? as usize;
                if n > self.b.len() {
                    return Err(format!("word count {n} exceeds remaining bytes"));
                }
                let mut words = Vec::with_capacity(n);
                for _ in 0..n {
                    words.push(Word(self.u16s()?));
                }
                Ok(WordSpec::Custom { words })
            }
            t => Err(format!("unknown word-spec tag {t}")),
        }
    }

    fn finish(self) -> Result<(), String> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes in record body", self.b.len()))
        }
    }
}

fn decode_body(kind: u8, body: &[u8]) -> Result<Record, String> {
    let mut c = Cur { b: body };
    let rec = match kind {
        kind::OPEN => Record::Open {
            id: c.u64()?,
            dim: c.u32()? as usize,
            window: c.u32()? as usize,
            spec: c.spec()?,
        },
        kind::PUSH => Record::Push {
            id: c.u64()?,
            samples: c.f64s()?,
        },
        kind::CLOSE => Record::Close { id: c.u64()? },
        kind::EVICT => Record::Evict { id: c.u64()? },
        kind::SNAP => {
            let id = c.u64()?;
            let dim = c.u32()? as usize;
            let spec = c.spec()?;
            let window = c.u32()? as usize;
            let n_seen = c.u64()? as usize;
            let back_len = c.u32()? as usize;
            let front_len = c.u32()? as usize;
            let last = c.f64s()?;
            let total = c.f64s()?;
            let back_agg = c.f64s()?;
            let back_dx = c.f64s()?;
            let front = c.f64s()?;
            Record::Snap {
                id,
                dim,
                spec,
                ck: StreamCheckpoint {
                    window,
                    n_seen,
                    back_len,
                    front_len,
                    last,
                    total,
                    back_agg,
                    back_dx,
                    front,
                },
            }
        }
        kind::CKPT_HEAD => Record::CkptHead {
            n_sessions: c.u32()? as usize,
        },
        k => return Err(format!("unknown record kind {k}")),
    };
    c.finish()?;
    Ok(rec)
}

/// Streaming validator/decoder over a byte buffer of records.
///
/// Yields `(seq, record)` pairs until the bytes run out or the first
/// invalid record; after iteration, [`RecordReader::good_len`] is the
/// clean-prefix length (the truncation point) and
/// [`RecordReader::error`] describes what stopped the scan, if
/// anything. A partial trailing record — the torn-write case — is an
/// error like any other corruption; callers decide whether to treat a
/// clean EOF differently.
pub struct RecordReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    good: usize,
    last_seq: Option<u64>,
    error: Option<String>,
}

impl<'a> RecordReader<'a> {
    /// Start scanning `bytes` from offset 0.
    pub fn new(bytes: &'a [u8]) -> RecordReader<'a> {
        RecordReader {
            bytes,
            pos: 0,
            good: 0,
            last_seq: None,
            error: None,
        }
    }

    /// Byte length of the valid record prefix scanned so far.
    pub fn good_len(&self) -> usize {
        self.good
    }

    /// What stopped the scan (`None` while scanning or on a clean EOF).
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    fn fail(&mut self, msg: String) -> Option<(u64, Record)> {
        self.error = Some(msg);
        None
    }

    /// Decode the next record, or `None` at EOF / first corruption.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(u64, Record)> {
        if self.error.is_some() || self.pos == self.bytes.len() {
            return None;
        }
        let rest = &self.bytes[self.pos..];
        if rest.len() < 4 {
            return self.fail(format!("torn length prefix ({} bytes)", rest.len()));
        }
        let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
        if len < 9 {
            return self.fail(format!("record payload too short ({len} bytes)"));
        }
        if len > MAX_RECORD_LEN {
            return self.fail(format!("record payload too long ({len} bytes)"));
        }
        if rest.len() < 4 + len + 4 {
            return self.fail(format!(
                "torn record: payload {len} + crc, only {} bytes left",
                rest.len() - 4
            ));
        }
        let payload = &rest[4..4 + len];
        let want = u32::from_le_bytes([
            rest[4 + len],
            rest[4 + len + 1],
            rest[4 + len + 2],
            rest[4 + len + 3],
        ]);
        let got = crc32(payload);
        if got != want {
            return self.fail(format!("crc mismatch: stored {want:#010x}, computed {got:#010x}"));
        }
        let kind = payload[0];
        let seq = u64::from_le_bytes([
            payload[1], payload[2], payload[3], payload[4], payload[5], payload[6], payload[7],
            payload[8],
        ]);
        if let Some(prev) = self.last_seq {
            if seq < prev {
                return self.fail(format!("sequence went backwards ({prev} → {seq})"));
            }
        }
        match decode_body(kind, &payload[9..]) {
            Ok(rec) => {
                self.pos += 4 + len + 4;
                self.good = self.pos;
                self.last_seq = Some(seq);
                Some((seq, rec))
            }
            Err(e) => self.fail(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_zlib_vectors() {
        // zlib.crc32(b"") == 0, zlib.crc32(b"123456789") == 0xCBF43926
        // (the classic CHECK value), zlib.crc32(b"hello") == 0x3610A686.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"hello"), 0x3610_A686);
    }

    fn all_specs() -> Vec<WordSpec> {
        vec![
            WordSpec::Truncated { depth: 3 },
            WordSpec::Lyndon { depth: 4 },
            WordSpec::Anisotropic {
                gamma: vec![1.0, 2.5],
                cutoff: 3.75,
            },
            WordSpec::Dag {
                depth: 2,
                edges: vec![vec![1], vec![0, 1]],
            },
            WordSpec::ConcatGenerated {
                depth: 4,
                generators: vec![Word(vec![0, 1]), Word(vec![1])],
            },
            WordSpec::Custom {
                words: vec![Word(vec![0]), Word(vec![1, 0, 1])],
            },
        ]
    }

    #[test]
    fn records_roundtrip() {
        let ck = StreamCheckpoint {
            window: 3,
            n_seen: 5,
            back_len: 1,
            front_len: 2,
            last: vec![0.5, -1.0],
            total: vec![1.0, 2.0, 3.0],
            back_agg: vec![1.0, 0.0, 0.25],
            back_dx: vec![0.125, -0.5],
            front: vec![1.0, 1.5, 2.5, 1.0, 0.5, 0.75],
        };
        let mut buf = Vec::new();
        for (i, spec) in all_specs().into_iter().enumerate() {
            encode_open(&mut buf, 2 * i as u64, 10 + i as u64, 2, 8, &spec);
            encode_snap(&mut buf, 2 * i as u64 + 1, 10 + i as u64, 2, &spec, &ck);
        }
        encode_push(&mut buf, 100, 7, &[0.5, 1.5, 2.5]);
        encode_close(&mut buf, 101, 7);
        encode_evict(&mut buf, 102, 8);
        encode_ckpt_head(&mut buf, 103, 6);
        let mut r = RecordReader::new(&buf);
        let mut n = 0;
        while let Some((seq, rec)) = r.next() {
            match rec {
                Record::Open { dim, window, .. } => {
                    assert_eq!((dim, window), (2, 8));
                }
                Record::Snap { ck: got, .. } => assert_eq!(got, ck),
                Record::Push { id, samples } => {
                    assert_eq!((id, seq), (7, 100));
                    assert_eq!(samples, vec![0.5, 1.5, 2.5]);
                }
                Record::Close { id } => assert_eq!(id, 7),
                Record::Evict { id } => assert_eq!(id, 8),
                Record::CkptHead { n_sessions } => assert_eq!(n_sessions, 6),
            }
            n += 1;
        }
        assert_eq!(n, 16);
        assert_eq!(r.error(), None);
        assert_eq!(r.good_len(), buf.len());
    }

    #[test]
    fn torn_tail_keeps_clean_prefix() {
        let mut buf = Vec::new();
        encode_open(&mut buf, 1, 1, 1, 2, &WordSpec::Truncated { depth: 2 });
        let clean = buf.len();
        encode_push(&mut buf, 2, 1, &[0.5]);
        // Tear the final record anywhere inside it: the reader must
        // still yield the first record and truncate at its end.
        for cut in clean + 1..buf.len() {
            let torn = &buf[..cut];
            let mut r = RecordReader::new(torn);
            assert!(matches!(r.next(), Some((1, Record::Open { .. }))));
            assert!(r.next().is_none());
            assert_eq!(r.good_len(), clean, "cut at {cut}");
            assert!(r.error().is_some(), "cut at {cut}");
        }
    }

    #[test]
    fn bit_flips_never_pass_the_crc() {
        let mut buf = Vec::new();
        encode_push(&mut buf, 9, 3, &[1.0, 2.0]);
        for i in 0..buf.len() * 8 {
            let mut bad = buf.clone();
            bad[i / 8] ^= 1 << (i % 8);
            let mut r = RecordReader::new(&bad);
            // Either the length prefix now lies (torn) or the crc
            // catches it; a flipped record must never decode.
            assert!(r.next().is_none(), "bit {i} slipped through");
            assert_eq!(r.good_len(), 0);
        }
    }

    #[test]
    fn sequence_regression_is_corruption() {
        let mut buf = Vec::new();
        encode_close(&mut buf, 5, 1);
        let clean = buf.len();
        encode_close(&mut buf, 4, 2);
        let mut r = RecordReader::new(&buf);
        assert!(r.next().is_some());
        assert!(r.next().is_none());
        assert_eq!(r.good_len(), clean);
        assert!(r.error().unwrap().contains("backwards"));
    }
}
