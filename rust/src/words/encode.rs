//! Appendix A: representation of words as base-`d` integers.
//!
//! A word `w = (i_1, …, i_n)` over the 0-based alphabet `{0, …, d-1}`
//! encodes as `φ_n(w) = Σ_j i_j d^{n-j}` (Definition A.1). The encoding is
//! level-wise bijective and order-preserving (Proposition A.2), and word
//! operations become integer arithmetic:
//!
//! * concatenation: `φ(u∘v) = φ(u)·d^{|v|} + φ(v)` (Proposition A.3),
//! * prefix extraction: `φ(u) = ⌊φ(w)/d^{|v|}⌋` (Corollary A.4),
//! * suffix extraction: `φ(v) = φ(w) mod d^{|v|}` (Corollary A.5).
//!
//! §A.2's packed-letters trick (decode once, then extract letters with
//! shifts/masks) is implemented in [`packed_letters`] / [`unpack_letter`].

use super::Word;

/// A word encoded as (level, base-d code). The pair is needed because
/// `φ_n` is only bijective per level (e.g. `(0)` and `(0,0)` both encode
/// to 0).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Encoded {
    /// Word length `n` (the level of the tensor algebra).
    pub level: u8,
    /// Base-`d` integer code `φ_n(w)`.
    pub code: u64,
}

/// `φ_n(w)` — base-`d` integer encoding of a word (Definition A.1).
///
/// Panics in debug mode if a letter is out of range or the code would
/// overflow `u64` (requires `|w|·log2(d) < 64`).
pub fn word_code(word: &[u16], d: usize) -> u64 {
    let mut code: u64 = 0;
    for &letter in word {
        debug_assert!((letter as usize) < d, "letter {letter} out of range for d={d}");
        code = code
            .checked_mul(d as u64)
            .and_then(|c| c.checked_add(letter as u64))
            .expect("word code overflows u64");
    }
    code
}

/// Decode a (level, code) pair back into letters.
pub fn decode(enc: Encoded, d: usize) -> Word {
    let mut letters = vec![0u16; enc.level as usize];
    let mut c = enc.code;
    for slot in letters.iter_mut().rev() {
        *slot = (c % d as u64) as u16;
        c /= d as u64;
    }
    debug_assert_eq!(c, 0, "code too large for level");
    Word(letters)
}

/// Proposition A.3: `φ(u∘v) = φ(u)·d^m + φ(v)` for `|v| = m`.
pub fn concat_code(u_code: u64, v_code: u64, v_len: usize, d: usize) -> u64 {
    u_code * (d as u64).pow(v_len as u32) + v_code
}

/// Corollary A.4: the code of the length-`k` prefix of a length-`n` word.
pub fn prefix_code(w_code: u64, n: usize, k: usize, d: usize) -> u64 {
    debug_assert!(k <= n);
    w_code / (d as u64).pow((n - k) as u32)
}

/// Corollary A.5: the code of the suffix of length `m`.
pub fn suffix_code(w_code: u64, m: usize, d: usize) -> u64 {
    w_code % (d as u64).pow(m as u32)
}

/// §A.2: pack the letters of a word into a single `u64`,
/// `Σ_j i_j · 2^{b(j-1)}` with `b = max(⌈log2 d⌉, 1)` bits per letter.
/// Returns `(packed, bits_per_letter)`. Panics if the word does not fit
/// (`b·n > 64`).
pub fn packed_letters(word: &[u16], d: usize) -> (u64, u32) {
    let b = bits_per_letter(d);
    assert!(
        b as usize * word.len() <= 64,
        "word of length {} does not fit at {} bits/letter",
        word.len(),
        b
    );
    let mut packed: u64 = 0;
    for (j, &letter) in word.iter().enumerate() {
        packed |= (letter as u64) << (b * j as u32);
    }
    (packed, b)
}

/// Extract letter `j` (0-based) from a packed representation.
#[inline]
pub fn unpack_letter(packed: u64, b: u32, j: usize) -> u16 {
    ((packed >> (b * j as u32)) & ((1u64 << b) - 1)) as u16
}

/// Bits needed per letter: `max(⌈log2 d⌉, 1)`.
pub fn bits_per_letter(d: usize) -> u32 {
    usize::BITS - (d - 1).max(1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_word(rng: &mut Rng, d: usize, n: usize) -> Vec<u16> {
        (0..n).map(|_| rng.below(d) as u16).collect()
    }

    #[test]
    fn encoding_bijective_per_level() {
        // Every word of W_3 over d=3 gets a distinct code in [0, 27).
        let d = 3;
        let mut seen = std::collections::HashSet::new();
        for a in 0..d as u16 {
            for b in 0..d as u16 {
                for c in 0..d as u16 {
                    let code = word_code(&[a, b, c], d);
                    assert!(code < 27);
                    assert!(seen.insert(code));
                }
            }
        }
        assert_eq!(seen.len(), 27);
    }

    #[test]
    fn encoding_preserves_lex_order() {
        let d = 4;
        let w1 = [0u16, 2, 3];
        let w2 = [0u16, 3, 0];
        assert!(word_code(&w1, d) < word_code(&w2, d));
    }

    #[test]
    fn decode_roundtrip_random() {
        let mut rng = Rng::new(41);
        for _ in 0..200 {
            let d = rng.range(2, 10);
            let n = rng.range(0, 8);
            let w = random_word(&mut rng, d, n);
            let enc = Encoded {
                level: n as u8,
                code: word_code(&w, d),
            };
            assert_eq!(decode(enc, d).0, w);
        }
    }

    #[test]
    fn concat_matches_direct_encoding() {
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let d = rng.range(2, 8);
            let nu = rng.range(0, 5);
            let u = random_word(&mut rng, d, nu);
            let nv = rng.range(0, 5);
            let v = random_word(&mut rng, d, nv);
            let mut uv = u.clone();
            uv.extend_from_slice(&v);
            assert_eq!(
                concat_code(word_code(&u, d), word_code(&v, d), v.len(), d),
                word_code(&uv, d)
            );
        }
    }

    #[test]
    fn prefix_suffix_extraction() {
        let mut rng = Rng::new(43);
        for _ in 0..200 {
            let d = rng.range(2, 8);
            let n = rng.range(1, 7);
            let w = random_word(&mut rng, d, n);
            let code = word_code(&w, d);
            for k in 0..=n {
                assert_eq!(
                    prefix_code(code, n, k, d),
                    word_code(&w[..k], d),
                    "prefix k={k} of {w:?}"
                );
                assert_eq!(
                    suffix_code(code, n - k, d),
                    word_code(&w[k..], d),
                    "suffix from {k} of {w:?}"
                );
            }
        }
    }

    #[test]
    fn packed_letters_roundtrip() {
        let mut rng = Rng::new(44);
        for _ in 0..200 {
            let d = rng.range(2, 40);
            let b = bits_per_letter(d) as usize;
            let nmax = (64 / b).min(10);
            let n = rng.range(1, nmax);
            let w = random_word(&mut rng, d, n);
            let (packed, bits) = packed_letters(&w, d);
            for (j, &want) in w.iter().enumerate() {
                assert_eq!(unpack_letter(packed, bits, j), want);
            }
        }
    }

    #[test]
    fn bits_per_letter_values() {
        assert_eq!(bits_per_letter(2), 1);
        assert_eq!(bits_per_letter(3), 2);
        assert_eq!(bits_per_letter(4), 2);
        assert_eq!(bits_per_letter(5), 3);
        assert_eq!(bits_per_letter(40), 6);
    }

    #[test]
    #[should_panic]
    fn packed_letters_overflow_panics() {
        // 40 letters at 2 bits each = 80 bits > 64.
        let w = vec![1u16; 40];
        packed_letters(&w, 3);
    }
}
