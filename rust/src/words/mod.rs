//! Words over the alphabet `{0, …, d-1}` — the index set of the tensor
//! algebra's canonical basis (paper §2.3 and Appendix A).
//!
//! Everything the engines consume is derived here:
//!
//! * [`encode`] — the paper's Appendix-A base-`d` integer encoding with
//!   arithmetic concatenation / prefix / suffix extraction, plus the §A.2
//!   packed-letters bit layout.
//! * [`Word`] — an owned word (sequence of 0-based letters).
//! * [`table::WordTable`] — the flat, prefix-closed computation table
//!   (letters, prefix indices, level ranges, output projection) used by
//!   the signature engines and mirrored bit-for-bit by
//!   `python/compile/words.py` for the Pallas kernels.
//! * [`generate`] — word-set generators: truncation, anisotropic (§7.2),
//!   DAG-induced (§7.1), concatenation-generated (§8), custom lists.
//! * [`lyndon`] — Lyndon words (Duval's algorithm) for the log-signature
//!   basis (§3.3).

pub mod encode;
pub mod generate;
pub mod lyndon;
pub mod table;

pub use encode::{concat_code, packed_letters, prefix_code, suffix_code, word_code, Encoded};
pub use generate::{
    anisotropic_words, concat_generated_words, dag_words, truncated_words, WordSpec,
};
pub use lyndon::{lyndon_words, lyndon_words_at_level};
pub use table::WordTable;

/// A word: a finite sequence of 0-based letters `0..d`. The empty word is
/// `Word(vec![])`.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Word(pub Vec<u16>);

impl Word {
    /// The empty word ε.
    pub fn empty() -> Word {
        Word(Vec::new())
    }

    /// Word length `|w|` (number of letters).
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// Whether this is the empty word ε.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// The prefix of length `k` (paper notation `w_[k]`).
    pub fn prefix(&self, k: usize) -> Word {
        Word(self.0[..k].to_vec())
    }

    /// The suffix starting after position `k` (so `w = w_[k] ∘ suffix`).
    pub fn suffix_from(&self, k: usize) -> Word {
        Word(self.0[k..].to_vec())
    }

    /// Concatenation `self ∘ other` (Definition 2.5).
    pub fn concat(&self, other: &Word) -> Word {
        let mut v = self.0.clone();
        v.extend_from_slice(&other.0);
        Word(v)
    }

    /// Weighted degree `|w|_γ` (Definition 7.1). Plain length when all
    /// weights are 1.
    pub fn weighted_degree(&self, gamma: &[f64]) -> f64 {
        self.0.iter().map(|&i| gamma[i as usize]).sum()
    }

    /// All proper and improper prefixes, shortest first (including ε and
    /// the word itself).
    pub fn prefixes(&self) -> impl Iterator<Item = Word> + '_ {
        (0..=self.len()).map(move |k| self.prefix(k))
    }

    /// Render as e.g. `(1,3,2)` with 1-based letters, matching the
    /// paper's notation. ε renders as `ε`.
    pub fn pretty(&self) -> String {
        if self.is_empty() {
            return "ε".to_string();
        }
        let parts: Vec<String> = self.0.iter().map(|&i| (i + 1).to_string()).collect();
        format!("({})", parts.join(","))
    }
}

impl From<&[u16]> for Word {
    fn from(s: &[u16]) -> Word {
        Word(s.to_vec())
    }
}

impl<const K: usize> From<[u16; K]> for Word {
    fn from(s: [u16; K]) -> Word {
        Word(s.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_suffix_partition() {
        let w = Word(vec![0, 2, 1, 3]);
        for k in 0..=4 {
            let joined = w.prefix(k).concat(&w.suffix_from(k));
            assert_eq!(joined, w);
        }
    }

    #[test]
    fn weighted_degree_reduces_to_length() {
        let w = Word(vec![0, 1, 0]);
        assert_eq!(w.weighted_degree(&[1.0, 1.0]), 3.0);
        assert_eq!(w.weighted_degree(&[0.5, 2.0]), 3.0);
    }

    #[test]
    fn pretty_is_one_based() {
        assert_eq!(Word(vec![0, 2]).pretty(), "(1,3)");
        assert_eq!(Word::empty().pretty(), "ε");
    }

    #[test]
    fn prefixes_enumerate_all() {
        let w = Word(vec![1, 0]);
        let ps: Vec<Word> = w.prefixes().collect();
        assert_eq!(
            ps,
            vec![Word::empty(), Word(vec![1]), Word(vec![1, 0])]
        );
    }
}
