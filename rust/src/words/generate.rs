//! Word-set generators — the projections of §7 plus plain truncation.
//!
//! Each generator returns a plain `Vec<Word>` (the *requested* output
//! coordinates, in a deterministic order). Engines then build a
//! [`super::WordTable`], which computes the prefix closure needed for
//! Chen's relation.

use super::{lyndon::lyndon_words, Word};

/// A declarative word-set specification — the coordinator's wire-level
/// description of a projection (parsed from request JSON).
#[derive(Clone, Debug, PartialEq)]
pub enum WordSpec {
    /// `W_{≤N}`: full truncation at depth N (§2.1).
    Truncated {
        /// Truncation depth `N`.
        depth: usize,
    },
    /// `W^γ_{≤r}`: anisotropic truncation (Definition 7.1).
    Anisotropic {
        /// One positive weight per channel.
        gamma: Vec<f64>,
        /// Weighted-degree cutoff `r`.
        cutoff: f64,
    },
    /// `W_{≤N}(G)`: words tracing edges of a DAG/digraph on channels
    /// (§7.1). `edges[i]` lists the letters allowed to follow letter `i`.
    Dag {
        /// Maximum word length `N`.
        depth: usize,
        /// Adjacency lists, one per channel.
        edges: Vec<Vec<u16>>,
    },
    /// Concatenations of a generator set with `|w| ≤ depth` (§8's sparse
    /// lead–lag construction).
    ConcatGenerated {
        /// Maximum total word length.
        depth: usize,
        /// Generator words (ε entries ignored).
        generators: Vec<Word>,
    },
    /// Lyndon words up to `depth` (the log-signature output set).
    Lyndon {
        /// Maximum word length.
        depth: usize,
    },
    /// An explicit list.
    Custom {
        /// The requested words, output order.
        words: Vec<Word>,
    },
}

impl WordSpec {
    /// Materialise the word set for alphabet size `d`.
    pub fn words(&self, d: usize) -> Vec<Word> {
        match self {
            WordSpec::Truncated { depth } => truncated_words(d, *depth),
            WordSpec::Anisotropic { gamma, cutoff } => anisotropic_words(d, gamma, *cutoff),
            WordSpec::Dag { depth, edges } => dag_words(d, *depth, edges),
            WordSpec::ConcatGenerated { depth, generators } => {
                concat_generated_words(d, *depth, generators)
            }
            WordSpec::Lyndon { depth } => lyndon_words(d, *depth),
            WordSpec::Custom { words } => words.clone(),
        }
    }

    /// Short description used in artifact names and logs.
    pub fn describe(&self) -> String {
        match self {
            WordSpec::Truncated { depth } => format!("trunc_n{depth}"),
            WordSpec::Anisotropic { cutoff, .. } => format!("aniso_r{cutoff}"),
            WordSpec::Dag { depth, .. } => format!("dag_n{depth}"),
            WordSpec::ConcatGenerated { depth, .. } => format!("gen_n{depth}"),
            WordSpec::Lyndon { depth } => format!("lyndon_n{depth}"),
            WordSpec::Custom { words } => format!("custom_{}", words.len()),
        }
    }
}

/// All non-empty words of length `1..=depth`: `W_{≤N} \ {ε}`, ordered by
/// (level, lexicographic) — the canonical truncated-signature layout.
/// Size `Σ_{n=1}^{N} d^n` (the paper's `D_sig`).
pub fn truncated_words(d: usize, depth: usize) -> Vec<Word> {
    let mut out = Vec::new();
    let mut level: Vec<Word> = vec![Word::empty()];
    for _ in 1..=depth {
        let mut next = Vec::with_capacity(level.len() * d);
        for w in &level {
            for letter in 0..d as u16 {
                let mut v = w.0.clone();
                v.push(letter);
                next.push(Word(v));
            }
        }
        out.extend(next.iter().cloned());
        level = next;
    }
    out
}

/// The truncated signature dimension `D_sig = Σ_{n=1}^N d^n` (paper §6.2).
pub fn sig_dim(d: usize, depth: usize) -> usize {
    (1..=depth).map(|n| d.pow(n as u32)).sum()
}

/// Anisotropic words `W^γ_{≤r} \ {ε}` (Definition 7.1): all words with
/// weighted degree `Σ γ_{i_j} ≤ r`. Requires all `γ_i > 0` so the set is
/// finite. Ordered by (level, lex).
pub fn anisotropic_words(d: usize, gamma: &[f64], cutoff: f64) -> Vec<Word> {
    assert_eq!(gamma.len(), d, "need one weight per channel");
    assert!(gamma.iter().all(|&g| g > 0.0), "weights must be positive");
    let mut out = Vec::new();
    // BFS by level; a word is extendable only if some extension stays
    // under the cutoff.
    let mut frontier: Vec<(Word, f64)> = vec![(Word::empty(), 0.0)];
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for (w, deg) in &frontier {
            for letter in 0..d as u16 {
                let nd = deg + gamma[letter as usize];
                if nd <= cutoff + 1e-12 {
                    let mut v = w.0.clone();
                    v.push(letter);
                    next.push((Word(v), nd));
                }
            }
        }
        out.extend(next.iter().map(|(w, _)| w.clone()));
        frontier = next;
    }
    out
}

/// DAG-induced words `W_{≤N}(G) \ {ε}` (§7.1): words whose consecutive
/// letter pairs trace edges of the digraph. `edges[i]` = letters allowed
/// after letter `i` (need not be acyclic — any digraph works).
pub fn dag_words(d: usize, depth: usize, edges: &[Vec<u16>]) -> Vec<Word> {
    assert_eq!(edges.len(), d, "need an adjacency list per channel");
    let mut out = Vec::new();
    let mut frontier: Vec<Word> = (0..d as u16).map(|i| Word(vec![i])).collect();
    for _ in 0..depth {
        out.extend(frontier.iter().cloned());
        let mut next = Vec::new();
        for w in &frontier {
            let last = *w.0.last().unwrap() as usize;
            for &nxt in &edges[last] {
                let mut v = w.0.clone();
                v.push(nxt);
                next.push(Word(v));
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    // Frontier words beyond depth are dropped: out currently holds
    // lengths 1..=depth (loop pushed before extending).
    out.retain(|w| w.len() <= depth);
    out.sort_by_key(|w| (w.len(), w.0.clone()));
    out
}

/// §8: all concatenations `u_1 ∘ … ∘ u_p` of the generator words with
/// total length ≤ `depth` (ε excluded). Deduplicated, ordered (level,
/// lex). This is the paper's sparse lead–lag construction when the
/// generators are `{(L_i)} ∪ {(ℓ_i, L_i), (L_i, ℓ_i)}`.
pub fn concat_generated_words(d: usize, depth: usize, generators: &[Word]) -> Vec<Word> {
    let mut seen = std::collections::HashSet::new();
    let mut out: Vec<Word> = Vec::new();
    let gens: Vec<&Word> = generators.iter().filter(|g| !g.is_empty()).collect();
    for g in &gens {
        assert!(
            g.0.iter().all(|&l| (l as usize) < d),
            "generator letter out of range"
        );
    }
    // BFS over concatenation depth.
    let mut frontier: Vec<Word> = vec![Word::empty()];
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for w in &frontier {
            for g in &gens {
                if w.len() + g.len() <= depth {
                    let cat = w.concat(g);
                    if seen.insert(cat.clone()) {
                        next.push(cat);
                    }
                }
            }
        }
        out.extend(next.iter().cloned());
        frontier = next;
    }
    out.sort_by_key(|w| (w.len(), w.0.clone()));
    out
}

/// §8's sparse lead–lag generator set for a `dim`-channel base path whose
/// lead–lag lift has channels `(ℓ_1,…,ℓ_dim, L_1,…,L_dim)`; letter `i` is
/// the lag channel and `dim + i` the lead channel of coordinate `i`.
///
/// `G = {(L_i)} ∪ {(ℓ_i, L_i), (L_i, ℓ_i) : i}` — single lead letters plus
/// same-coordinate lead/lag area pairs (cross-coordinate pairs are
/// excluded because independent components have zero quadratic
/// covariation).
pub fn sparse_leadlag_generators(dim: usize) -> Vec<Word> {
    let mut gens = Vec::new();
    for i in 0..dim as u16 {
        let lag = i;
        let lead = dim as u16 + i;
        gens.push(Word(vec![lead]));
        gens.push(Word(vec![lag, lead]));
        gens.push(Word(vec![lead, lag]));
    }
    gens
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncated_count_is_sig_dim() {
        for d in 2..=4 {
            for n in 1..=4 {
                assert_eq!(truncated_words(d, n).len(), sig_dim(d, n));
            }
        }
        // Paper Table 1 sanity: d=6, N=3 → 258.
        assert_eq!(sig_dim(6, 3), 258);
        // d=6, N=6 → 55986 ≈ "56.0K".
        assert_eq!(sig_dim(6, 6), 55986);
        // d=8, N=6 → 299592 ≈ "299.6K" (Table 2).
        assert_eq!(sig_dim(8, 6), 299592);
        // d=10, N=4 → 11110 ≈ "11.1K" (Table 1).
        assert_eq!(sig_dim(10, 4), 11110);
        // d=4, N=6 → 5460 ≈ "5.5K" (Table 1).
        assert_eq!(sig_dim(4, 6), 5460);
    }

    #[test]
    fn truncated_level_lex_order() {
        let ws = truncated_words(3, 3);
        for pair in ws.windows(2) {
            assert!((pair[0].len(), &pair[0].0) < (pair[1].len(), &pair[1].0));
        }
    }

    #[test]
    fn anisotropic_unit_weights_equal_truncation() {
        let d = 3;
        let got = anisotropic_words(d, &[1.0; 3], 4.0);
        let want = truncated_words(d, 4);
        assert_eq!(got, want);
    }

    #[test]
    fn anisotropic_heavy_channel_restricted() {
        // Channel 1 has weight 2: words using it twice need degree ≥ 4.
        let ws = anisotropic_words(2, &[1.0, 2.0], 3.0);
        assert!(ws.contains(&Word(vec![0, 0, 0])));
        assert!(ws.contains(&Word(vec![1, 0])));
        assert!(!ws.contains(&Word(vec![1, 1])));
        // Prefix-closed by construction.
        for w in &ws {
            for k in 1..w.len() {
                assert!(ws.contains(&w.prefix(k)));
            }
        }
    }

    #[test]
    fn dag_chain_graph() {
        // 0 → 1 → 2, no other edges.
        let edges = vec![vec![1u16], vec![2u16], vec![]];
        let ws = dag_words(3, 3, &edges);
        assert!(ws.contains(&Word(vec![0, 1, 2])));
        assert!(ws.contains(&Word(vec![1, 2])));
        assert!(!ws.iter().any(|w| w.0.windows(2).any(|p| p == [1, 0])));
        // Levels: 3 singles + 2 pairs + 1 triple.
        assert_eq!(ws.len(), 6);
    }

    #[test]
    fn dag_complete_graph_equals_truncation() {
        let d = 3;
        let edges: Vec<Vec<u16>> = (0..d).map(|_| (0..d as u16).collect()).collect();
        assert_eq!(dag_words(d, 3, &edges), truncated_words(d, 3));
    }

    #[test]
    fn concat_generated_counts_match_composition_formula() {
        // Generators: 5 lead singles + 10 pairs (dim 5 lead–lag, §8).
        let gens = sparse_leadlag_generators(5);
        let ws = concat_generated_words(10, 4, &gens);
        // Naive composition counts are c_1=5, c_2=5²+10=35,
        // c_3=5³+2·5·10=225, c_4=5⁴+3·25·10+100=1725, but distinct
        // WORDS are fewer because decompositions collide (e.g.
        // L_i∘(ℓ_i,L_i) = (L_i,ℓ_i)∘L_i). Golden values verified by
        // exhaustive enumeration: 5 / 35 / 220 / 1425.
        let by_len = |n: usize| ws.iter().filter(|w| w.len() == n).count();
        assert_eq!(by_len(1), 5);
        assert_eq!(by_len(2), 35);
        assert_eq!(by_len(3), 220);
        assert_eq!(by_len(4), 1425);
        assert_eq!(ws.len(), 1685);
    }

    #[test]
    fn concat_generated_dedups() {
        // Generators (0) and (0,0) produce overlapping concatenations.
        let gens = vec![Word(vec![0]), Word(vec![0, 0])];
        let ws = concat_generated_words(1, 3, &gens);
        assert_eq!(ws.len(), 3); // (0), (0,0), (0,0,0)
    }

    #[test]
    fn wordspec_roundtrip_describe() {
        let spec = WordSpec::Truncated { depth: 4 };
        assert_eq!(spec.describe(), "trunc_n4");
        assert_eq!(spec.words(3).len(), sig_dim(3, 4));
    }
}
