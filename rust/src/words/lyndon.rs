//! Lyndon words via Duval's algorithm.
//!
//! Lyndon words index the paper's log-signature basis (§3.3): the
//! coefficients of the tensor logarithm at Lyndon-word indices form the
//! "computationally efficient Lie basis" of Signatory that `pathsig`
//! adopts. A word is Lyndon iff it is strictly smaller (lexicographically)
//! than all of its proper rotations.

use super::Word;

/// All Lyndon words over `{0,…,d-1}` of length `1..=max_len`, in
/// lexicographic order (which Duval produces naturally).
pub fn lyndon_words(d: usize, max_len: usize) -> Vec<Word> {
    assert!(d >= 1);
    let mut out = Vec::new();
    if max_len == 0 {
        return out;
    }
    // Duval's generation algorithm.
    let mut w: Vec<u16> = vec![0];
    loop {
        if w.len() <= max_len {
            out.push(Word(w.clone()));
        }
        // Extend periodically up to max_len…
        let base = w.clone();
        while w.len() < max_len {
            let next = base[(w.len()) % base.len()];
            w.push(next);
        }
        // …then increment the last non-maximal letter.
        while let Some(&last) = w.last() {
            if last as usize == d - 1 {
                w.pop();
            } else {
                *w.last_mut().unwrap() += 1;
                break;
            }
        }
        if w.is_empty() {
            break;
        }
    }
    out
}

/// Lyndon words of exactly length `n`.
pub fn lyndon_words_at_level(d: usize, n: usize) -> Vec<Word> {
    lyndon_words(d, n).into_iter().filter(|w| w.len() == n).collect()
}

/// Witt's formula: the number of Lyndon words of length `n` over a
/// `d`-letter alphabet, `(1/n) Σ_{k|n} μ(k) d^{n/k}` — equals the
/// dimension of the degree-`n` component of the free Lie algebra, hence
/// the level-`n` log-signature dimension.
pub fn witt_count(d: usize, n: usize) -> usize {
    assert!(n >= 1);
    let mut total: i128 = 0;
    for k in 1..=n {
        if n % k == 0 {
            total += moebius(k) as i128 * (d as i128).pow((n / k) as u32);
        }
    }
    (total / n as i128) as usize
}

/// Total log-signature dimension up to depth `N` (sum of Witt counts).
pub fn logsig_dim(d: usize, depth: usize) -> usize {
    (1..=depth).map(|n| witt_count(d, n)).sum()
}

/// Möbius function μ(k).
fn moebius(mut k: usize) -> i64 {
    let mut primes = 0;
    let mut p = 2;
    while p * p <= k {
        if k % p == 0 {
            k /= p;
            if k % p == 0 {
                return 0; // squared factor
            }
            primes += 1;
        }
        p += 1;
    }
    if k > 1 {
        primes += 1;
    }
    if primes % 2 == 0 {
        1
    } else {
        -1
    }
}

/// Check the Lyndon property directly (used in tests; O(n²)).
pub fn is_lyndon(w: &[u16]) -> bool {
    if w.is_empty() {
        return false;
    }
    let n = w.len();
    for r in 1..n {
        let rotated: Vec<u16> = w[r..].iter().chain(&w[..r]).copied().collect();
        if rotated.as_slice() <= w {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_witt_formula() {
        for d in 2..=5 {
            for n in 1..=6 {
                let got = lyndon_words_at_level(d, n).len();
                assert_eq!(got, witt_count(d, n), "d={d} n={n}");
            }
        }
    }

    #[test]
    fn known_small_counts() {
        // d=2: 2, 1, 2, 3, 6, 9 at levels 1..6.
        let want = [2, 1, 2, 3, 6, 9];
        for (n, &w) in want.iter().enumerate() {
            assert_eq!(witt_count(2, n + 1), w);
        }
        // Paper Table 3: (d=6, N=3) logsig dim 91 → minus? The paper's 91
        // at (32,100,6) N=3: 6 + 15 + 70 = 91.
        assert_eq!(logsig_dim(6, 3), 91);
        // Table 3: (d=6, N=4): 406 = 91 + 315.
        assert_eq!(logsig_dim(6, 4), 406);
        // Table 3: (d=4, N=6): 964.
        assert_eq!(logsig_dim(4, 6), 964);
        // Table 3: (d=10, N=4): 2.9K = 10 + 45 + 330 + 2475.
        assert_eq!(logsig_dim(10, 4), 2860);
    }

    #[test]
    fn all_generated_are_lyndon() {
        for d in 2..=4 {
            for w in lyndon_words(d, 5) {
                assert!(is_lyndon(&w.0), "{:?} not lyndon", w);
            }
        }
    }

    #[test]
    fn lexicographic_order() {
        let ws = lyndon_words(3, 4);
        for pair in ws.windows(2) {
            assert!(pair[0].0 < pair[1].0);
        }
    }

    #[test]
    fn no_duplicates() {
        let ws = lyndon_words(3, 6);
        let set: std::collections::HashSet<_> = ws.iter().collect();
        assert_eq!(set.len(), ws.len());
    }

    #[test]
    fn single_letter_alphabet() {
        let ws = lyndon_words(1, 5);
        assert_eq!(ws, vec![Word(vec![0])]);
    }
}
