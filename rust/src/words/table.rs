//! The flat, prefix-closed computation table consumed by every signature
//! engine (§3.1–§3.2 of the paper).
//!
//! Given a requested word set `I`, the table holds the **prefix closure**
//! `C(I)` — the smallest prefix-closed superset (Definition 3.3) — sorted
//! by (level, lexicographic), with the empty word at state index 0. Per
//! word it stores the letters and the state indices of all proper
//! prefixes, so Algorithm 1's Horner update is a pair of flat gathers.
//!
//! Storage is **level-major CSR**: word `i` of length `n` owns the slots
//! `csr_start[i] .. csr_start[i] + n` of `csr_letters` / `csr_prefix`,
//! and because words are sorted by level those slots are densely packed
//! per level with no padding to `max_level` — projected and anisotropic
//! sets (mostly-short words) waste no cache lines on stride slack, and
//! a level sweep reads the metadata arrays strictly sequentially. The
//! strided `(state_len, max_level)` layout consumed by the Pallas
//! kernel is reconstructed on demand in [`WordTable::to_json`]
//! (golden-file cross-checked against `python/compile/words.py`).

use super::{encode::word_code, Word};
use std::collections::HashMap;

/// Flat word table over the prefix closure of a requested word set.
#[derive(Clone, Debug)]
pub struct WordTable {
    /// Alphabet size `d`.
    pub d: usize,
    /// Maximum word length in the closure (`N`).
    pub max_level: usize,
    /// Number of state entries (closure size, including ε at index 0).
    pub state_len: usize,
    /// The closure words in state order (index 0 = ε).
    pub words: Vec<Word>,
    /// `level_start[n]..level_start[n+1]` is the state-index range of
    /// level-`n` words; `level_start.len() == max_level + 2`.
    pub level_start: Vec<usize>,
    /// CSR row starts: word `i` owns `csr_start[i]..csr_start[i+1]`
    /// (`|w_i|` slots) of the packed arrays; `csr_start.len() ==
    /// state_len + 1`. Within a level all rows have equal length, so
    /// `csr_start[i] = csr_start[level_start[n]] + (i - level_start[n])·n`.
    pub csr_start: Vec<u32>,
    /// Packed letters: `csr_letters[csr_start[i] + t]` = letter `i_{t+1}`
    /// of word `i`.
    pub csr_letters: Vec<u16>,
    /// Packed prefix state indices: `csr_prefix[csr_start[i] + k]` =
    /// state index of `w_[k]` (entry `k = 0` is always 0 = ε).
    pub csr_prefix: Vec<u32>,
    /// State indices of the *requested* words, in request order — the
    /// output projection `π_I` (§7.1).
    pub output_map: Vec<u32>,
    /// The requested words (request order), for introspection.
    pub requested: Vec<Word>,
}

impl WordTable {
    /// Build the table for requested word set `request` over alphabet
    /// `d`. ε entries in the request are rejected (the signature at ε is
    /// identically 1). Duplicates in the request are allowed and map to
    /// the same state index.
    ///
    /// # Examples
    ///
    /// ```
    /// use pathsig::words::{Word, WordTable};
    ///
    /// // Requesting a single deep word materialises only its prefix
    /// // chain — not the full truncated set.
    /// let table = WordTable::build(3, &[Word(vec![2, 0, 1])]);
    /// assert_eq!(table.state_len, 4); // ε, (3), (3,1), (3,1,2)
    /// assert_eq!(table.out_dim(), 1);
    /// table.check_invariants();
    /// ```
    pub fn build(d: usize, request: &[Word]) -> WordTable {
        assert!(d >= 1, "alphabet must be non-empty");
        for w in request {
            assert!(!w.is_empty(), "ε is not a valid output coordinate");
            assert!(
                w.0.iter().all(|&l| (l as usize) < d),
                "letter out of range in {:?}",
                w
            );
        }

        // Prefix closure, keyed by (level, base-d code).
        let mut closure: HashMap<(u8, u64), Word> = HashMap::new();
        closure.insert((0, 0), Word::empty());
        for w in request {
            for k in 1..=w.len() {
                let p = w.prefix(k);
                let key = (k as u8, word_code(&p.0, d));
                closure.entry(key).or_insert(p);
            }
        }

        // Sort by (level, code) — code order == lex order per level
        // (Proposition A.2).
        let mut entries: Vec<((u8, u64), Word)> = closure.into_iter().collect();
        entries.sort_by_key(|(key, _)| *key);

        let max_level = entries.last().map(|((l, _), _)| *l as usize).unwrap_or(0);
        let state_len = entries.len();

        let mut index_of: HashMap<(u8, u64), u32> = HashMap::with_capacity(state_len);
        let mut words = Vec::with_capacity(state_len);
        let mut level_start = vec![0usize; max_level + 2];
        for (i, ((lvl, code), w)) in entries.iter().enumerate() {
            index_of.insert((*lvl, *code), i as u32);
            words.push(w.clone());
            level_start[*lvl as usize + 1] = i + 1;
        }
        // Forward-fill empty levels (possible only in degenerate cases).
        for n in 1..level_start.len() {
            if level_start[n] < level_start[n - 1] {
                level_start[n] = level_start[n - 1];
            }
        }

        // Level-major CSR packing: |w| slots per word, no stride waste.
        let total: usize = words.iter().map(|w| w.len()).sum();
        let mut csr_start = Vec::with_capacity(state_len + 1);
        let mut csr_letters = Vec::with_capacity(total);
        let mut csr_prefix = Vec::with_capacity(total);
        for w in &words {
            csr_start.push(csr_letters.len() as u32);
            for (k, &l) in w.0.iter().enumerate() {
                csr_letters.push(l);
                let key = (k as u8, word_code(&w.0[..k], d));
                csr_prefix.push(index_of[&key]);
            }
        }
        csr_start.push(csr_letters.len() as u32);

        let output_map = request
            .iter()
            .map(|w| index_of[&(w.len() as u8, word_code(&w.0, d))])
            .collect();

        WordTable {
            d,
            max_level,
            state_len,
            words,
            level_start,
            csr_start,
            csr_letters,
            csr_prefix,
            output_map,
            requested: request.to_vec(),
        }
    }

    /// State-index range of level-`n` words.
    #[inline]
    pub fn level_range(&self, n: usize) -> std::ops::Range<usize> {
        self.level_start[n]..self.level_start[n + 1]
    }

    /// CSR offset of the first level-`n` word's row (level rows are
    /// contiguous and `n` slots each, so word `level_start[n] + k` has
    /// its row at `level_csr_base(n) + k·n`).
    #[inline]
    pub fn level_csr_base(&self, n: usize) -> usize {
        self.csr_start[self.level_start[n]] as usize
    }

    /// Number of output coordinates `|I|`.
    #[inline]
    pub fn out_dim(&self) -> usize {
        self.output_map.len()
    }

    /// Whether the request was exactly the closure minus ε, in state
    /// order (true for truncated/anisotropic/DAG sets). Engines can then
    /// skip the gather in the output projection.
    pub fn output_is_identity(&self) -> bool {
        self.output_map.len() == self.state_len - 1
            && self
                .output_map
                .iter()
                .enumerate()
                .all(|(k, &i)| i as usize == k + 1)
    }

    /// Project a closure state vector onto the requested coordinates.
    pub fn project(&self, state: &[f64], out: &mut [f64]) {
        debug_assert_eq!(state.len(), self.state_len);
        debug_assert_eq!(out.len(), self.out_dim());
        for (o, &idx) in out.iter_mut().zip(&self.output_map) {
            *o = state[idx as usize];
        }
    }

    /// Scatter output-cotangents back onto a closure-sized state vector
    /// (adjoint of [`WordTable::project`]; accumulates on duplicates).
    pub fn scatter_grad(&self, grad_out: &[f64], grad_state: &mut [f64]) {
        debug_assert_eq!(grad_out.len(), self.out_dim());
        debug_assert_eq!(grad_state.len(), self.state_len);
        for (g, &idx) in grad_out.iter().zip(&self.output_map) {
            grad_state[idx as usize] += *g;
        }
    }

    /// Verify structural invariants (used by property tests).
    pub fn check_invariants(&self) {
        // ε at index 0.
        assert!(self.words[0].is_empty());
        assert_eq!(self.csr_start.len(), self.state_len + 1);
        for (i, w) in self.words.iter().enumerate() {
            let n = w.len();
            let base = self.csr_start[i] as usize;
            // CSR row width equals the word length.
            assert_eq!(
                self.csr_start[i + 1] as usize - base,
                n,
                "csr row width wrong for word {i}"
            );
            // Level ranges consistent, and the level-major closed form
            // for the row offset holds.
            assert!(self.level_range(n).contains(&i), "word {i} not in its level range");
            assert_eq!(
                base,
                self.level_csr_base(n) + (i - self.level_start[n]) * n,
                "csr row offset not level-major for word {i}"
            );
            // Prefix pointers point at the true prefixes.
            for k in 0..n {
                let p = &self.words[self.csr_prefix[base + k] as usize];
                assert_eq!(p.0, w.0[..k], "prefix table wrong for word {i} k={k}");
            }
            // Letters as stored.
            for (t, &l) in w.0.iter().enumerate() {
                assert_eq!(self.csr_letters[base + t], l);
            }
        }
        // Sorted by (level, lex) and unique.
        for pair in self.words.windows(2) {
            assert!((pair[0].len(), &pair[0].0) < (pair[1].len(), &pair[1].0));
        }
        // Output map points at the requested words.
        for (w, &idx) in self.requested.iter().zip(&self.output_map) {
            assert_eq!(&self.words[idx as usize], w);
        }
    }

    /// Serialize to JSON (artifact-manifest format shared with
    /// `python/compile/words.py`). The manifest keeps the Pallas
    /// kernel's strided `(state_len, max_level)` layout, reconstructed
    /// here from the CSR rows.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let stride = self.max_level.max(1);
        let mut letters = vec![0u16; self.state_len * stride];
        let mut prefix_idx = vec![0u32; self.state_len * stride];
        for (i, w) in self.words.iter().enumerate() {
            let base = self.csr_start[i] as usize;
            for k in 0..w.len() {
                letters[i * stride + k] = self.csr_letters[base + k];
                prefix_idx[i * stride + k] = self.csr_prefix[base + k];
            }
        }
        Json::obj(vec![
            ("d", Json::Num(self.d as f64)),
            ("max_level", Json::Num(self.max_level as f64)),
            ("state_len", Json::Num(self.state_len as f64)),
            (
                "letters",
                Json::Arr(letters.iter().map(|&l| Json::Num(l as f64)).collect()),
            ),
            (
                "prefix_idx",
                Json::Arr(prefix_idx.iter().map(|&p| Json::Num(p as f64)).collect()),
            ),
            ("level_start", Json::arr_usize(&self.level_start)),
            (
                "output_map",
                Json::Arr(self.output_map.iter().map(|&o| Json::Num(o as f64)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::words::generate::{sig_dim, truncated_words};

    #[test]
    fn truncated_table_is_dense() {
        let d = 3;
        let n = 3;
        let t = WordTable::build(d, &truncated_words(d, n));
        assert_eq!(t.state_len, 1 + sig_dim(d, n));
        assert!(t.output_is_identity());
        t.check_invariants();
    }

    #[test]
    fn projection_closure_is_minimal() {
        // Request a single deep word: closure = its prefix chain.
        let w = Word(vec![2, 0, 1, 1]);
        let t = WordTable::build(3, &[w.clone()]);
        assert_eq!(t.state_len, 5); // ε + 4 prefixes
        assert_eq!(t.out_dim(), 1);
        assert_eq!(t.words[t.output_map[0] as usize], w);
        assert!(!t.output_is_identity());
        t.check_invariants();
    }

    #[test]
    fn csr_packing_has_no_stride_waste() {
        // A projected set of mostly-short words: the packed arrays hold
        // exactly Σ|w| slots, not state_len · max_level.
        let ws = vec![Word(vec![0]), Word(vec![1]), Word(vec![0, 1, 1, 0, 1])];
        let t = WordTable::build(2, &ws);
        let total: usize = t.words.iter().map(|w| w.len()).sum();
        assert_eq!(t.csr_letters.len(), total);
        assert_eq!(t.csr_prefix.len(), total);
        assert!(total < t.state_len * t.max_level, "packing saved nothing");
        t.check_invariants();
    }

    #[test]
    fn shared_prefixes_deduplicate() {
        let ws = vec![Word(vec![0, 1, 2]), Word(vec![0, 1, 0])];
        let t = WordTable::build(3, &ws);
        // ε, (0), (0,1), (0,1,0), (0,1,2) — shared chain stored once.
        assert_eq!(t.state_len, 5);
        t.check_invariants();
    }

    #[test]
    fn project_and_scatter_are_adjoint() {
        let ws = vec![Word(vec![1]), Word(vec![0, 1])];
        let t = WordTable::build(2, &ws);
        let state: Vec<f64> = (0..t.state_len).map(|i| i as f64).collect();
        let mut out = vec![0.0; t.out_dim()];
        t.project(&state, &mut out);
        // <project(s), g> == <s, scatter(g)>
        let g = vec![2.0, -1.5];
        let lhs: f64 = out.iter().zip(&g).map(|(a, b)| a * b).sum();
        let mut gs = vec![0.0; t.state_len];
        t.scatter_grad(&g, &mut gs);
        let rhs: f64 = state.iter().zip(&gs).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn duplicate_requests_allowed() {
        let ws = vec![Word(vec![0]), Word(vec![0])];
        let t = WordTable::build(2, &ws);
        assert_eq!(t.out_dim(), 2);
        assert_eq!(t.output_map[0], t.output_map[1]);
    }

    #[test]
    #[should_panic(expected = "ε is not a valid output coordinate")]
    fn empty_word_request_rejected() {
        WordTable::build(2, &[Word::empty()]);
    }

    #[test]
    #[should_panic(expected = "letter out of range")]
    fn out_of_range_letter_rejected() {
        WordTable::build(2, &[Word(vec![5])]);
    }

    #[test]
    fn json_serialization_reconstructs_strided_tables() {
        let t = WordTable::build(2, &truncated_words(2, 2));
        let j = t.to_json();
        assert_eq!(j.get("d").as_usize(), Some(2));
        assert_eq!(j.get("state_len").as_usize(), Some(7));
        // The manifest format is strided (state_len × max_level), even
        // though in-memory storage is CSR.
        assert_eq!(
            j.get("letters").as_arr().unwrap().len(),
            t.state_len * t.max_level
        );
        assert_eq!(
            j.get("prefix_idx").as_arr().unwrap().len(),
            t.state_len * t.max_level
        );
    }
}
