//! Faithful re-implementations of the comparator libraries' algorithms
//! (paper §6: `keras_sig` and `pySigLib`), used by the benchmark harness
//! to reproduce Figures 1–3 and Tables 1–3.
//!
//! These are *not* strawmen: each follows the cited library's published
//! algorithm and carries its characteristic asymptotics, which is what
//! the paper's comparisons hinge on:
//!
//! * [`chen_full`] — pySigLib/iisignature-style **direct recursion** in
//!   the dense tensor algebra: `S ← S ⊗ exp(ΔX_j)` per step, computed on
//!   the host and single-threaded per path (Remark 6.1: pySigLib runs on
//!   CPU and "saturates at modest thread counts" — we grant it one
//!   thread per path, the same courtesy the paper extends).
//!   Work `O(M · Σ_n n·d^n)`-ish with full materialisation of every
//!   level; memory `O(D_sig)` per path.
//! * [`matmul_style`] — keras_sig-style **parallel cumulative products**:
//!   materialise the per-step exponentials for *all* steps (the
//!   time-parallel reformulation), then combine via an associative scan.
//!   Memory `O(M · D_sig)` per path — the `O(BMD)` footprint of Table 2
//!   that OOMs at long sequences.
//! * [`chen_windows`] — the Signatory-style windowed baseline (§5):
//!   expanding-window states + `S_{0,l}^{-1} ⊗ S_{0,r}` per window.

pub mod chen_full;
pub mod matmul_style;
pub mod chen_windows;

pub use chen_full::{chen_full_logsig, chen_full_signature, chen_full_signature_batch};
pub use chen_windows::chen_windowed_signatures;
pub use matmul_style::{
    matmul_style_signature, matmul_style_signature_batch, matmul_style_train_batch,
    matmul_style_train_step,
};
