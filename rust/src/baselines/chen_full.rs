//! pySigLib / iisignature-style baseline: direct Chen recursion in the
//! dense truncated tensor algebra.
//!
//! For every step: materialise `exp(ΔX_j)` (all `D_sig` coefficients) and
//! compute the full truncated product `S ← S ⊗ exp(ΔX_j)`. This is the
//! "organise around tensor-algebra operations" approach of §3.1 that
//! pathsig's word-basis recursion avoids. Per-path single-threaded
//! (pySigLib is a CPU library; Remark 6.1).

use crate::tensor::{tensor_log_series, TruncTensor};
use crate::words::lyndon_words;

/// Full truncated signature via dense tensor-algebra recursion.
/// `path` row-major `(M+1, d)`; output level-major flat `D_sig`.
pub fn chen_full_signature(d: usize, depth: usize, path: &[f64]) -> Vec<f64> {
    chen_full_state(d, depth, path).flatten_nonscalar()
}

/// Dense tensor-algebra forward state (exposed for the benches'
/// pySigLib-style training step).
pub fn chen_full_state(d: usize, depth: usize, path: &[f64]) -> TruncTensor {
    assert_eq!(path.len() % d, 0);
    let m1 = path.len() / d;
    let mut s = TruncTensor::one(d, depth);
    let mut dx = vec![0.0; d];
    let mut scratch = Vec::new();
    for j in 1..m1 {
        for i in 0..d {
            dx[i] = path[j * d + i] - path[(j - 1) * d + i];
        }
        s.mul_assign(&TruncTensor::exp_level1(&dx, depth), &mut scratch);
    }
    s
}

/// Batched version — sequential over the batch by default (CPU library
/// behaviour); pass `threads > 1` to grant it shared-memory parallelism
/// (pySigLib's OpenMP mode).
pub fn chen_full_signature_batch(
    d: usize,
    depth: usize,
    paths: &[f64],
    batch: usize,
    threads: usize,
) -> Vec<f64> {
    let per = paths.len() / batch;
    let rows = crate::util::threadpool::parallel_map(batch, threads, |b| {
        chen_full_signature(d, depth, &paths[b * per..(b + 1) * per])
    });
    let mut out = Vec::with_capacity(batch * rows.first().map(|r| r.len()).unwrap_or(0));
    for r in rows {
        out.extend(r);
    }
    out
}

/// pySigLib-style log-signature: full dense signature at depth `N`, then
/// a dense tensor logarithm, then read off the Lyndon coordinates — the
/// full top level is materialised (no §3.3 shortcut).
pub fn chen_full_logsig(d: usize, depth: usize, path: &[f64]) -> Vec<f64> {
    let s = chen_full_state(d, depth, path);
    let log = tensor_log_series(&s);
    let mut ly = lyndon_words(d, depth);
    ly.sort_by_key(|w| (w.len(), w.0.clone()));
    ly.iter().map(|w| log.coeff(&w.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature, SigEngine};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, WordTable};

    #[test]
    fn agrees_with_word_basis_engine() {
        let mut rng = Rng::new(500);
        for &(d, n, m) in &[(2, 4, 8), (3, 3, 12), (5, 2, 6)] {
            let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, n)));
            let path = rng.brownian_path(m, d, 0.6);
            let base = chen_full_signature(d, n, &path);
            let ours = signature(&eng, &path);
            assert_allclose(&base, &ours, 1e-11, 1e-10, &format!("d={d} n={n}"));
        }
    }

    #[test]
    fn logsig_agrees_with_engine() {
        let mut rng = Rng::new(501);
        let (d, n, m) = (3, 3, 7);
        let eng = crate::logsig::LogSigEngine::new(d, n);
        let path = rng.brownian_path(m, d, 0.5);
        let base = chen_full_logsig(d, n, &path);
        let ours = eng.logsig(&path);
        assert_allclose(&base, &ours, 1e-11, 1e-10, "logsig baseline");
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(502);
        let (d, n, m, b) = (2, 3, 5, 4);
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, d, 1.0));
        }
        let all = chen_full_signature_batch(d, n, &paths, b, 2);
        let per = (m + 1) * d;
        let dim = crate::words::generate::sig_dim(d, n);
        for k in 0..b {
            let single = chen_full_signature(d, n, &paths[k * per..(k + 1) * per]);
            assert_allclose(&all[k * dim..(k + 1) * dim], &single, 0.0, 0.0, "row");
        }
    }
}
