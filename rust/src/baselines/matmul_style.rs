//! keras_sig-style baseline: time-parallel signature via materialised
//! per-step exponentials + cumulative tensor products.
//!
//! keras_sig (Genet & Inzirillo, 2025) reframes the Chen recursion as
//! "parallel matrix multiplications and cumulative products" so a GPU can
//! parallelise over the time axis. The structural consequence the paper
//! leans on (Table 2) is the memory footprint: the reformulation stores
//! per-step tensors for **every** time step — `O(M · D_sig)` per path —
//! both in the forward pass and (for training) as autograd residuals.
//!
//! We reproduce exactly that schedule: (1) materialise `exp(ΔX_j)` for
//! all `j` (one `D_sig`-sized tensor per step, kept live), (2) reduce
//! with an inclusive product scan (pairwise tree, the GPU-style
//! associative scan), (3) for the backward pass, keep all prefix products
//! live (the autograd residuals) and sweep cotangents back through the
//! scan. Parallelism over time is granted via the thread pool.

use crate::tensor::{mul_adjoint, TruncTensor};
use crate::util::threadpool::parallel_map;

/// Full truncated signature via the keras_sig schedule. Returns the
/// flat `D_sig` vector. Peak memory `O(M · D_sig)` by construction.
pub fn matmul_style_signature(d: usize, depth: usize, path: &[f64], threads: usize) -> Vec<f64> {
    let exps = step_exponentials(d, depth, path, threads);
    if exps.is_empty() {
        return TruncTensor::one(d, depth).flatten_nonscalar();
    }
    reduce_product_tree(exps, threads).flatten_nonscalar()
}

/// Batched forward.
pub fn matmul_style_signature_batch(
    d: usize,
    depth: usize,
    paths: &[f64],
    batch: usize,
    threads: usize,
) -> Vec<f64> {
    let per = paths.len() / batch;
    let rows = parallel_map(batch, threads, |b| {
        // Inner time-parallelism is already accounted; per-path serial
        // here, parallel across the batch (the GPU parallelises both).
        matmul_style_signature(d, depth, &paths[b * per..(b + 1) * per], 1)
    });
    let mut out = Vec::new();
    for r in rows {
        out.extend(r);
    }
    out
}

/// One "training step" through the baseline: forward with all residuals
/// retained + backward to path gradients, given output cotangents.
/// This is the `O(B·M·D_sig)` training footprint of Table 2: prefix
/// products `S_{0,t_j}` for all `j` are stored (standard autograd through
/// a cumulative product), then cotangents sweep backward.
pub fn matmul_style_train_step(
    d: usize,
    depth: usize,
    path: &[f64],
    grad_out: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    let m1 = path.len() / d;
    let steps = m1 - 1;
    let exps = step_exponentials(d, depth, path, 1);
    // Residuals: prefix products P_j = S_{0,t_j} for all j (all live).
    let mut prefixes: Vec<TruncTensor> = Vec::with_capacity(steps + 1);
    prefixes.push(TruncTensor::one(d, depth));
    for e in &exps {
        prefixes.push(prefixes.last().unwrap().mul(e));
    }
    let sig = prefixes[steps].flatten_nonscalar();

    // Backward: suffix cotangent sweep. Λ_j = adjoint of P_j.
    // P_j = P_{j-1} ⊗ E_j ⇒ Λ_{j-1}(u) = Σ_v Λ_j(u∘v) E_j(v),
    //                      Ê_j(v)   = Σ_u P_{j-1}(u) Λ_j(u∘v).
    let mut lambda = TruncTensor::zero(d, depth);
    {
        let mut k = 0;
        for n in 1..=depth {
            for c in 0..d.pow(n as u32) {
                lambda.levels[n][c] = grad_out[k];
                k += 1;
            }
        }
    }
    let mut grad_dx = vec![0.0; steps * d];
    for j in (1..=steps).rev() {
        let e = &exps[j - 1];
        let p_prev = &prefixes[j - 1];
        let mut g_e = TruncTensor::zero(d, depth);
        let mut lambda_prev = TruncTensor::zero(d, depth);
        mul_adjoint(p_prev, e, &lambda, &mut lambda_prev, &mut g_e);
        // exp gradient: Ê(v) → ΔX via ∂exp(x,v)/∂x (product rule).
        let dx: Vec<f64> = (0..d)
            .map(|i| path[j * d + i] - path[(j - 1) * d + i])
            .collect();
        accumulate_exp_grad(&g_e, &dx, &mut grad_dx[(j - 1) * d..j * d]);
        lambda = lambda_prev;
    }
    // Increments → points.
    let mut grad_path = vec![0.0; path.len()];
    for i in 0..d {
        if steps > 0 {
            grad_path[i] = -grad_dx[i];
            grad_path[steps * d + i] = grad_dx[(steps - 1) * d + i];
        }
    }
    for j in 1..steps {
        for i in 0..d {
            grad_path[j * d + i] = grad_dx[(j - 1) * d + i] - grad_dx[j * d + i];
        }
    }
    (sig, grad_path)
}

/// Batched training step holding **all** paths' residuals live
/// simultaneously, as the batch-vectorised keras_sig does — this is the
/// configuration whose peak memory Table 2 reports (`O(B·M·D_sig)`).
pub fn matmul_style_train_batch(
    d: usize,
    depth: usize,
    paths: &[f64],
    grads_out: &[f64],
    batch: usize,
) -> (Vec<f64>, Vec<f64>) {
    let per = paths.len() / batch;
    let dim: usize = (1..=depth).map(|n| d.pow(n as u32)).sum();
    // Phase 1: forward residuals for every path in the batch (all live).
    let mut residuals: Vec<(Vec<TruncTensor>, Vec<TruncTensor>)> = Vec::with_capacity(batch);
    for b in 0..batch {
        let path = &paths[b * per..(b + 1) * per];
        let exps = step_exponentials(d, depth, path, 1);
        let mut prefixes = Vec::with_capacity(exps.len() + 1);
        prefixes.push(TruncTensor::one(d, depth));
        for e in &exps {
            prefixes.push(prefixes.last().unwrap().mul(e));
        }
        residuals.push((exps, prefixes));
    }
    // Phase 2: outputs + backward sweeps (residuals still live).
    let mut sigs = Vec::with_capacity(batch * dim);
    let mut grad_paths = Vec::with_capacity(paths.len());
    for b in 0..batch {
        let path = &paths[b * per..(b + 1) * per];
        let g = &grads_out[b * dim..(b + 1) * dim];
        let (exps, prefixes) = &residuals[b];
        let steps = exps.len();
        sigs.extend(prefixes[steps].flatten_nonscalar());
        let mut lambda = TruncTensor::zero(d, depth);
        let mut k = 0;
        for n in 1..=depth {
            for c in 0..d.pow(n as u32) {
                lambda.levels[n][c] = g[k];
                k += 1;
            }
        }
        let mut grad_dx = vec![0.0; steps * d];
        for j in (1..=steps).rev() {
            let mut g_e = TruncTensor::zero(d, depth);
            let mut lambda_prev = TruncTensor::zero(d, depth);
            mul_adjoint(&prefixes[j - 1], &exps[j - 1], &lambda, &mut lambda_prev, &mut g_e);
            let dx: Vec<f64> = (0..d)
                .map(|i| path[j * d + i] - path[(j - 1) * d + i])
                .collect();
            accumulate_exp_grad(&g_e, &dx, &mut grad_dx[(j - 1) * d..j * d]);
            lambda = lambda_prev;
        }
        let m1 = per / d;
        let mut gp = vec![0.0; per];
        for i in 0..d {
            if steps > 0 {
                gp[i] = -grad_dx[i];
                gp[(m1 - 1) * d + i] = grad_dx[(steps - 1) * d + i];
            }
        }
        for j in 1..steps {
            for i in 0..d {
                gp[j * d + i] = grad_dx[(j - 1) * d + i] - grad_dx[j * d + i];
            }
        }
        grad_paths.extend(gp);
    }
    (sigs, grad_paths)
}

/// Materialise exp(ΔX_j) for every step (time-parallel).
fn step_exponentials(d: usize, depth: usize, path: &[f64], threads: usize) -> Vec<TruncTensor> {
    let m1 = path.len() / d;
    if m1 <= 1 {
        return Vec::new();
    }
    parallel_map(m1 - 1, threads, |k| {
        let j = k + 1;
        let dx: Vec<f64> = (0..d)
            .map(|i| path[j * d + i] - path[(j - 1) * d + i])
            .collect();
        TruncTensor::exp_level1(&dx, depth)
    })
}

/// Pairwise product-reduction tree (associative scan shape).
fn reduce_product_tree(mut xs: Vec<TruncTensor>, threads: usize) -> TruncTensor {
    while xs.len() > 1 {
        let pairs = xs.len() / 2;
        let mut next = parallel_map(pairs, threads, |k| xs[2 * k].mul(&xs[2 * k + 1]));
        if xs.len() % 2 == 1 {
            next.push(xs.pop().unwrap());
        }
        xs = next;
    }
    xs.pop().unwrap()
}

/// Given cotangents on exp(x) coefficients, accumulate ∂/∂x.
/// exp(x)[v] = Π_t x_{v_t} / |v|!; walk words recursively accumulating
/// per-letter products (O(D_sig·N)).
fn accumulate_exp_grad(g_e: &TruncTensor, dx: &[f64], out: &mut [f64]) {
    let d = dx.len();
    let depth = g_e.depth;
    // For each level n and word code c, letters can be decoded on the
    // fly; use prefix/suffix product arrays per word (words are short).
    let mut letters = vec![0usize; depth];
    for n in 1..=depth {
        let inv_fact: f64 = 1.0 / (1..=n).map(|k| k as f64).product::<f64>();
        for (c, &g) in g_e.levels[n].iter().enumerate() {
            if g == 0.0 {
                continue;
            }
            // decode letters of c.
            let mut cc = c;
            for t in (0..n).rev() {
                letters[t] = cc % d;
                cc /= d;
            }
            // left/right partial products.
            for p in 0..n {
                let mut prod = 1.0;
                for (t, &l) in letters[..n].iter().enumerate() {
                    if t != p {
                        prod *= dx[l];
                    }
                }
                out[letters[p]] += g * inv_fact * prod;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{sig_backward, signature, SigEngine};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, WordTable};

    #[test]
    fn forward_agrees_with_engine() {
        let mut rng = Rng::new(510);
        for &(d, n, m) in &[(2, 4, 9), (3, 3, 6), (4, 2, 15)] {
            let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, n)));
            let path = rng.brownian_path(m, d, 0.7);
            let base = matmul_style_signature(d, n, &path, 2);
            let ours = signature(&eng, &path);
            assert_allclose(&base, &ours, 1e-11, 1e-10, &format!("d={d} n={n}"));
        }
    }

    #[test]
    fn train_step_gradient_agrees_with_engine_backward() {
        let mut rng = Rng::new(511);
        let (d, n, m) = (2, 3, 6);
        let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, n)));
        let path = rng.brownian_path(m, d, 0.8);
        let g: Vec<f64> = (0..eng.out_dim()).map(|_| rng.gaussian()).collect();
        let (sig_b, grad_b) = matmul_style_train_step(d, n, &path, &g);
        let sig_e = signature(&eng, &path);
        let grad_e = sig_backward(&eng, &path, &g);
        assert_allclose(&sig_b, &sig_e, 1e-11, 1e-10, "fwd");
        assert_allclose(&grad_b, &grad_e, 1e-9, 1e-8, "bwd");
    }

    #[test]
    fn empty_steps_give_trivial_signature() {
        let out = matmul_style_signature(2, 3, &[1.0, 2.0], 1);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
