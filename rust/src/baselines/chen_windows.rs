//! Signatory-style windowed-signature baseline (§5).
//!
//! Precompute expanding-window signatures `S_{0,t_j}` for every `j`
//! (memory `O(M · D_sig)`), then recover each requested window as
//! `S_{t_l,t_r} = S_{0,t_l}^{-1} ⊗ S_{0,t_r}` via the group inverse
//! (Lemma 4.5). The paper notes this "can be numerically unstable and
//! memory-intensive for long sequences" — both effects are measured in
//! `benches/fig3_windows.rs`.

use crate::sig::Window;
use crate::tensor::TruncTensor;

/// Windowed signatures via precomputed expanding states + Chen
/// combination. Returns row-major `(K, D_sig)`.
pub fn chen_windowed_signatures(
    d: usize,
    depth: usize,
    path: &[f64],
    windows: &[Window],
) -> Vec<f64> {
    let m1 = path.len() / d;
    // Expanding states S_{0,t_j} for all j — the O(M·D_sig) table.
    let mut states: Vec<TruncTensor> = Vec::with_capacity(m1);
    states.push(TruncTensor::one(d, depth));
    let mut dx = vec![0.0; d];
    let mut scratch = Vec::new();
    for j in 1..m1 {
        for i in 0..d {
            dx[i] = path[j * d + i] - path[(j - 1) * d + i];
        }
        let mut next = states[j - 1].clone();
        next.mul_assign(&TruncTensor::exp_level1(&dx, depth), &mut scratch);
        states.push(next);
    }
    let mut out = Vec::new();
    for w in windows {
        let combined = states[w.l].group_inverse().mul(&states[w.r]);
        out.extend(combined.flatten_nonscalar());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{windowed_signatures, SigEngine};
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::{truncated_words, WordTable};

    #[test]
    fn agrees_with_direct_windows() {
        let mut rng = Rng::new(520);
        let (d, n) = (2, 3);
        let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, n)));
        let path = rng.brownian_path(25, d, 0.6);
        let wins = vec![Window::new(0, 10), Window::new(5, 20), Window::new(24, 25)];
        let base = chen_windowed_signatures(d, n, &path, &wins);
        let ours = windowed_signatures(&eng, &path, &wins);
        assert_allclose(&base, &ours, 1e-9, 1e-8, "windowed");
    }

    #[test]
    fn instability_grows_with_path_magnitude() {
        // The inverse-combine approach loses accuracy when |S_{0,l}| is
        // large (big increments, long prefix); the direct method does
        // not. This documents the §5 remark quantitatively.
        let mut rng = Rng::new(521);
        let (d, n) = (2, 4);
        let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, n)));
        let path = rng.brownian_path(200, d, 2.0); // large increments
        let wins = vec![Window::new(190, 200)];
        let base = chen_windowed_signatures(d, n, &path, &wins);
        let ours = windowed_signatures(&eng, &path, &wins);
        let err = crate::util::proptest::max_abs_diff(&base, &ours);
        // Not asserting a huge error (it varies) — just that the direct
        // window matches an independently computed sub-path signature
        // to machine precision while the combined one drifts.
        let sub = crate::sig::signature(&eng, &path[190 * d..]);
        let direct_err = crate::util::proptest::max_abs_diff(&ours, &sub);
        assert!(direct_err < 1e-10, "direct drifted: {direct_err}");
        assert!(err >= direct_err, "combine err {err} < direct err {direct_err}");
    }
}
