//! Minimal JSON implementation (RFC 8259 subset) — parser, serializer and
//! a small accessor API.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), the
//! coordinator's TCP wire protocol and benchmark result dumps. No serde in
//! the vendored crate set, so this is a from-scratch substrate.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (stable golden files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (JSON does not distinguish integers from floats).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; keys sorted (BTreeMap) for deterministic output.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Human-readable description of what went wrong.
    pub msg: String,
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------- parse

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    /// The value as an `f64`, if it is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a `usize`, if it is a non-negative integer number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The value as an `i64`, if it is an integer number.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// The value as a string slice, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool, if it is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as a slice of elements, if it is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The value as a key → value map, if it is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `self[key]` as a `Vec<f64>`; empty if missing.
    pub fn f64_vec(&self, key: &str) -> Vec<f64> {
        self.get(key)
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default()
    }

    /// `self[key]` as a `Vec<usize>`; empty if missing.
    pub fn usize_vec(&self, key: &str) -> Vec<usize> {
        self.get(key)
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    // --------------------------------------------------------- constructors

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array from an `f64` slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Build a numeric array from an `f32` slice.
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Build a numeric array from a `usize` slice.
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Build a string value (clones the input).
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ------------------------------------------------------------ serialize

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like most tolerant encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
                            // Surrogate pairs: parse low surrogate if present.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad hex"))?;
                                    self.i += 5;
                                    let full =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(full).ok_or_else(|| self.err("bad codepoint"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                self.i += 4;
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,-3],"name":"p\"q","ok":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\nbreak\t\"q\" \\ \u{1F600}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parse() {
        let v = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str(), Some("A\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn accessor_defaults() {
        let v = Json::parse(r#"{"xs": [1, 2, 3]}"#).unwrap();
        assert_eq!(v.usize_vec("xs"), vec![1, 2, 3]);
        assert_eq!(v.usize_vec("missing"), Vec::<usize>::new());
        assert_eq!(v.get("nope").as_str(), None);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("a", Json::arr_usize(&[1, 2])),
            ("b", Json::obj(vec![("c", Json::Num(1.5))])),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn large_int_precision() {
        let v = Json::parse("1234567890123").unwrap();
        assert_eq!(v.as_i64(), Some(1234567890123));
        assert_eq!(v.to_string(), "1234567890123");
    }
}
