//! Reusable-buffer pools for the zero-allocation hot paths.
//!
//! Batch entry points need one scratch workspace per worker thread.
//! Allocating those on every call is exactly the churn the lane-major
//! kernel is meant to avoid, so engines keep a [`Pool`] of workspaces:
//! a call takes the whole vector of workspaces out (one mutex lock),
//! grows it if the worker count went up, and puts it back when done.
//! In steady state (same engine, same worker count) the take/put pair
//! performs no heap allocation at all — verified by the counting
//! allocator in `benches/fig1_truncated.rs`.

use std::fmt;
use std::sync::Mutex;

/// A mutex-guarded stash of reusable worker contexts.
///
/// Cloning a pool yields an *empty* pool (scratch buffers are not
/// shared between engine clones), which keeps `#[derive(Clone)]`
/// usable on structs that embed one.
pub struct Pool<T>(Mutex<Vec<T>>);

impl<T> Default for Pool<T> {
    fn default() -> Self {
        Pool(Mutex::new(Vec::new()))
    }
}

impl<T> fmt::Debug for Pool<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let n = self.0.lock().map(|v| v.len()).unwrap_or(0);
        write!(f, "Pool({n} cached)")
    }
}

impl<T> Clone for Pool<T> {
    fn clone(&self) -> Self {
        Pool::default()
    }
}

impl<T: Default> Pool<T> {
    /// Take the cached contexts, growing the vector to at least `n`
    /// entries (new entries are `T::default()`). Steady state — pool
    /// already holds ≥ `n` contexts — allocates nothing.
    pub fn take_at_least(&self, n: usize) -> Vec<T> {
        let mut v = std::mem::take(&mut *self.0.lock().unwrap());
        if v.len() < n {
            v.resize_with(n, T::default);
        }
        v
    }

    /// Return contexts to the pool for the next call. If two calls
    /// race, the later `put` wins and the other vector is dropped —
    /// correctness is unaffected, only reuse.
    pub fn put(&self, v: Vec<T>) {
        *self.0.lock().unwrap() = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_grows_and_put_reuses() {
        let pool: Pool<Vec<u8>> = Pool::default();
        let mut v = pool.take_at_least(3);
        assert_eq!(v.len(), 3);
        v[0].push(7);
        let cap = {
            v[0].reserve(100);
            v[0].capacity()
        };
        pool.put(v);
        // Second take sees the same buffers (no shrink, no realloc).
        let v2 = pool.take_at_least(2);
        assert_eq!(v2.len(), 3);
        assert_eq!(v2[0][0], 7);
        assert_eq!(v2[0].capacity(), cap);
    }

    #[test]
    fn clone_is_empty() {
        let pool: Pool<u32> = Pool::default();
        pool.put(vec![1, 2, 3]);
        let clone = pool.clone();
        assert_eq!(clone.take_at_least(0).len(), 0);
        assert_eq!(pool.take_at_least(0).len(), 3);
    }

    #[test]
    fn debug_prints_cache_size() {
        let pool: Pool<u32> = Pool::default();
        pool.put(vec![1, 2]);
        assert!(format!("{pool:?}").contains('2'));
    }
}
