//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64`, plus Gaussian sampling via
//! the Marsaglia polar method. Deterministic seeding is load-bearing for
//! the whole repo: every benchmark, test and experiment derives its
//! workload from an explicit seed so that paper-table reproductions are
//! bit-stable across runs.

/// `splitmix64` stream — used to expand a single `u64` seed into the
/// xoshiro state (the construction recommended by the xoshiro authors).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `xoshiro256**` PRNG. Fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from the polar method.
    spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free for
    /// practical purposes at these ranges).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard Gaussian via the Marsaglia polar method.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Fill a slice with i.i.d. standard Gaussians.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.gaussian();
        }
    }

    /// Fill a slice with i.i.d. standard Gaussians, `f32`.
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.gaussian() as f32;
        }
    }

    /// A random Brownian-like path: `steps+1` points in `dim` dimensions,
    /// increments `N(0, scale^2)`. Row-major `(steps+1, dim)`.
    pub fn brownian_path(&mut self, steps: usize, dim: usize, scale: f64) -> Vec<f64> {
        let mut path = vec![0.0; (steps + 1) * dim];
        for j in 1..=steps {
            for i in 0..dim {
                path[j * dim + i] = path[(j - 1) * dim + i] + scale * self.gaussian();
            }
        }
        path
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(123);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sum2 += g * g;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn brownian_path_shape_and_start() {
        let mut r = Rng::new(5);
        let p = r.brownian_path(16, 3, 1.0);
        assert_eq!(p.len(), 17 * 3);
        assert_eq!(&p[..3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
