//! Fixed-size work-stealing-free thread pool with scoped parallel-for.
//!
//! This is the "GPU" of the reproduction: the paper assigns one CUDA
//! thread per prefix-closed word set (Definition 3.4); here each pool
//! worker processes a contiguous block of (batch × word) units. The pool
//! is deliberately simple — a shared injector queue of boxed jobs — since
//! signature workloads are coarse-grained (one job per batch-block per
//! step loop, not per step).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

struct Shared {
    rx: Mutex<Receiver<Msg>>,
    pending: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Msg>();
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            pending: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..size)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    let msg = { sh.rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(job)) => {
                            job();
                            if sh.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                                let _g = sh.done_lock.lock().unwrap();
                                sh.done.notify_all();
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool {
            tx,
            shared,
            workers,
            size,
        }
    }

    /// Pool sized like [`default_threads`] (`PATHSIG_THREADS` override,
    /// else `available_parallelism` capped at 16).
    pub fn default_pool() -> Self {
        ThreadPool::new(default_threads())
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job. Prefer [`ThreadPool::scope_chunks`] for data
    /// parallelism.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.pending.load(Ordering::Acquire) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Parallel-for over disjoint mutable chunks of `data`: splits `data`
    /// into `chunk` -sized pieces and runs `f(chunk_index, chunk)` across
    /// the pool, blocking until all complete.
    ///
    /// Safety note: chunks are disjoint `&mut` slices obtained via
    /// `chunks_mut`, moved into jobs with lifetimes erased by scoped
    /// threads underneath — implemented with `std::thread::scope` so no
    /// unsafe is needed.
    pub fn scope_chunks<T: Send, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Send + Sync,
    {
        let chunk = chunk.max(1);
        let n_jobs = data.len().div_ceil(chunk);
        if n_jobs <= 1 {
            if !data.is_empty() {
                f(0, data);
            }
            return;
        }
        // Scoped threads sidestep 'static bounds; reuse pool size as the
        // concurrency cap by batching chunks into `size` stripes.
        let stripes = self.size.min(n_jobs);
        std::thread::scope(|s| {
            let f = &f;
            for (stripe, piece) in data.chunks_mut(chunk * n_jobs.div_ceil(stripes)).enumerate() {
                let base = stripe * n_jobs.div_ceil(stripes);
                s.spawn(move || {
                    for (k, sub) in piece.chunks_mut(chunk).enumerate() {
                        f(base + k, sub);
                    }
                });
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default worker count: the `PATHSIG_THREADS` environment variable if
/// set to a positive integer, else `available_parallelism` capped at 16
/// (the paper's CPU workloads saturate well before that). A set-but-
/// rejected value warns once on stderr instead of silently defaulting.
pub fn default_threads() -> usize {
    let (n, warn) = threads_from_checked(std::env::var("PATHSIG_THREADS").ok().as_deref());
    if let Some(msg) = warn {
        crate::util::envknob::warn_knob_once("PATHSIG_THREADS", &msg);
    }
    n
}

/// The machine fallback `PATHSIG_THREADS` resolves to when unset or
/// rejected: `available_parallelism` capped at 16.
fn machine_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Pure core of [`default_threads`] (unit-testable without touching the
/// process environment): `env` is the raw `PATHSIG_THREADS` value; a
/// rejected value (zero, unparsable) comes back with the warning
/// message [`default_threads`] prints.
fn threads_from_checked(env: Option<&str>) -> (usize, Option<String>) {
    let Some(raw) = env else {
        return (machine_threads(), None);
    };
    match raw.trim().parse::<usize>() {
        Ok(n) if n >= 1 => (n, None),
        _ => {
            let fallback = machine_threads();
            (
                fallback,
                Some(format!(
                    "ignoring invalid PATHSIG_THREADS={raw:?} \
                     (expected a positive integer); using {fallback}"
                )),
            )
        }
    }
}

/// [`threads_from_checked`] without the warning channel (legacy shim
/// for the parsing tests).
#[cfg(test)]
fn threads_from(env: Option<&str>) -> usize {
    threads_from_checked(env).0
}

/// Run `f(i, ctx)` for `i in 0..n` with one scoped worker thread per
/// context in `ctxs`, work-stealing unit indices from a shared atomic
/// counter. Each worker owns its `&mut W` exclusively, which is how the
/// batch kernels thread reusable workspaces through a parallel loop
/// without locks or per-unit allocation. With a single context (or a
/// single unit) the loop runs inline on the caller's thread — no spawn,
/// no allocation.
pub fn parallel_for_ctx<W: Send, F>(n: usize, ctxs: &mut [W], f: F)
where
    F: Fn(usize, &mut W) + Send + Sync,
{
    // The grain-1 case of the grained claim loop below — one shared
    // implementation, one place to fix.
    parallel_for_ctx_grained(n, 1, ctxs, f);
}

/// [`parallel_for_ctx`] claiming `grain` consecutive unit indices per
/// atomic fetch. For fine-grained unit loops (many cheap units — e.g.
/// the time-parallel windowed path's per-window folds) the per-index
/// contention on the shared counter becomes measurable; batched claims
/// keep the counter cold while preserving work stealing across
/// workers. Each worker still owns its `&mut W` context exclusively.
pub fn parallel_for_ctx_grained<W: Send, F>(n: usize, grain: usize, ctxs: &mut [W], f: F)
where
    F: Fn(usize, &mut W) + Send + Sync,
{
    let grain = grain.max(1);
    assert!(!ctxs.is_empty(), "parallel_for_ctx_grained needs at least one context");
    if n == 0 {
        return;
    }
    if ctxs.len() == 1 || n <= grain {
        let ctx = &mut ctxs[0];
        for i in 0..n {
            f(i, ctx);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let f = &f;
        let next = &next;
        for ctx in ctxs.iter_mut().take(n.div_ceil(grain)) {
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + grain).min(n) {
                    f(i, ctx);
                }
            });
        }
    });
}

/// Chunked parallel write into the caller's output buffer: splits `out`
/// into consecutive `chunk`-sized pieces (last may be short) and runs
/// `f(chunk_index, piece, ctx)` across one worker per context, writing
/// **in place** — no per-job boxing, no result rows, no post-join copy.
/// This replaces the old `parallel_map` + `out.extend(row)` pattern on
/// every batch hot path.
pub fn parallel_for_into<T, W, F>(out: &mut [T], chunk: usize, ctxs: &mut [W], f: F)
where
    T: Send,
    W: Send,
    F: Fn(usize, &mut [T], &mut W) + Send + Sync,
{
    let chunk = chunk.max(1);
    let len = out.len();
    let n_chunks = len.div_ceil(chunk);
    let base = SendPtr(out.as_mut_ptr());
    parallel_for_ctx(n_chunks, ctxs, move |k, ctx| {
        let start = k * chunk;
        let end = (start + chunk).min(len);
        // SAFETY: each chunk index is claimed exactly once by
        // parallel_for_ctx, so the slices are disjoint; `out` outlives
        // the scoped workers.
        let piece = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(k, piece, ctx);
    });
}

/// [`parallel_for_into`] without worker contexts: fill `out` row by row
/// (`row_len` elements each) across `threads` workers.
pub fn parallel_fill_rows<T: Send, F>(out: &mut [T], row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    let mut ctxs = vec![(); threads.max(1)];
    parallel_for_into(out, row_len, &mut ctxs, |k, piece, _| f(k, piece));
}

/// Run `f(i)` for `i in 0..n` across `threads` scoped threads, collecting
/// results in order. Prefer [`parallel_for_into`] on hot paths — this
/// variant allocates one `T` slot per unit.
pub fn parallel_map<T: Send, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let f = &f;
        let next = &next;
        // Hand each worker an interleaved view via raw splitting on index.
        let out_ptr = SendPtr(out.as_mut_ptr());
        for _ in 0..threads {
            s.spawn(move || {
                // Capture the wrapper by value (edition-2021 disjoint
                // capture would otherwise grab the raw field and lose
                // the Send impl).
                let slot = out_ptr;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    // SAFETY: each index i is claimed exactly once via
                    // the atomic counter, so writes are disjoint; the
                    // scope guarantees `out` outlives the workers.
                    unsafe {
                        *slot.0.add(i) = Some(v);
                    }
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Run `f(i)` for `i in 0..n` for side effects only, across `threads`.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let f = &f;
        let next = &next;
        for _ in 0..threads {
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// A raw pointer that asserts Send/Sync so scoped workers can write to
/// provably disjoint regions of one buffer (each index claimed exactly
/// once via an atomic counter). Crate-visible for kernels whose output
/// rows are disjoint but strided (e.g. windowed batch lanes).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// Manual Clone/Copy: the derive would add a spurious `T: Copy` bound.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: only used with disjoint index writes inside a scope.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scope_chunks_touches_every_element() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u64; 1000];
        pool.scope_chunks(&mut data, 37, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(257, 8, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_for_counts() {
        let counter = AtomicU64::new(0);
        parallel_for(1234, 7, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1234);
    }

    #[test]
    fn parallel_map_single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_for_into_writes_every_chunk_in_place() {
        let mut out = vec![0usize; 103]; // 103 = 10 chunks of 11 + tail of 4... (9*11=99, tail 4)
        let mut ctxs = vec![0usize; 4];
        parallel_for_into(&mut out, 11, &mut ctxs, |k, piece, ctx| {
            *ctx += 1;
            for x in piece {
                *x = k + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i / 11 + 1, "element {i}");
        }
        // Every chunk was handled by exactly one worker.
        assert_eq!(ctxs.iter().sum::<usize>(), 103usize.div_ceil(11));
    }

    #[test]
    fn parallel_for_into_single_context_runs_inline() {
        let mut out = vec![0u8; 10];
        let mut ctxs = [0usize];
        parallel_for_into(&mut out, 3, &mut ctxs, |_, piece, ctx| {
            *ctx += piece.len();
        });
        assert_eq!(ctxs[0], 10);
    }

    #[test]
    fn parallel_fill_rows_covers_exact_rows() {
        let mut out = vec![0.0f64; 6 * 4];
        parallel_fill_rows(&mut out, 4, 3, |r, row| {
            assert_eq!(row.len(), 4);
            for x in row {
                *x = r as f64;
            }
        });
        for r in 0..6 {
            assert!(out[r * 4..(r + 1) * 4].iter().all(|&x| x == r as f64));
        }
    }

    #[test]
    fn parallel_for_ctx_grained_each_unit_once() {
        // Unit count not divisible by the grain; every index claimed
        // exactly once, by exactly one worker.
        let mut hits = vec![0u8; 1003];
        let slot = SendPtr(hits.as_mut_ptr());
        let mut ctxs = vec![(); 5];
        parallel_for_ctx_grained(1003, 16, &mut ctxs, move |i, _| {
            let slot = slot;
            // SAFETY: each index is claimed exactly once.
            unsafe { *slot.0.add(i) += 1 };
        });
        assert!(hits.iter().all(|&h| h == 1));
        // Single-context fallback runs inline.
        let c = AtomicU64::new(0);
        let mut one = [()];
        parallel_for_ctx_grained(7, 3, &mut one, |_, _| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn parallel_for_ctx_each_unit_once() {
        let hits = AtomicU64::new(0);
        let mut ctxs = vec![(); 5];
        parallel_for_ctx(777, &mut ctxs, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 777);
    }

    #[test]
    fn threads_from_env_parsing() {
        assert_eq!(threads_from(Some("3")), 3);
        assert_eq!(threads_from(Some(" 12 ")), 12);
        let fallback = threads_from(None);
        assert!((1..=16).contains(&fallback));
        // Zero and garbage fall back to the machine default.
        assert_eq!(threads_from(Some("0")), fallback);
        assert_eq!(threads_from(Some("many")), fallback);
    }

    #[test]
    fn threads_rejections_warn_with_value_and_default() {
        // Valid values and unset are warning-free…
        assert_eq!(threads_from_checked(Some("3")), (3, None));
        assert!(threads_from_checked(None).1.is_none());
        // …every rejection path names the rejected value and the
        // default actually used.
        for bad in ["0", "many", "-2", "1.5", ""] {
            let (n, warn) = threads_from_checked(Some(bad));
            assert_eq!(n, threads_from(None), "{bad}");
            let msg = warn.expect("rejected PATHSIG_THREADS must warn");
            assert!(
                msg.contains("PATHSIG_THREADS") && msg.contains(bad) && msg.contains(&n.to_string()),
                "{msg}"
            );
        }
    }
}
