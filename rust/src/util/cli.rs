//! Tiny CLI argument parser (no `clap` in the vendored set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and a usage-string helper. Parsing is
//! strict: unknown flags are surfaced so typos fail fast.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Sub-command (first positional), if any.
    pub command: Option<String>,
    /// Remaining positionals after the command.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); skips argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut args = Args::default();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args())
    }

    /// Raw option value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Whether a boolean flag was passed (`--flag`, `--flag=1`, …).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Option parsed as `usize`, with a default for missing/bad values.
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option parsed as `f64`, with a default for missing/bad values.
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option parsed as `u64`, with a default for missing/bad values.
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option as a string, with a default when missing.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Comma-separated list of `usize` (e.g. `--depths 2,3,4`).
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated list of `f64`.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        let mut v = vec!["prog".to_string()];
        v.extend(toks.iter().map(|s| s.to_string()));
        Args::parse_from(v)
    }

    #[test]
    fn command_and_positionals() {
        let a = parse(&["serve", "extra1", "extra2"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["run", "--depth", "4", "--dim=3", "--verbose"]);
        assert_eq!(a.usize("depth", 0), 4);
        assert_eq!(a.usize("dim", 0), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse(&["bench", "--depths", "2,3,4"]);
        assert_eq!(a.usize_list("depths", &[9]), vec![2, 3, 4]);
        assert_eq!(a.usize_list("dims", &[9]), vec![9]);
        assert_eq!(a.f64("lr", 0.01), 0.01);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "7"]);
        assert!(a.flag("a"));
        assert_eq!(a.usize("b", 0), 7);
    }

    #[test]
    fn negative_number_as_value() {
        // A value starting with "--" would be ambiguous; "-1.5" is fine.
        let a = parse(&["x", "--lr", "-1.5"]);
        assert_eq!(a.f64("lr", 0.0), -1.5);
    }
}
