//! Property-testing mini-framework (no `proptest` crate in the vendored
//! set).
//!
//! Provides seeded case generation with first-failure reporting and a
//! shrink-lite mechanism: on failure the framework retries the property on
//! a sequence of "smaller" cases produced by a user-supplied shrinker and
//! reports the smallest failing case found.
//!
//! ```no_run
//! use pathsig::util::proptest::{property, Gen};
//! property("addition commutes", 64, |g| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to each property invocation. Wraps an [`Rng`]
/// with convenience draws sized for signature workloads.
pub struct Gen {
    /// The case's deterministic random stream.
    pub rng: Rng,
    /// Case index (0-based); useful for coverage-directed sizing so early
    /// cases are tiny and later ones grow.
    pub case: usize,
    /// Total number of cases for this property.
    pub cases: usize,
}

impl Gen {
    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// Uniform integer in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// Size that grows with the case index: in `[lo, lo + (hi-lo)*t]`
    /// where `t = case/cases`. Keeps early counterexamples small.
    pub fn sized(&mut self, lo: usize, hi: usize) -> usize {
        let t = (self.case + 1) as f64 / self.cases as f64;
        let cap = lo + ((hi - lo) as f64 * t).round() as usize;
        self.rng.range(lo, cap.max(lo))
    }

    /// Standard Gaussian.
    pub fn gaussian(&mut self) -> f64 {
        self.rng.gaussian()
    }

    /// Vector of i.i.d. gaussians.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.rng.fill_gaussian(&mut v);
        v
    }

    /// Random path `(steps+1, dim)` row-major with N(0, scale²) increments.
    pub fn path(&mut self, steps: usize, dim: usize, scale: f64) -> Vec<f64> {
        self.rng.brownian_path(steps, dim, scale)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Environment knob: `PATHSIG_PROPTEST_SEED` overrides the base seed so
/// failures can be replayed exactly.
fn base_seed() -> u64 {
    std::env::var("PATHSIG_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_D00D)
}

/// Run `prop` on `cases` generated cases. Panics (with the failing seed
/// and case index) if any case panics. Each case gets an independent,
/// deterministic RNG stream so failures replay exactly.
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(seed),
                case,
                cases,
            };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}, \
                 set PATHSIG_PROPTEST_SEED={seed0} to replay): {msg}"
            );
        }
    }
}

/// Assert two float slices are element-wise close (absolute + relative).
#[track_caller]
pub fn assert_allclose(got: &[f64], want: &[f64], atol: f64, rtol: f64, ctx: &str) {
    assert_eq!(
        got.len(),
        want.len(),
        "{ctx}: length mismatch {} vs {}",
        got.len(),
        want.len()
    );
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs().max(g.abs());
        assert!(
            (g - w).abs() <= tol || (g.is_nan() && w.is_nan()),
            "{ctx}: mismatch at [{i}]: got {g}, want {w} (|diff|={}, tol={tol})",
            (g - w).abs()
        );
    }
}

/// Max absolute difference between two slices (diagnostics).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        property("tautology", 32, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports() {
        property("must fail", 8, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 5, "x was {x}");
        });
    }

    #[test]
    fn sized_grows() {
        let mut small = 0;
        property("sized small early", 100, |g| {
            let s = g.sized(1, 50);
            if g.case < 10 {
                assert!(s <= 1 + 5, "early case too large: {s}");
            }
        });
        small += 1;
        assert_eq!(small, 1);
    }

    #[test]
    fn allclose_passes_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-9, 2.0], 1e-8, 0.0, "ok");
    }

    #[test]
    #[should_panic(expected = "mismatch at [1]")]
    fn allclose_fails_outside_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.1], 1e-8, 0.0, "bad");
    }
}
