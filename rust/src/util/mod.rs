//! From-scratch substrates: deterministic PRNG, JSON, FFT, thread pool,
//! descriptive statistics, CLI parsing and a property-testing
//! mini-framework.
//!
//! The crate is dependency-free by policy (the build environment is
//! hermetic — no network, no vendored registry), so everything a
//! production library would normally pull from crates.io
//! (serde/rayon/rand/criterion/proptest/clap/rustfft) is implemented
//! here.

pub mod envknob;
pub mod failpoint;
pub mod rng;
pub mod json;
pub mod fft;
pub mod pool;
pub mod threadpool;
pub mod stats;
pub mod cli;
pub mod proptest;
