//! From-scratch substrates: deterministic PRNG, JSON, FFT, thread pool,
//! descriptive statistics, CLI parsing and a property-testing
//! mini-framework.
//!
//! The execution image has no network access and only the `xla`,
//! `anyhow` and `num-traits` crates vendored, so everything a
//! production library would normally pull from crates.io
//! (serde/rayon/rand/criterion/proptest/clap) is implemented here.

pub mod rng;
pub mod json;
pub mod fft;
pub mod threadpool;
pub mod stats;
pub mod cli;
pub mod proptest;
