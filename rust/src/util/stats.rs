//! Descriptive statistics and latency histograms.
//!
//! Used by the benchmark harness (warmup/median/IQR reporting as in the
//! paper's §6 protocol: "3–10 warm-up runs, followed by averaged
//! measurements over 10–50 runs") and by the coordinator's metrics
//! registry.

/// Summary statistics over a sample of `f64` observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest observation.
    pub min: f64,
    /// 25th percentile (linear interpolation).
    pub p25: f64,
    /// 50th percentile.
    pub median: f64,
    /// 75th percentile.
    pub p75: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Largest observation.
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns default (zeros) on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p25: percentile_sorted(&v, 0.25),
            median: percentile_sorted(&v, 0.50),
            p75: percentile_sorted(&v, 0.75),
            p95: percentile_sorted(&v, 0.95),
            max: v[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Fixed-bucket log-scale latency histogram (nanoseconds), lock-free
/// increments. Bucket `i` covers `[2^i, 2^(i+1))` ns — 64 buckets cover
/// everything up to ~584 years.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<std::sync::atomic::AtomicU64>,
    count: std::sync::atomic::AtomicU64,
    sum_ns: std::sync::atomic::AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Create an empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..64).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
            count: std::sync::atomic::AtomicU64::new(0),
            sum_ns: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Record a duration.
    pub fn record(&self, d: std::time::Duration) {
        use std::sync::atomic::Ordering::Relaxed;
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let bucket = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
    }

    /// Total number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Mean recorded latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        use std::sync::atomic::Ordering::Relaxed;
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Relaxed);
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// Pearson correlation of two equal-length samples.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (a, b) in x.iter().zip(y) {
        cov += (a - mx) * (b - my);
        vx += (a - mx) * (a - mx);
        vy += (b - my) * (b - my);
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(std::time::Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_ns(0.5) <= h.quantile_ns(0.99));
        assert!(h.mean_ns() > 1e6);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [-1.0, -2.0, -3.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }
}
