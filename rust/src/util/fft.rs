//! Radix-2 iterative complex FFT.
//!
//! Substrate for the Davies–Harte exact fBM sampler ([`crate::fbm`]), which
//! needs an `O(M log M)` circulant-embedding transform. Implemented from
//! scratch (no FFT crate vendored): bit-reversal permutation + iterative
//! Cooley–Tukey butterflies.

use std::f64::consts::PI;

/// Complex number as `(re, im)` — kept as a plain tuple-struct to avoid
/// pulling in a complex-arithmetic dependency.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct C64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl C64 {
    /// Construct from real and imaginary parts.
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    /// Complex multiplication.
    #[inline]
    pub fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    /// Complex addition.
    #[inline]
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    /// Complex subtraction.
    #[inline]
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

/// In-place FFT (forward for `inverse=false`). Length must be a power of 2.
///
/// The inverse transform applies the conventional `1/n` normalisation.
pub fn fft(buf: &mut [C64], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }

    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i.reverse_bits() >> (usize::BITS - bits)) as usize;
        if i < j {
            buf.swap(i, j);
        }
    }

    // Iterative butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = C64::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = C64::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = buf[i + k];
                let v = buf[i + k + len / 2].mul(w);
                buf[i + k] = u.add(v);
                buf[i + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }

    if inverse {
        let inv_n = 1.0 / n as f64;
        for x in buf.iter_mut() {
            x.re *= inv_n;
            x.im *= inv_n;
        }
    }
}

/// Convenience: FFT of a real signal, returning the complex spectrum.
pub fn rfft(signal: &[f64]) -> Vec<C64> {
    let mut buf: Vec<C64> = signal.iter().map(|&x| C64::new(x, 0.0)).collect();
    fft(&mut buf, false);
    buf
}

/// Smallest power of two `>= n`.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn allclose(a: &[C64], b: &[C64], tol: f64) -> bool {
        a.len() == b.len()
            && a.iter()
                .zip(b)
                .all(|(x, y)| (x.re - y.re).abs() < tol && (x.im - y.im).abs() < tol)
    }

    #[test]
    fn delta_transforms_to_ones() {
        let mut buf = vec![C64::default(); 8];
        buf[0] = C64::new(1.0, 0.0);
        fft(&mut buf, false);
        for x in &buf {
            assert!((x.re - 1.0).abs() < 1e-12 && x.im.abs() < 1e-12);
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(3);
        let orig: Vec<C64> = (0..64)
            .map(|_| C64::new(rng.gaussian(), rng.gaussian()))
            .collect();
        let mut buf = orig.clone();
        fft(&mut buf, false);
        fft(&mut buf, true);
        assert!(allclose(&buf, &orig, 1e-10));
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = crate::util::rng::Rng::new(17);
        let x: Vec<C64> = (0..16)
            .map(|_| C64::new(rng.gaussian(), rng.gaussian()))
            .collect();
        // Naive O(n^2) DFT.
        let n = x.len();
        let mut want = vec![C64::default(); n];
        for (k, w) in want.iter_mut().enumerate() {
            for (j, &xj) in x.iter().enumerate() {
                let ang = -2.0 * PI * (k * j) as f64 / n as f64;
                *w = w.add(xj.mul(C64::new(ang.cos(), ang.sin())));
            }
        }
        let mut got = x.clone();
        fft(&mut got, false);
        assert!(allclose(&got, &want, 1e-9));
    }

    #[test]
    fn parseval_energy() {
        let mut rng = crate::util::rng::Rng::new(23);
        let x: Vec<f64> = (0..32).map(|_| rng.gaussian()).collect();
        let time_energy: f64 = x.iter().map(|v| v * v).sum();
        let spec = rfft(&x);
        let freq_energy: f64 =
            spec.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / x.len() as f64;
        assert!((time_energy - freq_energy).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn rejects_non_pow2() {
        let mut buf = vec![C64::default(); 12];
        fft(&mut buf, false);
    }
}
