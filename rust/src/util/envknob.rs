//! One-time stderr warnings for rejected environment-knob values.
//!
//! Every `PATHSIG_*` knob is parsed by a *pure* checked function
//! (`lane_width_from`, `threads_from_checked`, `chunk_policy_from_checked`,
//! `Isa::pick_from`, `precision_from` — each unit-tested per rejection
//! path without touching the process environment, since `set_var` races
//! parallel tests). A rejected value used to fall back to the default
//! silently; now the parser returns a message naming the rejected value
//! and the default used, and the engine funnels it here. Warnings are
//! deduplicated **per knob**, not per message: engines are constructed
//! on hot serving paths (one per word-table cache miss), and a
//! misconfigured environment should say so once, not once per engine.

use std::collections::BTreeSet;
use std::sync::Mutex;

/// Knobs that have already warned (process-wide).
static WARNED: Mutex<BTreeSet<&'static str>> = Mutex::new(BTreeSet::new());

/// Emit `msg` on stderr the first time `knob` warns in this process;
/// subsequent warnings for the same knob are dropped. Returns whether
/// the message was printed (the unit tests' observation point).
pub fn warn_knob_once(knob: &'static str, msg: &str) -> bool {
    let mut warned = WARNED.lock().unwrap_or_else(|e| e.into_inner());
    if warned.insert(knob) {
        eprintln!("pathsig: {msg}");
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warns_once_per_knob() {
        // Use test-local knob names: the set is process-global and
        // other tests may legitimately construct engines under a
        // misconfigured environment.
        assert!(warn_knob_once("TEST_KNOB_A", "first"));
        assert!(!warn_knob_once("TEST_KNOB_A", "second"));
        assert!(warn_knob_once("TEST_KNOB_B", "other knob still warns"));
        assert!(!warn_knob_once("TEST_KNOB_B", "but only once"));
    }
}
