//! Deterministic, seeded failpoints — a zero-dependency fault-injection
//! layer in the spirit of tikv's `fail-rs`, but schedule-driven so a
//! chaos run replays bit-identically from its seed.
//!
//! A *failpoint* is a named site in the code (journal append, fsync,
//! checkpoint rename, mailbox send, socket read …) that consults this
//! registry and, when armed, injects a fault: an `io::Error` or a
//! deterministic delay. Sites are compiled in **only** under the
//! `failpoints` cargo feature — without it, [`check`] is an
//! `#[inline(always)]` constant `None` and every site folds away, so
//! release hot paths carry zero overhead (the `BENCH_durability.json`
//! warm-append alloc counter stays exactly 0).
//!
//! ## Schedule grammar
//!
//! Schedules come from the `PATHSIG_FAILPOINTS` environment variable
//! (read lazily on the first armed hit) or programmatically via
//! [`configure`]:
//!
//! ```text
//! PATHSIG_FAILPOINTS="journal.append=err@3;journal.fsync=err@p0.01/seed42;mailbox.send=delay50ms@5"
//! ```
//!
//! Semicolon-separated `name=ACTION[@TRIGGER]` clauses:
//!
//! * `ACTION` — `err` (inject an `io::Error`) or `delay<N>ms` (sleep
//!   `N` milliseconds, then continue normally).
//! * `@N` — fire on exactly the `N`-th hit of the site (1-based).
//! * `@N..` — fire on the `N`-th hit and every hit after it.
//! * `@p<P>/seed<S>` — fire each hit independently with probability
//!   `P`, drawn from a per-point `splitmix64` stream seeded with `S`
//!   (deterministic: same seed, same hit sequence, same faults).
//! * no trigger — fire on every hit.
//!
//! The parser is compiled unconditionally (and unit-tested in tier-1
//! builds); only the *sites* are feature-gated.

use std::collections::BTreeMap;
use std::io;
use std::sync::Mutex;
use std::time::Duration;

use super::rng::splitmix64;

/// What an armed failpoint does when its trigger fires.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    /// Inject an `io::Error` (the site propagates it as the real
    /// failure would — disk full, EIO, connection reset …).
    Err,
    /// Sleep for the given duration, then continue normally (models
    /// slow disks, stalled peers, scheduler hiccups).
    Delay(Duration),
}

/// When an armed failpoint fires.
#[derive(Clone, Debug, PartialEq)]
pub enum Trigger {
    /// Every hit.
    Always,
    /// Exactly the `n`-th hit (1-based).
    Nth(u64),
    /// The `n`-th hit and every later one.
    From(u64),
    /// Each hit independently with probability `p`, from a seeded
    /// `splitmix64` stream.
    Prob {
        /// Fire probability per hit, in `[0, 1]`.
        p: f64,
        /// Seed of the per-point deterministic stream.
        seed: u64,
    },
}

/// One armed failpoint: parsed clause + hit bookkeeping.
#[derive(Clone, Debug)]
pub struct Point {
    /// Fault to inject when the trigger fires.
    pub action: Action,
    /// Firing schedule.
    pub trigger: Trigger,
    /// Times the site has been reached.
    pub hits: u64,
    /// Times the fault actually fired.
    pub fired: u64,
    /// Current state of the `Prob` stream (advances per hit).
    prob_state: u64,
}

struct Registry {
    points: BTreeMap<String, Point>,
}

/// `None` until the first armed hit or explicit [`configure`] /
/// [`clear`]; the env schedule is loaded exactly once.
static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

/// Parse a full schedule string into named points. Pure (no process
/// state), so every rejection path is unit-testable.
pub fn parse_schedule(spec: &str) -> Result<BTreeMap<String, Point>, String> {
    let mut points = BTreeMap::new();
    for clause in spec.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let (name, rest) = clause
            .split_once('=')
            .ok_or_else(|| format!("failpoint clause {clause:?}: missing '='"))?;
        let (action_s, trigger_s) = match rest.split_once('@') {
            Some((a, t)) => (a, Some(t)),
            None => (rest, None),
        };
        let action = parse_action(action_s)
            .ok_or_else(|| format!("failpoint {name:?}: bad action {action_s:?}"))?;
        let trigger = match trigger_s {
            None => Trigger::Always,
            Some(t) => parse_trigger(t)
                .ok_or_else(|| format!("failpoint {name:?}: bad trigger {t:?}"))?,
        };
        let prob_state = match trigger {
            Trigger::Prob { seed, .. } => seed,
            _ => 0,
        };
        points.insert(
            name.trim().to_string(),
            Point { action, trigger, hits: 0, fired: 0, prob_state },
        );
    }
    Ok(points)
}

fn parse_action(s: &str) -> Option<Action> {
    let s = s.trim();
    if s == "err" {
        return Some(Action::Err);
    }
    if let Some(ms) = s.strip_prefix("delay").and_then(|r| r.strip_suffix("ms")) {
        return ms.parse::<u64>().ok().map(|n| Action::Delay(Duration::from_millis(n)));
    }
    None
}

fn parse_trigger(s: &str) -> Option<Trigger> {
    let s = s.trim();
    if let Some(rest) = s.strip_prefix('p') {
        // p<float>/seed<u64> — probabilistic, explicitly seeded so the
        // schedule is reproducible (an unseeded random fault would
        // defeat the whole point of the layer).
        let (p_s, seed_s) = rest.split_once("/seed")?;
        let p: f64 = p_s.parse().ok()?;
        if !(0.0..=1.0).contains(&p) {
            return None;
        }
        let seed: u64 = seed_s.parse().ok()?;
        return Some(Trigger::Prob { p, seed });
    }
    if let Some(n_s) = s.strip_suffix("..") {
        let n: u64 = n_s.parse().ok()?;
        return if n >= 1 { Some(Trigger::From(n)) } else { None };
    }
    let n: u64 = s.parse().ok()?;
    if n >= 1 {
        Some(Trigger::Nth(n))
    } else {
        None
    }
}

fn load_env() -> Registry {
    let points = match std::env::var("PATHSIG_FAILPOINTS") {
        Ok(spec) => match parse_schedule(&spec) {
            Ok(p) => p,
            Err(e) => {
                super::envknob::warn_knob_once(
                    "PATHSIG_FAILPOINTS",
                    &format!("PATHSIG_FAILPOINTS rejected ({e}); no failpoints armed"),
                );
                BTreeMap::new()
            }
        },
        Err(_) => BTreeMap::new(),
    };
    Registry { points }
}

/// Install a schedule programmatically (replaces any env/previous
/// schedule and resets all hit counters). Tests serialize access to
/// the process-global registry around this.
pub fn configure(spec: &str) -> Result<(), String> {
    let points = parse_schedule(spec)?;
    *REGISTRY.lock().unwrap_or_else(|e| e.into_inner()) = Some(Registry { points });
    Ok(())
}

/// Disarm every failpoint (and stop the env schedule from reloading).
pub fn clear() {
    *REGISTRY.lock().unwrap_or_else(|e| e.into_inner()) =
        Some(Registry { points: BTreeMap::new() });
}

/// `(hits, fired)` counters for a named point — the chaos suite's
/// observation hook. `(0, 0)` when the point is not armed.
pub fn counters(name: &str) -> (u64, u64) {
    let mut guard = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let reg = guard.get_or_insert_with(load_env);
    reg.points.get(name).map(|p| (p.hits, p.fired)).unwrap_or((0, 0))
}

/// Record a hit on `name` and return the fault to inject, if the
/// point is armed and its trigger fires. `Delay` actions sleep here
/// (outside the registry lock) and return `None` — the site proceeds
/// normally after the stall.
pub fn hit(name: &str) -> Option<io::Error> {
    let delay;
    {
        let mut guard = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        let reg = guard.get_or_insert_with(load_env);
        let point = reg.points.get_mut(name)?;
        point.hits += 1;
        let fires = match point.trigger {
            Trigger::Always => true,
            Trigger::Nth(n) => point.hits == n,
            Trigger::From(n) => point.hits >= n,
            Trigger::Prob { p, .. } => {
                let draw = splitmix64(&mut point.prob_state);
                ((draw >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < p
            }
        };
        if !fires {
            return None;
        }
        point.fired += 1;
        match point.action {
            Action::Err => {
                return Some(io::Error::other(format!("failpoint {name}: injected fault")))
            }
            Action::Delay(d) => delay = d,
        }
    }
    std::thread::sleep(delay);
    None
}

/// Consult the failpoint `name`. With the `failpoints` feature off
/// this is a constant `None` that the optimizer removes entirely —
/// the only form sites are allowed to call.
#[cfg(feature = "failpoints")]
#[inline]
pub fn check(name: &str) -> Option<io::Error> {
    hit(name)
}

/// Consult the failpoint `name` (no-op build: always `None`).
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn check(_name: &str) -> Option<io::Error> {
    None
}

/// Inject at an `io::Result` site: `failpoint!("journal.append");`
/// early-returns the injected error (via `.into()`, so sites whose
/// error type is `From<io::Error>` work too). Expands to nothing
/// observable when the `failpoints` feature is off.
#[macro_export]
macro_rules! failpoint {
    ($name:expr) => {
        if let Some(e) = $crate::util::failpoint::check($name) {
            return Err(e.into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_issue_example() {
        let pts = parse_schedule(
            "journal.append=err@3;journal.fsync=err@p0.01/seed42;mailbox.send=delay50ms@5",
        )
        .unwrap();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts["journal.append"].action, Action::Err);
        assert_eq!(pts["journal.append"].trigger, Trigger::Nth(3));
        assert_eq!(
            pts["journal.fsync"].trigger,
            Trigger::Prob { p: 0.01, seed: 42 }
        );
        assert_eq!(
            pts["mailbox.send"].action,
            Action::Delay(Duration::from_millis(50))
        );
        assert_eq!(pts["mailbox.send"].trigger, Trigger::Nth(5));
    }

    #[test]
    fn parses_open_ranges_and_always() {
        let pts = parse_schedule("a=err;b=err@2..;c=delay5ms").unwrap();
        assert_eq!(pts["a"].trigger, Trigger::Always);
        assert_eq!(pts["b"].trigger, Trigger::From(2));
        assert_eq!(pts["c"].action, Action::Delay(Duration::from_millis(5)));
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "noequals",
            "x=panic",
            "x=err@0",
            "x=err@p1.5/seed1",
            "x=err@pnope/seed1",
            "x=err@p0.5",
            "x=delayms",
            "x=delay5s",
            "x=err@0..",
        ] {
            assert!(parse_schedule(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn empty_and_whitespace_ok() {
        assert!(parse_schedule("").unwrap().is_empty());
        assert!(parse_schedule(" ; ;").unwrap().is_empty());
        let pts = parse_schedule(" a = err @ 2 ").unwrap();
        assert_eq!(pts["a"].trigger, Trigger::Nth(2));
    }

    #[test]
    fn prob_stream_is_deterministic() {
        // Two points with the same seed fire on exactly the same hits.
        let fire_pattern = |seed: u64| -> Vec<bool> {
            let mut state = seed;
            (0..256)
                .map(|_| {
                    let draw = splitmix64(&mut state);
                    ((draw >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < 0.25
                })
                .collect()
        };
        assert_eq!(fire_pattern(42), fire_pattern(42));
        assert_ne!(fire_pattern(42), fire_pattern(43));
        let fired = fire_pattern(42).iter().filter(|f| **f).count();
        assert!((32..96).contains(&fired), "p=0.25 over 256 hits fired {fired}");
    }

    // The registry tests below replace process-global state
    // (configure() swaps the whole schedule), so they serialize on a
    // module lock instead of relying on distinct point names.
    static REG_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn nth_trigger_fires_exactly_once() {
        let _g = REG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        configure("test.reg.nth=err@3").unwrap();
        assert!(hit("test.reg.nth").is_none());
        assert!(hit("test.reg.nth").is_none());
        let e = hit("test.reg.nth").expect("3rd hit fires");
        assert!(e.to_string().contains("test.reg.nth"));
        assert!(hit("test.reg.nth").is_none());
        assert_eq!(counters("test.reg.nth"), (4, 1));
        clear();
    }

    #[test]
    fn unarmed_points_are_free() {
        let _g = REG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        configure("test.reg.other=err").unwrap();
        assert!(hit("test.reg.unarmed").is_none());
        assert_eq!(counters("test.reg.unarmed"), (0, 0));
        clear();
    }

    #[test]
    fn check_matches_feature_state() {
        // In no-op builds check() must be None even when armed; with
        // the feature on it must behave exactly like hit().
        let _g = REG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        configure("test.reg.check=err").unwrap();
        let got = check("test.reg.check");
        if cfg!(feature = "failpoints") {
            assert!(got.is_some());
        } else {
            assert!(got.is_none());
        }
        clear();
    }
}
