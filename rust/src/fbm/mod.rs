//! Fractional Brownian motion generators — the §8 workload substrate.
//!
//! The paper's illustrative experiment estimates the Hurst parameter of a
//! multivariate fBM with independent components from simulated paths
//! (`H ~ U(0.25, 0.75)`, 250 steps). We implement two exact samplers:
//!
//! * [`davies_harte_fgn`] — circulant embedding of the fractional
//!   Gaussian noise covariance, `O(M log M)` via the from-scratch FFT
//!   ([`crate::util::fft`]). Used for dataset generation.
//! * [`cholesky_fgn`] — `O(M³)` Cholesky factorisation of the exact
//!   covariance, used as the correctness oracle for Davies–Harte.
//!
//! Both return *fGn increments* at unit spacing scaled to a path on
//! `[0, 1]`, i.e. `X_{k/M} = (1/M)^H · Σ_{j≤k} ξ_j`.

use crate::util::fft::{fft, C64};
use crate::util::rng::Rng;

/// Autocovariance of unit-spacing fractional Gaussian noise:
/// `γ(k) = ½(|k+1|^{2H} − 2|k|^{2H} + |k−1|^{2H})`.
pub fn fgn_autocov(k: usize, hurst: f64) -> f64 {
    let h2 = 2.0 * hurst;
    let kf = k as f64;
    0.5 * ((kf + 1.0).powf(h2) - 2.0 * kf.powf(h2) + (kf - 1.0).abs().powf(h2))
}

/// Sample `m` fGn increments (unit variance at lag 0) via Davies–Harte.
///
/// Internally embeds into a circulant of length `2·next_pow2(m)` so the
/// radix-2 FFT applies. Panics if the embedding is not nonnegative
/// definite (does not occur for `H ∈ (0,1)` with this embedding size).
pub fn davies_harte_fgn(rng: &mut Rng, m: usize, hurst: f64) -> Vec<f64> {
    assert!(m >= 1);
    assert!(hurst > 0.0 && hurst < 1.0, "H must be in (0,1)");
    if (hurst - 0.5).abs() < 1e-12 {
        // Plain white noise — skip the FFT machinery.
        let mut out = vec![0.0; m];
        rng.fill_gaussian(&mut out);
        return out;
    }
    let mp = m.next_power_of_two();
    let n2 = 2 * mp;
    // First row of the circulant: γ(0..mp), then mirrored tail.
    let mut row = vec![C64::default(); n2];
    for k in 0..=mp {
        row[k] = C64::new(fgn_autocov(k, hurst), 0.0);
    }
    for k in 1..mp {
        row[n2 - k] = C64::new(fgn_autocov(k, hurst), 0.0);
    }
    fft(&mut row, false);
    // Eigenvalues of the circulant = FFT of the first row (real).
    let mut lambda = vec![0.0; n2];
    for (i, c) in row.iter().enumerate() {
        let l = c.re;
        assert!(
            l > -1e-8,
            "circulant embedding not nonneg-definite (λ[{i}]={l}, H={hurst})"
        );
        lambda[i] = l.max(0.0);
    }
    // Synthesize the spectral sample.
    let mut y = vec![C64::default(); n2];
    y[0] = C64::new((lambda[0] * n2 as f64).sqrt() * rng.gaussian(), 0.0);
    y[mp] = C64::new((lambda[mp] * n2 as f64).sqrt() * rng.gaussian(), 0.0);
    for k in 1..mp {
        let scale = (lambda[k] * n2 as f64 / 2.0).sqrt();
        let (u, v) = (rng.gaussian(), rng.gaussian());
        y[k] = C64::new(scale * u, scale * v);
        y[n2 - k] = C64::new(scale * u, -scale * v);
    }
    fft(&mut y, true); // inverse FFT includes 1/n2
    y[..m].iter().map(|c| c.re).collect()
}

/// Exact fGn via Cholesky factorisation (oracle; `O(m³)`).
pub fn cholesky_fgn(rng: &mut Rng, m: usize, hurst: f64) -> Vec<f64> {
    // Covariance matrix Σ_{ij} = γ(|i-j|).
    let mut l = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..=i {
            l[i * m + j] = fgn_autocov(i - j, hurst);
        }
    }
    // In-place lower Cholesky.
    for i in 0..m {
        for j in 0..=i {
            let mut s = l[i * m + j];
            for k in 0..j {
                s -= l[i * m + k] * l[j * m + k];
            }
            if i == j {
                assert!(s > 0.0, "covariance not PD at {i}");
                l[i * m + i] = s.sqrt();
            } else {
                l[i * m + j] = s / l[j * m + j];
            }
        }
    }
    let mut z = vec![0.0; m];
    rng.fill_gaussian(&mut z);
    (0..m)
        .map(|i| (0..=i).map(|k| l[i * m + k] * z[k]).sum())
        .collect()
}

/// Which sampler to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FbmMethod {
    /// Circulant embedding + FFT, `O(M log M)` (dataset generation).
    DaviesHarte,
    /// Exact Cholesky factorisation, `O(M³)` (correctness oracle).
    Cholesky,
}

/// A `dim`-component fBM path on `[0,1]` with `steps` increments and
/// independent components sharing a single Hurst exponent (the §8
/// setup). Returns row-major `(steps+1, dim)` starting at 0.
pub fn fbm_path(rng: &mut Rng, steps: usize, dim: usize, hurst: f64, method: FbmMethod) -> Vec<f64> {
    let scale = (1.0 / steps as f64).powf(hurst);
    let mut path = vec![0.0; (steps + 1) * dim];
    for i in 0..dim {
        let fgn = match method {
            FbmMethod::DaviesHarte => davies_harte_fgn(rng, steps, hurst),
            FbmMethod::Cholesky => cholesky_fgn(rng, steps, hurst),
        };
        let mut acc = 0.0;
        for (j, xi) in fgn.iter().enumerate() {
            acc += xi * scale;
            path[(j + 1) * dim + i] = acc;
        }
    }
    path
}

/// A labelled dataset of fBM paths for Hurst regression: returns
/// `(paths (B, steps+1, dim), hurst (B))` with `H_b ~ U(h_lo, h_hi)`
/// i.i.d. per path (the paper's `H ~ U(0.25, 0.75)`).
pub fn fbm_dataset(
    rng: &mut Rng,
    batch: usize,
    steps: usize,
    dim: usize,
    h_lo: f64,
    h_hi: f64,
) -> (Vec<f64>, Vec<f64>) {
    let mut paths = Vec::with_capacity(batch * (steps + 1) * dim);
    let mut hs = Vec::with_capacity(batch);
    for _ in 0..batch {
        let h = rng.uniform_in(h_lo, h_hi);
        hs.push(h);
        paths.extend(fbm_path(rng, steps, dim, h, FbmMethod::DaviesHarte));
    }
    (paths, hs)
}

/// The lead–lag transform (Definition 8.1): `(M+1, d)` → `(2M+1, 2d)`,
/// channel layout `(lag_1..lag_d, lead_1..lead_d)` matching
/// [`crate::words::generate::sparse_leadlag_generators`].
pub fn lead_lag(path: &[f64], d: usize) -> Vec<f64> {
    let m1 = path.len() / d;
    let m = m1 - 1;
    let mut out = vec![0.0; (2 * m + 1) * 2 * d];
    lead_lag_into(path, d, &mut out);
    out
}

/// [`lead_lag`] writing into a caller-provided `(2M+1, 2d)` buffer —
/// the zero-allocation variant used by the training hot path.
pub fn lead_lag_into(path: &[f64], d: usize, out: &mut [f64]) {
    let m1 = path.len() / d;
    debug_assert_eq!(path.len(), m1 * d);
    let m = m1 - 1;
    let d2 = 2 * d;
    assert_eq!(out.len(), (2 * m + 1) * d2, "lead–lag buffer has wrong size");
    let pt = |j: usize| &path[j * d..(j + 1) * d];
    for k in 0..m {
        // X̂_{2k} = (X_k, X_k)
        out[(2 * k) * d2..(2 * k) * d2 + d].copy_from_slice(pt(k));
        out[(2 * k) * d2 + d..(2 * k + 1) * d2].copy_from_slice(pt(k));
        // X̂_{2k+1} = (X_k, X_{k+1})  (lag stays, lead advances)
        out[(2 * k + 1) * d2..(2 * k + 1) * d2 + d].copy_from_slice(pt(k));
        out[(2 * k + 1) * d2 + d..(2 * k + 2) * d2].copy_from_slice(pt(k + 1));
    }
    // X̂_{2M} = (X_M, X_M)
    out[(2 * m) * d2..(2 * m) * d2 + d].copy_from_slice(pt(m));
    out[(2 * m) * d2 + d..(2 * m + 1) * d2].copy_from_slice(pt(m));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autocov_h_half_is_white() {
        assert!((fgn_autocov(0, 0.5) - 1.0).abs() < 1e-12);
        for k in 1..10 {
            assert!(fgn_autocov(k, 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn autocov_signs_by_regime() {
        // H > 1/2 ⇒ positive correlation; H < 1/2 ⇒ negative at lag 1.
        assert!(fgn_autocov(1, 0.7) > 0.0);
        assert!(fgn_autocov(1, 0.3) < 0.0);
    }

    #[test]
    fn davies_harte_matches_theoretical_covariance() {
        // Estimate lag-0/1/2 covariances over many samples.
        let mut rng = Rng::new(600);
        let h = 0.7;
        let m = 64;
        let reps = 4000;
        let mut acc = [0.0; 3];
        for _ in 0..reps {
            let x = davies_harte_fgn(&mut rng, m, h);
            for lag in 0..3 {
                let mut c = 0.0;
                for i in 0..m - lag {
                    c += x[i] * x[i + lag];
                }
                acc[lag] += c / (m - lag) as f64;
            }
        }
        for (lag, a) in acc.iter().enumerate() {
            let got = a / reps as f64;
            let want = fgn_autocov(lag, h);
            assert!(
                (got - want).abs() < 0.02,
                "lag {lag}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn cholesky_matches_theoretical_covariance() {
        let mut rng = Rng::new(601);
        let h = 0.3;
        let m = 32;
        let reps = 4000;
        let mut lag1 = 0.0;
        for _ in 0..reps {
            let x = cholesky_fgn(&mut rng, m, h);
            let mut c = 0.0;
            for i in 0..m - 1 {
                c += x[i] * x[i + 1];
            }
            lag1 += c / (m - 1) as f64;
        }
        let got = lag1 / reps as f64;
        let want = fgn_autocov(1, h);
        assert!((got - want).abs() < 0.02, "got {got}, want {want}");
    }

    #[test]
    fn fbm_path_shape_and_start() {
        let mut rng = Rng::new(602);
        let p = fbm_path(&mut rng, 50, 3, 0.4, FbmMethod::DaviesHarte);
        assert_eq!(p.len(), 51 * 3);
        assert_eq!(&p[..3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn fbm_selfsimilarity_variance() {
        // Var[X_1] should be ≈ 1 for the scaled path (T=1).
        let mut rng = Rng::new(603);
        for &h in &[0.3, 0.6] {
            let reps = 3000;
            let mut v = 0.0;
            for _ in 0..reps {
                let p = fbm_path(&mut rng, 32, 1, h, FbmMethod::DaviesHarte);
                let x1 = p[32];
                v += x1 * x1;
            }
            v /= reps as f64;
            assert!((v - 1.0).abs() < 0.1, "H={h}: Var[X_1]={v}");
        }
    }

    #[test]
    fn dataset_shapes_and_label_range() {
        let mut rng = Rng::new(604);
        let (paths, hs) = fbm_dataset(&mut rng, 10, 20, 2, 0.25, 0.75);
        assert_eq!(paths.len(), 10 * 21 * 2);
        assert_eq!(hs.len(), 10);
        assert!(hs.iter().all(|&h| (0.25..0.75).contains(&h)));
    }

    #[test]
    fn lead_lag_structure() {
        // Simple 1-d path 0,1,3.
        let path = [0.0, 1.0, 3.0];
        let ll = lead_lag(&path, 1);
        // (2M+1, 2) = (5, 2): rows (lag, lead):
        // (0,0), (0,1), (1,1), (1,3), (3,3)
        assert_eq!(ll, vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn lead_lag_level2_area_is_half_quadratic_variation() {
        // For the lead-lag path, S((lag,lead)) - S((lead,lag)) equals the
        // discrete quadratic variation Σ (ΔX)² … the antisymmetric part
        // is the signed area = ½·Σ(ΔX_j)² each orientation; check the
        // known identity area(lead,lag) = ½ Σ ΔX².
        use crate::sig::{signature, SigEngine};
        use crate::words::{Word, WordTable};
        let mut rng = Rng::new(605);
        let path: Vec<f64> = rng.brownian_path(20, 1, 1.0);
        let ll = lead_lag(&path, 1);
        // channels: 0 = lag, 1 = lead.
        let eng = SigEngine::new(WordTable::build(
            2,
            &[Word(vec![0, 1]), Word(vec![1, 0])],
        ));
        let sig = signature(&eng, &ll);
        let qv: f64 = (0..20)
            .map(|j| {
                let dx = path[j + 1] - path[j];
                dx * dx
            })
            .sum();
        // Per step the lead channel moves first, then the lag catches
        // up, so S(lead,lag) collects ΔX² while S(lag,lead) collects 0:
        // the antisymmetric part S(lag,lead) − S(lead,lag) = −[X, X].
        let area = sig[0] - sig[1];
        assert!(
            (area + qv).abs() < 1e-10,
            "lead-lag area {area} vs -QV {}",
            -qv
        );
    }
}
