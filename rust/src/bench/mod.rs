//! Benchmark harness + counting allocator.
//!
//! Mirrors the paper's §6 protocol: "3–10 warm-up runs, followed by
//! averaged measurements over 10–50 runs", reporting mean/median. Peak
//! memory (Table 2) is measured with [`CountingAllocator`], a
//! `#[global_allocator]` wrapper that tracks live bytes and a
//! resettable high-water mark — the host-side analogue of
//! `torch.cuda.max_memory_allocated()`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Timing result for one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    /// Case label.
    pub name: String,
    /// Discarded warm-up runs.
    pub warmups: usize,
    /// Timed runs.
    pub runs: usize,
    /// Mean seconds per run.
    pub mean_s: f64,
    /// Median seconds per run.
    pub median_s: f64,
    /// Fastest run, seconds.
    pub min_s: f64,
    /// Slowest run, seconds.
    pub max_s: f64,
}

impl Timing {
    /// Human-friendly duration (µs/ms/s).
    pub fn fmt_secs(s: f64) -> String {
        if s < 1e-3 {
            format!("{:.2} µs", s * 1e6)
        } else if s < 1.0 {
            format!("{:.2} ms", s * 1e3)
        } else {
            format!("{:.2} s", s)
        }
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {:>10}  median {:>10}  (n={})",
            self.name,
            Self::fmt_secs(self.mean_s),
            Self::fmt_secs(self.median_s),
            self.runs
        )
    }
}

/// Run `f` with `warmups` discarded runs then `runs` timed runs.
pub fn time_fn<F: FnMut()>(name: &str, warmups: usize, runs: usize, mut f: F) -> Timing {
    for _ in 0..warmups {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        name: name.to_string(),
        warmups,
        runs,
        mean_s: samples.iter().sum::<f64>() / runs as f64,
        median_s: crate::util::stats::percentile_sorted(&sorted, 0.5),
        min_s: sorted[0],
        max_s: sorted[runs - 1],
    }
}

/// Adaptive variant: choose the run count so the total measurement takes
/// roughly `budget_s` seconds (bounded to [paper's 10, 50] runs), after
/// a first calibration call.
pub fn time_auto<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> Timing {
    let t0 = Instant::now();
    f(); // calibration + first warmup
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let runs = ((budget_s / one) as usize).clamp(3, 50);
    let warmups = (runs / 3).clamp(1, 10);
    time_fn(name, warmups, runs, f)
}

// ------------------------------------------------------------------
// Counting allocator
// ------------------------------------------------------------------

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static COUNT: AtomicUsize = AtomicUsize::new(0);

/// Global-allocator wrapper tracking live bytes and a peak watermark.
/// Install in a bench binary with:
/// ```ignore
/// #[global_allocator]
/// static ALLOC: pathsig::bench::CountingAllocator = pathsig::bench::CountingAllocator;
/// ```
pub struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            COUNT.fetch_add(1, Ordering::Relaxed);
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            COUNT.fetch_add(1, Ordering::Relaxed);
            if new_size >= layout.size() {
                let live =
                    LIVE.fetch_add(new_size - layout.size(), Ordering::Relaxed) + new_size
                        - layout.size();
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// Live heap bytes right now (as seen by the counting allocator).
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Reset the peak watermark to the current live size.
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Peak live bytes since the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Total number of heap allocations (including reallocations) since
/// process start, as seen by the counting allocator. Diff two readings
/// around a call to verify a hot path is allocation-free in steady
/// state.
pub fn alloc_count() -> usize {
    COUNT.load(Ordering::Relaxed)
}

/// Measure the incremental peak heap usage of `f` (peak minus the live
/// bytes at entry). Only meaningful when the [`CountingAllocator`] is
/// installed as the global allocator.
pub fn measure_peak<T, F: FnOnce() -> T>(f: F) -> (T, usize) {
    let base = live_bytes();
    reset_peak();
    let out = f();
    let peak = peak_bytes().saturating_sub(base);
    (out, peak)
}

/// Pretty bytes.
pub fn fmt_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b} B")
    } else if b < 1024 * 1024 {
        format!("{:.1} KB", b as f64 / 1024.0)
    } else if b < 1024 * 1024 * 1024 {
        format!("{:.1} MB", b as f64 / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GB", b as f64 / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_runs() {
        let mut calls = 0;
        let t = time_fn("noop", 2, 5, || {
            calls += 1;
        });
        assert_eq!(calls, 7);
        assert_eq!(t.runs, 5);
        assert!(t.mean_s >= 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert!(Timing::fmt_secs(5e-6).contains("µs"));
        assert!(Timing::fmt_secs(5e-2).contains("ms"));
        assert!(fmt_bytes(2048).contains("KB"));
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MB"));
    }

    #[test]
    fn timing_report_contains_name() {
        let t = time_fn("my_bench", 0, 3, || {});
        assert!(t.report().contains("my_bench"));
    }
}
