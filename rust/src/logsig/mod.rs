//! Log-signatures in the Lyndon basis (paper §3.3).
//!
//! `pathsig` computes the log-signature as the tensor logarithm of the
//! signature, read off at Lyndon-word coordinates (Signatory's
//! "computationally efficient Lie basis"). The §3.3 optimisation is the
//! headline here: since only Lyndon coordinates of the **top** level are
//! needed, the signature itself is computed over the reduced word set
//!
//! ```text
//!   C = W_{≤N-1} ∪ Lyndon_N
//! ```
//!
//! (every factor of a Lyndon word of length `N` lies in `W_{≤N-1}`, so
//! the truncated log at those coordinates is exactly computable). The
//! level-`N` slab dominates both work and memory (`d^N` of `D_sig`
//! coefficients), so skipping its non-Lyndon part is where the paper's
//! "log-signature 2–3× faster than signature" observation comes from.
//!
//! All entry points run on the `*_into` + workspace-pool discipline:
//! scratch (closure state, dense power tensors, adjoint accumulators
//! and the §4 backward workspace) lives in a pooled
//! [`LogSigWorkspace`], so steady-state batch and gradient calls reuse
//! buffers instead of reallocating the dense tensor chain per call.

use crate::sig::{
    forward_sweep_range, sig_backward_into, BackwardWorkspace, SigEngine,
};
use crate::tensor::{mul_adjoint, TruncTensor};
use crate::util::pool::Pool;
use crate::util::threadpool::{parallel_fill_rows, parallel_for_into};
use crate::words::{lyndon_words, truncated_words, Word, WordTable};

/// Reusable scratch for log-signature forward/backward calls. One per
/// worker; engines cache them in a [`Pool`] so steady-state calls
/// perform no tensor reallocation.
#[derive(Debug)]
pub struct LogSigWorkspace {
    /// Closure state of the reduced signature engine.
    state: Vec<f64>,
    /// Step increment scratch for the forward sweep.
    dx: Vec<f64>,
    /// Dense `y = S - 1` truncated at depth `N-1`.
    y: TruncTensor,
    /// Dense powers `P_m = y^{⊗m}`, `m = 1..N-1`.
    powers: Vec<TruncTensor>,
    /// Dense truncated log `Σ c_m P_m` (forward outputs).
    dense_log: TruncTensor,
    /// Adjoint accumulators (backward).
    g_y: TruncTensor,
    g_powers: Vec<TruncTensor>,
    g_state: Vec<f64>,
    g_request: Vec<f64>,
    /// §4 signature backward scratch.
    bwd: BackwardWorkspace,
}

impl Default for LogSigWorkspace {
    fn default() -> Self {
        LogSigWorkspace {
            state: Vec::new(),
            dx: Vec::new(),
            y: TruncTensor::zero(1, 0),
            powers: Vec::new(),
            dense_log: TruncTensor::zero(1, 0),
            g_y: TruncTensor::zero(1, 0),
            g_powers: Vec::new(),
            g_state: Vec::new(),
            g_request: Vec::new(),
            bwd: BackwardWorkspace::default(),
        }
    }
}

/// Engine for Lyndon-basis log-signatures at depth `N`.
#[derive(Clone, Debug)]
pub struct LogSigEngine {
    /// Alphabet size / path dimension `d`.
    pub d: usize,
    /// Truncation depth `N`.
    pub depth: usize,
    /// Signature engine over the reduced set `W_{≤N-1} ∪ Lyndon_N`.
    pub sig: SigEngine,
    /// Output words: all Lyndon words of length `1..=N`, lex-ordered
    /// within each level, level-major.
    pub lyndon: Vec<Word>,
    /// Positions (state indices) of the level-`N` Lyndon words in the
    /// signature engine's state vector.
    top_state_idx: Vec<usize>,
    /// Positions of output Lyndon words with level `< N` inside the
    /// dense `T_{≤N-1}` flat layout, as (level, code).
    low_positions: Vec<(usize, usize)>,
    /// log-series coefficients c_m = (-1)^{m+1}/m.
    coef: Vec<f64>,
    /// Pooled per-worker scratch (cloning an engine yields empty pools).
    ws_pool: Pool<LogSigWorkspace>,
}

impl LogSigEngine {
    /// Build the engine for alphabet size `d` at depth `N ≥ 1`,
    /// materialising the reduced word set `W_{≤N-1} ∪ Lyndon_N`.
    pub fn new(d: usize, depth: usize) -> LogSigEngine {
        assert!(depth >= 1);
        // Request: dense words up to N-1 (state order) + Lyndon at N.
        let mut request = truncated_words(d, depth - 1);
        let top: Vec<Word> = lyndon_words(d, depth)
            .into_iter()
            .filter(|w| w.len() == depth)
            .collect();
        request.extend(top.iter().cloned());
        let table = WordTable::build(d, &request);
        let sig = SigEngine::new(table);

        let lyndon: Vec<Word> = {
            let mut v = lyndon_words(d, depth);
            v.sort_by_key(|w| (w.len(), w.0.clone()));
            v
        };
        let top_state_idx: Vec<usize> = top
            .iter()
            .map(|w| {
                let pos = sig
                    .table
                    .requested
                    .iter()
                    .position(|r| r == w)
                    .unwrap();
                sig.table.output_map[pos] as usize
            })
            .collect();
        let low_positions = lyndon
            .iter()
            .filter(|w| w.len() < depth)
            .map(|w| {
                (
                    w.len(),
                    crate::words::encode::word_code(&w.0, d) as usize,
                )
            })
            .collect();
        let coef = (0..=depth)
            .map(|m| {
                if m == 0 {
                    0.0
                } else if m % 2 == 1 {
                    1.0 / m as f64
                } else {
                    -1.0 / m as f64
                }
            })
            .collect();
        LogSigEngine {
            d,
            depth,
            sig,
            lyndon,
            top_state_idx,
            low_positions,
            coef,
            ws_pool: Pool::default(),
        }
    }

    /// Output dimension = number of Lyndon words ≤ depth (Witt sum).
    pub fn out_dim(&self) -> usize {
        self.lyndon.len()
    }

    /// Forward intermediates into the workspace: terminal closure state
    /// (`ws.state`), dense `y = S - 1` at depth `N-1` and its powers —
    /// allocation-free once the workspace is warm.
    fn forward_internal(&self, path: &[f64], ws: &mut LogSigWorkspace) {
        let d = self.sig.table.d;
        assert!(path.len() % d == 0, "path length not divisible by d");
        let m1 = path.len() / d;
        assert!(m1 >= 1, "path needs at least one point");
        forward_sweep_range(&self.sig, path, 0, m1 - 1, &mut ws.state, &mut ws.dx);
        // Dense y = S - 1 at depth N-1 (scalar part zeroed). Dense
        // words occupy state indices 1..=D_{N-1} in state order
        // (level-major, lex) — exactly the flat layout.
        ws.y.reset_zero(self.d, self.depth - 1);
        {
            let mut k = 1;
            for n in 1..self.depth {
                for c in 0..self.d.pow(n as u32) {
                    ws.y.levels[n][c] = ws.state[k];
                    k += 1;
                }
            }
        }
        // Dense powers P_m = y^{⊗m}, m = 1..N-1 (depth N-1).
        let np = (self.depth - 1).max(1);
        if ws.powers.len() != np {
            ws.powers = (0..np).map(|_| TruncTensor::zero(1, 0)).collect();
        }
        ws.powers[0].copy_from(&ws.y);
        for m in 2..self.depth {
            let (head, tail) = ws.powers.split_at_mut(m - 1);
            tail[0].mul_into(&head[m - 2], &ws.y);
        }
    }

    /// The log-signature in the Lyndon basis: coefficients of
    /// `log(S_{0,T}(X))` at Lyndon words, level-major then lex.
    ///
    /// # Examples
    ///
    /// ```
    /// use pathsig::logsig::LogSigEngine;
    ///
    /// let eng = LogSigEngine::new(2, 3);
    /// // One linear segment: log S = ΔX — level 1 only (primitivity).
    /// let out = eng.logsig(&[0.0, 0.0, 0.5, -0.25]);
    /// assert_eq!(out.len(), eng.out_dim());
    /// assert!((out[0] - 0.5).abs() < 1e-12);
    /// assert!((out[1] + 0.25).abs() < 1e-12);
    /// assert!(out[2..].iter().all(|x| x.abs() < 1e-12));
    /// ```
    pub fn logsig(&self, path: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.out_dim()];
        let mut workers = self.ws_pool.take_at_least(1);
        // A standalone single-path call is the one place the level-N
        // Lyndon slab (the dominant cost) can use the whole pool, so
        // spread the top-word loop across the engine's threads; the
        // per-worker `logsig_into` stays sequential and allocation-free.
        self.forward_internal(path, &mut workers[0]);
        self.outputs_from(&mut workers[0], &mut out, self.sig.threads);
        self.ws_pool.put(workers);
        out
    }

    /// [`LogSigEngine::logsig`] with caller-provided workspace and
    /// output row (`out.len() == out_dim()`) — the zero-allocation
    /// per-worker hot path (sequential inside; batch entry points
    /// parallelise over paths instead).
    pub fn logsig_into(&self, path: &[f64], ws: &mut LogSigWorkspace, out: &mut [f64]) {
        assert_eq!(out.len(), self.out_dim(), "output buffer has wrong size");
        self.forward_internal(path, ws);
        self.outputs_from(ws, out, 1);
    }

    fn outputs_from(&self, ws: &mut LogSigWorkspace, out: &mut [f64], threads: usize) {
        let n = self.depth;
        // Dense log at depth N-1: Σ c_m P_m.
        ws.dense_log.reset_zero(self.d, n - 1);
        for (m, p) in ws.powers.iter().enumerate() {
            let c = self.coef[m + 1];
            for lvl in 1..n {
                for (o, v) in ws.dense_log.levels[lvl].iter_mut().zip(&p.levels[lvl]) {
                    *o += c * v;
                }
            }
        }
        for (o, &(lvl, code)) in out.iter_mut().zip(&self.low_positions) {
            *o = ws.dense_log.levels[lvl][code];
        }
        // Top level: log_N(w) = c_1·S_N(w) + Σ_{m=2}^{N} c_m·(y^m)_N(w),
        // (y^m)_N(w) = Σ_{k} (y^{m-1})_k(w_[k]) · y_{N-k}(suffix_k).
        // One unit per top word; `threads == 1` runs inline with no
        // spawn and no allocation (`parallel_fill_rows` fast path).
        let n_low = self.low_positions.len();
        let top_words = self.top_words();
        let (powers, y, state) = (&ws.powers, &ws.y, &ws.state);
        parallel_fill_rows(&mut out[n_low..], 1, threads, |wi, slot| {
            let w = &top_words[wi];
            let s_top = state[self.top_state_idx[wi]];
            let mut acc = self.coef[1] * s_top;
            for m in 2..=n {
                acc += self.coef[m] * self.power_top_coeff(powers, y, w, m);
            }
            slot[0] = acc;
        });
    }

    /// Level-`N` Lyndon words (the top slab of the output).
    fn top_words(&self) -> &[Word] {
        let first_top = self
            .lyndon
            .iter()
            .position(|w| w.len() == self.depth)
            .unwrap_or(self.lyndon.len());
        &self.lyndon[first_top..]
    }

    /// `(y^m)_N(w)` via prefix/suffix contraction of dense lower levels.
    fn power_top_coeff(&self, powers: &[TruncTensor], y: &TruncTensor, w: &Word, m: usize) -> f64 {
        let n = self.depth;
        debug_assert!(m >= 2 && m <= n);
        let mut acc = 0.0;
        // prefix length k carries y^{m-1} (needs k ≥ m-1), suffix
        // length n-k carries y (needs n-k ≥ 1 ⇒ k ≤ n-1).
        for k in (m - 1).max(1)..n {
            let pk = crate::words::encode::word_code(&w.0[..k], self.d) as usize;
            let sk = crate::words::encode::word_code(&w.0[k..], self.d) as usize;
            let a = powers[m - 2].levels[k][pk];
            let b = y.levels[n - k][sk];
            acc += a * b;
        }
        acc
    }

    /// Batched log-signatures: `(B, M+1, d)` → `(B, out_dim)`. Rows are
    /// written straight into the output buffer (no post-join copy).
    pub fn logsig_batch(&self, paths: &[f64], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0; batch * self.out_dim()];
        self.logsig_batch_into(paths, batch, &mut out);
        out
    }

    /// [`LogSigEngine::logsig_batch`] writing into a caller-provided
    /// `(B, out_dim)` buffer with pooled per-worker workspaces — zero
    /// tensor churn in steady state.
    pub fn logsig_batch_into(&self, paths: &[f64], batch: usize, out: &mut [f64]) {
        assert!(batch > 0);
        assert_eq!(paths.len() % batch, 0);
        let per = paths.len() / batch;
        let odim = self.out_dim();
        assert_eq!(out.len(), batch * odim, "output buffer has wrong size");
        let nw = self.sig.threads.min(batch).max(1);
        let mut workers = self.ws_pool.take_at_least(nw);
        parallel_for_into(out, odim, &mut workers[..nw], |b, row, ws| {
            self.logsig_into(&paths[b * per..(b + 1) * per], ws, row);
        });
        self.ws_pool.put(workers);
    }

    /// Backward pass: cotangents on the Lyndon outputs → path gradient
    /// `(M+1, d)`. Reverse-mode through the truncated log series, then
    /// through the signature engine (§4).
    pub fn logsig_backward(&self, path: &[f64], grad_out: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; path.len()];
        let mut workers = self.ws_pool.take_at_least(1);
        self.logsig_backward_into(path, grad_out, &mut workers[0], &mut out);
        self.ws_pool.put(workers);
        out
    }

    /// [`LogSigEngine::logsig_backward`] with caller-provided workspace
    /// and output buffer (`out.len() == path.len()`) — the
    /// zero-allocation gradient path (the dense adjoint tensors and the
    /// §4 backward workspace are all recycled).
    pub fn logsig_backward_into(
        &self,
        path: &[f64],
        grad_out: &[f64],
        ws: &mut LogSigWorkspace,
        out: &mut [f64],
    ) {
        assert_eq!(grad_out.len(), self.out_dim());
        assert_eq!(out.len(), path.len(), "gradient buffer has wrong size");
        let n = self.depth;
        self.forward_internal(path, ws);

        // --- adjoint accumulators ---
        ws.g_y.reset_zero(self.d, n - 1);
        if ws.g_powers.len() != n - 1 {
            ws.g_powers = (0..n - 1).map(|_| TruncTensor::zero(1, 0)).collect();
        }
        for gp in &mut ws.g_powers {
            gp.reset_zero(self.d, n - 1);
        }
        // Gradient wrt signature state (closure layout).
        ws.g_state.clear();
        ws.g_state.resize(ws.state.len(), 0.0);

        // (1) dense Lyndon outputs: dense_log = Σ c_m P_m.
        let n_low = self.low_positions.len();
        for (oi, &(lvl, code)) in self.low_positions.iter().enumerate() {
            let g = grad_out[oi];
            for (m, gp) in ws.g_powers.iter_mut().enumerate() {
                gp.levels[lvl][code] += self.coef[m + 1] * g;
            }
        }
        // (2) top-level outputs.
        for (wi, w) in self.top_words().iter().enumerate() {
            let g = grad_out[n_low + wi];
            if g == 0.0 {
                continue;
            }
            ws.g_state[self.top_state_idx[wi]] += self.coef[1] * g;
            for m in 2..=n {
                let c = self.coef[m] * g;
                for k in (m - 1).max(1)..n {
                    let pk = crate::words::encode::word_code(&w.0[..k], self.d) as usize;
                    let sk = crate::words::encode::word_code(&w.0[k..], self.d) as usize;
                    let a = ws.powers[m - 2].levels[k][pk];
                    let b = ws.y.levels[n - k][sk];
                    ws.g_powers[m - 2].levels[k][pk] += c * b;
                    ws.g_y.levels[n - k][sk] += c * a;
                }
            }
        }
        // (3) reverse the power chain P_m = P_{m-1} ⊗ y.
        for m in (2..n).rev() {
            // C = A ⊗ B adjoint: Â(u) += Ĉ(u∘v)·B(v), B̂(v) += A(u)·Ĉ(u∘v).
            let (head, tail) = ws.g_powers.split_at_mut(m - 1);
            let gc = &tail[0]; // grad of P_m (index m-1)
            let ga = &mut head[m - 2]; // grad of P_{m-1}
            mul_adjoint(&ws.powers[m - 2], &ws.y, gc, ga, &mut ws.g_y);
        }
        // grad of P_1 = y flows straight into g_y.
        if n > 1 {
            for lvl in 1..n {
                for (gy, gp) in ws.g_y.levels[lvl].iter_mut().zip(&ws.g_powers[0].levels[lvl]) {
                    *gy += gp;
                }
            }
        }
        // (4) y = (dense part of state) - 1 ⇒ identity on levels ≥ 1.
        {
            let mut k = 1;
            for lvl in 1..n {
                for c in 0..self.d.pow(lvl as u32) {
                    ws.g_state[k] += ws.g_y.levels[lvl][c];
                    k += 1;
                }
            }
        }
        // (5) signature backward. g_state is in closure-state layout;
        // requested order = dense words then top Lyndon words, and
        // state indices 1.. match that order exactly.
        ws.g_request.clear();
        ws.g_request
            .extend(self.sig.table.output_map.iter().map(|&i| ws.g_state[i as usize]));
        sig_backward_into(&self.sig, path, &ws.g_request, &mut ws.bwd, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{sig_forward_state, signature};
    use crate::tensor::tensor_log_series;
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;
    use crate::words::lyndon::logsig_dim;

    /// Oracle: full dense signature at depth N → dense tensor log →
    /// read Lyndon coordinates.
    fn oracle_logsig(d: usize, depth: usize, path: &[f64]) -> Vec<f64> {
        let eng = SigEngine::new(WordTable::build(d, &truncated_words(d, depth)));
        let flat = signature(&eng, path);
        let mut s = TruncTensor::one(d, depth);
        let mut k = 0;
        for n in 1..=depth {
            for c in 0..d.pow(n as u32) {
                s.levels[n][c] = flat[k];
                k += 1;
            }
        }
        let log = tensor_log_series(&s);
        let mut ly = lyndon_words(d, depth);
        ly.sort_by_key(|w| (w.len(), w.0.clone()));
        ly.iter().map(|w| log.coeff(&w.0)).collect()
    }

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(400);
        for &(d, n, m) in &[(2, 3, 6), (3, 3, 5), (2, 5, 8), (4, 2, 10), (3, 4, 4)] {
            let eng = LogSigEngine::new(d, n);
            let path = rng.brownian_path(m, d, 0.5);
            let got = eng.logsig(&path);
            let want = oracle_logsig(d, n, &path);
            assert_allclose(&got, &want, 1e-11, 1e-9, &format!("logsig d={d} n={n}"));
        }
    }

    #[test]
    fn workspace_reuse_is_deterministic() {
        // The same workspace must give identical results across calls
        // with different shapes (tensor reset, not stale state).
        let mut rng = Rng::new(403);
        let eng3 = LogSigEngine::new(2, 3);
        let eng4 = LogSigEngine::new(2, 4);
        let mut ws = LogSigWorkspace::default();
        let p1 = rng.brownian_path(6, 2, 0.5);
        let p2 = rng.brownian_path(9, 2, 0.5);
        let mut a = vec![0.0; eng3.out_dim()];
        eng3.logsig_into(&p1, &mut ws, &mut a);
        // Interleave a different engine/shape through the same workspace.
        let mut b = vec![0.0; eng4.out_dim()];
        eng4.logsig_into(&p2, &mut ws, &mut b);
        let mut a2 = vec![0.0; eng3.out_dim()];
        eng3.logsig_into(&p1, &mut ws, &mut a2);
        assert_eq!(a, a2, "workspace reuse changed the result");
        assert_eq!(b, eng4.logsig(&p2), "fresh vs reused workspace");
    }

    #[test]
    fn dimension_is_witt_sum() {
        for &(d, n) in &[(2, 4), (3, 3), (6, 3), (4, 6)] {
            let eng = LogSigEngine::new(d, n);
            assert_eq!(eng.out_dim(), logsig_dim(d, n), "d={d} n={n}");
        }
    }

    #[test]
    fn single_segment_logsig_is_increment() {
        // log(exp(Δx)) = Δx: only level-1 Lyndon coordinates non-zero.
        let d = 3;
        let eng = LogSigEngine::new(d, 4);
        let path = [0.0, 0.0, 0.0, 1.5, -0.5, 0.25];
        let out = eng.logsig(&path);
        assert_allclose(&out[..3], &[1.5, -0.5, 0.25], 1e-13, 1e-12, "level1");
        assert!(out[3..].iter().all(|&x| x.abs() < 1e-12), "higher levels vanish");
    }

    #[test]
    fn reduced_state_is_smaller_than_full() {
        // §3.3: the engine must NOT materialise the non-Lyndon top level.
        let d = 4;
        let n = 5;
        let eng = LogSigEngine::new(d, n);
        let full_state = 1 + crate::words::generate::sig_dim(d, n);
        assert!(eng.sig.table.state_len < full_state / 2,
            "reduced {} vs full {}", eng.sig.table.state_len, full_state);
    }

    #[test]
    fn gradcheck_logsig() {
        let mut rng = Rng::new(401);
        for &(d, n, m) in &[(2, 3, 4), (3, 2, 5), (2, 4, 3)] {
            let eng = LogSigEngine::new(d, n);
            let path = rng.brownian_path(m, d, 0.6);
            let g: Vec<f64> = (0..eng.out_dim()).map(|_| rng.gaussian()).collect();
            let got = eng.logsig_backward(&path, &g);
            // Finite differences.
            let mut p = path.clone();
            let eps = 1e-5;
            for k in 0..path.len() {
                p[k] = path[k] + eps;
                let up: f64 = eng.logsig(&p).iter().zip(&g).map(|(a, b)| a * b).sum();
                p[k] = path[k] - eps;
                let dn: f64 = eng.logsig(&p).iter().zip(&g).map(|(a, b)| a * b).sum();
                p[k] = path[k];
                let fd = (up - dn) / (2.0 * eps);
                assert!(
                    (got[k] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                    "d={d} n={n} coord {k}: got {} fd {}",
                    got[k],
                    fd
                );
            }
        }
    }

    #[test]
    fn depth_one_roundtrip() {
        // Degenerate depth: log-sig = level-1 increments; backward is
        // the endpoint indicator (exercises the empty power chain).
        let eng = LogSigEngine::new(2, 1);
        let path = [0.0, 0.0, 1.0, -2.0, 3.0, 0.5];
        let out = eng.logsig(&path);
        assert_allclose(&out, &[3.0, 0.5], 1e-13, 0.0, "depth-1 logsig");
        let grad = eng.logsig_backward(&path, &[1.0, 0.0]);
        let want = [-1.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        assert_allclose(&grad, &want, 1e-13, 0.0, "depth-1 grad");
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(402);
        let eng = LogSigEngine::new(2, 3);
        let m = 7;
        let b = 3;
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(rng.brownian_path(m, 2, 1.0));
        }
        let all = eng.logsig_batch(&paths, b);
        let per = (m + 1) * 2;
        for k in 0..b {
            let single = eng.logsig(&paths[k * per..(k + 1) * per]);
            assert_allclose(
                &all[k * eng.out_dim()..(k + 1) * eng.out_dim()],
                &single,
                0.0,
                0.0,
                "row",
            );
        }
    }

    // sig_forward_state is still exercised through the public oracle
    // path below (kept from the pre-workspace implementation).
    #[test]
    fn forward_state_matches_reduced_projection() {
        let mut rng = Rng::new(404);
        let eng = LogSigEngine::new(3, 3);
        let path = rng.brownian_path(5, 3, 0.5);
        let state = sig_forward_state(&eng.sig, &path);
        let mut ws = LogSigWorkspace::default();
        let mut out = vec![0.0; eng.out_dim()];
        eng.logsig_into(&path, &mut ws, &mut out);
        // The workspace's state buffer must equal the standalone sweep.
        assert_eq!(ws.state, state);
    }
}
