//! The compute service: resolves a request configuration to an engine
//! (building and caching word tables on first use), and executes either
//! natively or through a matching PJRT artifact.
//!
//! Routing policy (`backend: "auto"`):
//! * a request is PJRT-eligible if the manifest has a `sig_fwd` entry
//!   with the same `(dim, depth, steps)` and truncated projection —
//!   artifacts have static shapes, so anything else falls back;
//! * otherwise the native word-basis engine handles it (any shape, any
//!   projection).

use super::protocol::{Backend, Request, RequestOp};
use crate::logsig::LogSigEngine;
use crate::sig::{signature, signature_batch_into, windowed_signatures, SigEngine, Window};
use crate::runtime::Runtime;
use crate::util::pool::Pool;
use crate::words::{WordSpec, WordTable};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// Reusable flatten/output buffers for the stacked-batch execution
/// path: the service keeps them pooled so steady-state batch requests
/// re-use one allocation pair instead of flattening into a fresh
/// vector per call.
#[derive(Debug, Default)]
struct BatchScratch {
    flat: Vec<f64>,
    out: Vec<f64>,
}

/// Cache key for an engine: alphabet size + projection description +
/// depth. (`WordSpec::describe()` is injective enough for our spec set
/// once combined with the explicit fields; custom word lists hash their
/// full contents.)
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ConfigKey {
    /// Path dimension / alphabet size.
    pub dim: usize,
    /// Truncation depth.
    pub depth: usize,
    /// Canonical identity string of the word spec.
    pub spec_id: String,
    /// Operation tag (`"sig"`, `"logsig"`, `"windowed"`, …).
    pub op: &'static str,
    /// Path points (M+1); part of the key so batches stack cleanly and
    /// PJRT artifacts (static shapes) can be matched.
    pub points: usize,
}

impl ConfigKey {
    /// The configuration key of a request.
    pub fn of(req: &Request) -> ConfigKey {
        ConfigKey {
            dim: req.dim,
            depth: req.depth,
            spec_id: spec_identity(&req.spec),
            op: match req.op {
                RequestOp::Signature => "sig",
                RequestOp::LogSig => "logsig",
                RequestOp::Windowed => "windowed",
                RequestOp::Metrics => "metrics",
                RequestOp::Ping => "ping",
            },
            points: if req.dim == 0 { 0 } else { req.path.len() / req.dim },
        }
    }
}

/// Full identity string of a word spec (cache-key safe).
fn spec_identity(spec: &WordSpec) -> String {
    match spec {
        WordSpec::Truncated { depth } => format!("trunc:{depth}"),
        WordSpec::Lyndon { depth } => format!("lyndon:{depth}"),
        WordSpec::Anisotropic { gamma, cutoff } => {
            format!("aniso:{cutoff}:{gamma:?}")
        }
        WordSpec::Dag { depth, edges } => format!("dag:{depth}:{edges:?}"),
        WordSpec::ConcatGenerated { depth, generators } => {
            format!("gen:{depth}:{generators:?}")
        }
        WordSpec::Custom { words } => format!("custom:{words:?}"),
    }
}

/// Engine cache + optional PJRT runtime.
pub struct SigService {
    engines: RwLock<HashMap<String, Arc<SigEngine>>>,
    logsig_engines: Mutex<HashMap<(usize, usize), Arc<LogSigEngine>>>,
    batch_scratch: Pool<BatchScratch>,
    /// PJRT artifact runtime, if one was configured at boot.
    pub runtime: Option<Arc<Runtime>>,
    /// Shared metrics registry (also read by the server).
    pub metrics: Arc<super::Metrics>,
}

impl SigService {
    /// Create a service, optionally wired to a PJRT runtime.
    pub fn new(runtime: Option<Arc<Runtime>>) -> SigService {
        SigService {
            engines: RwLock::new(HashMap::new()),
            logsig_engines: Mutex::new(HashMap::new()),
            batch_scratch: Pool::default(),
            runtime,
            metrics: Arc::new(super::Metrics::new()),
        }
    }

    /// Get (or build) the native engine for a (dim, spec) pair.
    pub fn engine(&self, dim: usize, spec: &WordSpec) -> Arc<SigEngine> {
        let key = format!("{dim}:{}", spec_identity(spec));
        if let Some(e) = self.engines.read().unwrap().get(&key) {
            return e.clone();
        }
        let words = spec.words(dim);
        let engine = Arc::new(SigEngine::new(WordTable::build(dim, &words)));
        self.engines
            .write()
            .unwrap()
            .insert(key, engine.clone());
        engine
    }

    /// Get (or build) the log-signature engine for a (dim, depth) pair.
    pub fn logsig_engine(&self, dim: usize, depth: usize) -> Arc<LogSigEngine> {
        let mut cache = self.logsig_engines.lock().unwrap();
        cache
            .entry((dim, depth))
            .or_insert_with(|| Arc::new(LogSigEngine::new(dim, depth)))
            .clone()
    }

    /// Name of a PJRT artifact able to serve `key` (batch size `b`), if
    /// any: kind `sig_fwd`, matching dim/depth/points, batch ≥ b,
    /// truncated projection only.
    pub fn pjrt_artifact_for(&self, key: &ConfigKey, b: usize) -> Option<String> {
        let rt = self.runtime.as_ref()?;
        if !rt.backend_available() {
            // Metadata-only runtime: routing to an artifact would burn a
            // padded input buffer per request just to hit the "no
            // backend" error and fall back.
            return None;
        }
        if key.op != "sig" || !key.spec_id.starts_with("trunc:") {
            return None;
        }
        rt.manifest
            .by_kind("sig_fwd")
            .into_iter()
            .filter(|e| {
                e.meta.get("dim").as_usize() == Some(key.dim)
                    && e.meta.get("depth").as_usize() == Some(key.depth)
                    && e.meta.get("points").as_usize() == Some(key.points)
                    && e.meta.get("batch").as_usize().unwrap_or(0) >= b
            })
            .min_by_key(|e| e.meta.get("batch").as_usize().unwrap_or(usize::MAX))
            .map(|e| e.name.clone())
    }

    /// Execute one request (no batching). Returns (flat result, shape,
    /// backend label).
    pub fn execute(&self, req: &Request) -> Result<(Vec<f64>, Vec<usize>, &'static str), String> {
        match req.op {
            RequestOp::Signature => {
                let key = ConfigKey::of(req);
                if req.backend != Backend::Native {
                    if let Some(name) = self.pjrt_artifact_for(&key, 1) {
                        if let Ok(out) = self.execute_pjrt_batch(&name, &[req.path.as_slice()]) {
                            let dim = out[0].len();
                            self.metrics
                                .pjrt_executions
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            return Ok((out.into_iter().next().unwrap(), vec![dim], "pjrt"));
                        }
                    }
                    if req.backend == Backend::Pjrt {
                        let reason = match &self.runtime {
                            None => "no PJRT runtime configured",
                            Some(rt) if !rt.backend_available() => {
                                "artifact manifest loaded but no PJRT execution \
                                 backend attached (see DESIGN.md)"
                            }
                            Some(_) => "no matching PJRT artifact for request shape",
                        };
                        return Err(format!("cannot serve backend=\"pjrt\": {reason}"));
                    }
                }
                let eng = self.engine(req.dim, &req.spec);
                let out = signature(&eng, &req.path);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let n = out.len();
                Ok((out, vec![n], "native"))
            }
            RequestOp::LogSig => {
                let eng = self.logsig_engine(req.dim, req.depth);
                let out = eng.logsig(&req.path);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let n = out.len();
                Ok((out, vec![n], "native"))
            }
            RequestOp::Windowed => {
                let eng = self.engine(req.dim, &req.spec);
                let wins: Vec<Window> = req
                    .windows
                    .iter()
                    .map(|&(l, r)| Window::new(l, r))
                    .collect();
                let out = windowed_signatures(&eng, &req.path, &wins);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let odim = eng.out_dim();
                Ok((out, vec![wins.len(), odim], "native"))
            }
            RequestOp::Metrics | RequestOp::Ping => {
                Err("control ops are handled by the server, not the service".into())
            }
        }
    }

    /// Execute a stacked batch of same-config signature requests
    /// natively (lane-major kernel once the batch spans a lane block).
    /// `paths` must all have equal length; paths are borrowed, not
    /// cloned, and the stacking flatten plus the batch output go
    /// through pooled scratch buffers — in steady state the only
    /// allocations left are the per-request response rows the wire
    /// protocol needs.
    pub fn execute_native_batch(
        &self,
        dim: usize,
        spec: &WordSpec,
        paths: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        let eng = self.engine(dim, spec);
        let odim = eng.out_dim();
        let mut scratch = self.batch_scratch.take_at_least(1);
        let ws = &mut scratch[0];
        ws.flat.clear();
        for p in paths {
            ws.flat.extend_from_slice(p);
        }
        ws.out.clear();
        ws.out.resize(paths.len() * odim, 0.0);
        signature_batch_into(&eng, &ws.flat, paths.len(), &mut ws.out);
        self.metrics
            .native_executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let rows = ws.out.chunks(odim).map(|c| c.to_vec()).collect();
        self.batch_scratch.put(scratch);
        rows
    }

    /// Execute a stacked batch through a PJRT artifact, padding the
    /// batch axis up to the artifact's static batch size.
    pub fn execute_pjrt_batch(
        &self,
        artifact: &str,
        paths: &[&[f64]],
    ) -> Result<Vec<Vec<f64>>, String> {
        let rt = self.runtime.as_ref().ok_or("no runtime configured")?;
        let entry = rt
            .manifest
            .find(artifact)
            .ok_or_else(|| format!("artifact '{artifact}' vanished"))?;
        let art_batch = entry.meta.get("batch").as_usize().unwrap_or(paths.len());
        let per_path = entry.inputs[0].numel() / art_batch;
        if paths.len() > art_batch {
            return Err(format!(
                "batch {} exceeds artifact batch {art_batch}",
                paths.len()
            ));
        }
        let mut input = vec![0f32; entry.inputs[0].numel()];
        for (b, p) in paths.iter().enumerate() {
            if p.len() != per_path {
                return Err(format!(
                    "path length {} does not match artifact slot {per_path}",
                    p.len()
                ));
            }
            for (k, &v) in p.iter().enumerate() {
                input[b * per_path + k] = v as f32;
            }
        }
        let outs = rt
            .run_f32(artifact, &[&input])
            .map_err(|e| format!("pjrt execution failed: {e}"))?;
        let flat = &outs[0];
        let odim = entry.outputs[0].numel() / art_batch;
        Ok(paths
            .iter()
            .enumerate()
            .map(|(b, _)| flat[b * odim..(b + 1) * odim].iter().map(|&x| x as f64).collect())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse_request;

    fn svc() -> SigService {
        SigService::new(None)
    }

    #[test]
    fn engine_cache_reuses() {
        let s = svc();
        let a = s.engine(2, &WordSpec::Truncated { depth: 3 });
        let b = s.engine(2, &WordSpec::Truncated { depth: 3 });
        assert!(Arc::ptr_eq(&a, &b));
        let c = s.engine(2, &WordSpec::Truncated { depth: 4 });
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn execute_signature_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, shape, backend) = s.execute(&req).unwrap();
        assert_eq!(shape, vec![6]);
        assert_eq!(backend, "native");
        // Level 1 = total displacement (1,1).
        assert!((out[0] - 1.0).abs() < 1e-12);
        assert!((out[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn execute_windowed_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"windowed","dim":1,"depth":2,"windows":[[0,1],[0,2]],
                "path":[0,1,3]}"#,
        )
        .unwrap();
        let (out, shape, _) = s.execute(&req).unwrap();
        assert_eq!(shape, vec![2, 2]);
        assert!((out[0] - 1.0).abs() < 1e-12); // S_(0,1) level 1
        assert!((out[2] - 3.0).abs() < 1e-12); // S_(0,2) level 1
    }

    #[test]
    fn execute_logsig_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"logsig","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, shape, _) = s.execute(&req).unwrap();
        // Lyndon dim for d=2, N=2: 2 + 1 = 3.
        assert_eq!(shape, vec![3]);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn native_batch_matches_singles() {
        let s = svc();
        let spec = WordSpec::Truncated { depth: 3 };
        let mut rng = crate::util::rng::Rng::new(900);
        let paths: Vec<Vec<f64>> = (0..5).map(|_| rng.brownian_path(7, 2, 1.0)).collect();
        let path_refs: Vec<&[f64]> = paths.iter().map(|p| p.as_slice()).collect();
        let batch = s.execute_native_batch(2, &spec, &path_refs);
        let eng = s.engine(2, &spec);
        for (b, p) in paths.iter().enumerate() {
            let single = crate::sig::signature(&eng, p);
            assert_eq!(batch[b], single);
        }
    }

    #[test]
    fn pjrt_preference_without_runtime_errors() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"backend":"pjrt","path":[0,0,1,1]}"#,
        )
        .unwrap();
        assert!(s.execute(&req).is_err());
    }
}
