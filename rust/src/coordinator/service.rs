//! The compute service: resolves a request configuration to an engine
//! (building and caching word tables on first use), and executes either
//! natively or through a matching PJRT artifact.
//!
//! Routing policy (`backend: "auto"`):
//! * a request is PJRT-eligible if the manifest has a `sig_fwd` entry
//!   with the same `(dim, depth, steps)` and truncated projection —
//!   artifacts have static shapes, so anything else falls back;
//! * otherwise the native word-basis engine handles it (any shape, any
//!   projection).
//!
//! Streaming sessions live in an actor-sharded table (see
//! [`super::shard`]): the service performs parsing, admission-relevant
//! budget checks and engine construction, then routes the session op to
//! the shard worker that owns it. The shard set is spun up lazily on
//! first use, capturing the `pub` tuning fields (`session_ttl`,
//! `max_sessions`, `shard_count`, …) at that point.

use super::protocol::{Backend, Request, RequestOp};
use super::shard::{ShardConfig, ShardSet, ShardStat, StreamError};
use crate::logsig::LogSigEngine;
use crate::persist::{cache_key, CacheStats, DurabilityConfig, DurabilityMode, SigCache};
use crate::sig::{
    gram_into, signature_batch_into, windowed_signatures, Precision, SigEngine, StreamEngine,
    StreamScratch, StreamTable, Window,
};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::words::{WordSpec, WordTable};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Duration;

/// Reusable flatten/output buffers for the stacked-batch execution
/// path: the service keeps them pooled so steady-state batch requests
/// re-use one allocation pair instead of flattening into a fresh
/// vector per call.
#[derive(Debug, Default)]
struct BatchScratch {
    flat: Vec<f64>,
    out: Vec<f64>,
}

/// Cache key for an engine: alphabet size + projection description +
/// depth. (`WordSpec::describe()` is injective enough for our spec set
/// once combined with the explicit fields; custom word lists hash their
/// full contents.)
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ConfigKey {
    /// Path dimension / alphabet size.
    pub dim: usize,
    /// Truncation depth.
    pub depth: usize,
    /// Canonical identity string of the word spec.
    pub spec_id: String,
    /// Operation tag (`"sig"`, `"logsig"`, `"windowed"`, …).
    pub op: &'static str,
    /// Path points (M+1); part of the key so batches stack cleanly and
    /// PJRT artifacts (static shapes) can be matched.
    pub points: usize,
}

impl ConfigKey {
    /// The configuration key of a request.
    pub fn of(req: &Request) -> ConfigKey {
        ConfigKey {
            dim: req.dim,
            depth: req.depth,
            spec_id: spec_identity(&req.spec),
            op: match req.op {
                RequestOp::Signature => "sig",
                RequestOp::Gram => "gram",
                RequestOp::LogSig => "logsig",
                RequestOp::Windowed => "windowed",
                RequestOp::Metrics => "metrics",
                RequestOp::Ping => "ping",
                RequestOp::Stats => "stats",
                RequestOp::StreamOpen
                | RequestOp::StreamPush
                | RequestOp::StreamWindow
                | RequestOp::StreamClose => "stream",
            },
            points: if req.dim == 0 { 0 } else { req.path.len() / req.dim },
        }
    }
}

/// Full identity string of a word spec (cache-key safe).
fn spec_identity(spec: &WordSpec) -> String {
    match spec {
        WordSpec::Truncated { depth } => format!("trunc:{depth}"),
        WordSpec::Lyndon { depth } => format!("lyndon:{depth}"),
        WordSpec::Anisotropic { gamma, cutoff } => {
            format!("aniso:{cutoff}:{gamma:?}")
        }
        WordSpec::Dag { depth, edges } => format!("dag:{depth}:{edges:?}"),
        WordSpec::ConcatGenerated { depth, generators } => {
            format!("gen:{depth}:{generators:?}")
        }
        WordSpec::Custom { words } => format!("custom:{words:?}"),
    }
}

/// What a stream op produced (the server maps this onto the wire
/// [`super::protocol::Response`] variants).
#[derive(Clone, Debug, PartialEq)]
pub enum StreamReply {
    /// `stream_open` succeeded.
    Opened {
        /// The session handle to pass to subsequent stream ops.
        session: String,
        /// Output dimension `|I|` of the session's projection.
        out_dim: usize,
    },
    /// `stream_push` appended samples.
    Pushed {
        /// Samples appended by this request.
        pushed: usize,
        /// Total samples the session has seen.
        seen: usize,
    },
    /// `stream_window` computed a signature.
    Values {
        /// Flat result values.
        result: Vec<f64>,
        /// Logical shape (`[|I|]`).
        shape: Vec<usize>,
    },
    /// `stream_close` freed the session.
    Closed,
}

/// Engine cache + optional PJRT runtime.
pub struct SigService {
    engines: RwLock<HashMap<String, Arc<SigEngine>>>,
    logsig_engines: Mutex<HashMap<(usize, usize), Arc<LogSigEngine>>>,
    batch_scratch: Pool<BatchScratch>,
    /// Factor-closed streaming tables, cached per `(dim, spec)` like
    /// [`SigService::engine`].
    stream_tables: RwLock<HashMap<String, Arc<StreamTable>>>,
    /// The actor-sharded session table, spun up lazily on first stream
    /// use so the `pub` tuning fields below can be set after `new()`.
    shards: OnceLock<Arc<ShardSet>>,
    /// Recycled stream workspaces: closing (or evicting) a session
    /// returns its buffers here so the next `stream_open` reuses them.
    /// Shared with the shard workers.
    stream_scratch: Arc<Pool<StreamScratch>>,
    /// Idle eviction threshold: sessions untouched for longer than
    /// this are dropped by their shard worker's sweep. Set before the
    /// first stream op.
    pub session_ttl: Duration,
    /// Upper bound on concurrently open sessions across all shards:
    /// `stream_open` is admission-controlled, so a client loop cannot
    /// exhaust server memory faster than the TTL reclaims it. Set
    /// before the first stream op.
    pub max_sessions: usize,
    /// Per-session reservation budget in `f64` slots: `stream_open`
    /// rejects configurations whose two-stack store would reserve more
    /// than this (`window · (state_len + d)`). The wire-level
    /// `MAX_STREAM_WINDOW` caps only the increment count; this bounds
    /// the actual memory, which scales with the word-table size.
    /// Default `1 << 24` (128 MiB per session); worst-case streaming
    /// footprint is `max_sessions · max_session_floats · 8` bytes.
    pub max_session_floats: usize,
    /// Shard workers to spin up; `0` (the default) resolves to the
    /// machine's available parallelism, capped at 8. Set before the
    /// first stream op (the CLI's `--shards`).
    pub shard_count: usize,
    /// Bounded per-shard mailbox capacity; a full mailbox load-sheds
    /// instead of blocking the connection thread.
    pub mailbox_capacity: usize,
    /// Backoff hint (milliseconds) carried in load-shed replies.
    pub shed_retry_ms: u64,
    /// Journal directory for crash-safe sessions; `None` (the default)
    /// disables durability entirely — no files are touched and every
    /// serving path is bitwise identical. Set before the first stream
    /// op (the CLI's `--journal-dir`).
    pub journal_dir: Option<PathBuf>,
    /// Checkpoint cadence: snapshot each shard's sessions (and truncate
    /// its journal) every this many journaled ops (`--checkpoint-every`).
    pub checkpoint_every: u64,
    /// `fdatasync` after every journal append (`--fsync`): a crash
    /// loses at most the record being written.
    pub fsync: bool,
    /// Journal-failure policy (`--durability`): strict rejects any op
    /// whose journal record cannot be made durable; degraded (the
    /// default) keeps acking from memory but flips the sticky
    /// `degraded` health bit. Irrelevant while `journal_dir` is
    /// `None`. Set before the first stream op.
    pub durability: DurabilityMode,
    /// Bounded content-addressed cache of terminal signatures consulted
    /// by the batch `signature` verb, in entries; `0` (the default)
    /// disables it — not even a key is hashed (`--sig-cache-cap`).
    pub sig_cache_cap: usize,
    /// Forward-path element precision applied to every engine this
    /// service builds (`--precision`): `None` (the default) keeps each
    /// engine's own default (the `PATHSIG_PRECISION` env knob, else
    /// f64); `Some(Precision::F32)` serves inference at double SIMD
    /// lane width. Streaming and training paths stay f64 either way.
    /// Set before the first request — engines are cached per word
    /// spec at the precision current when first built.
    pub precision: Option<Precision>,
    /// The content-addressed cache itself, spun up lazily with
    /// `sig_cache_cap` on first use.
    sig_cache: OnceLock<Mutex<SigCache>>,
    /// PJRT artifact runtime, if one was configured at boot.
    pub runtime: Option<Arc<Runtime>>,
    /// Shared metrics registry (also read by the server).
    pub metrics: Arc<super::Metrics>,
}

impl SigService {
    /// Create a service, optionally wired to a PJRT runtime.
    pub fn new(runtime: Option<Arc<Runtime>>) -> SigService {
        SigService {
            engines: RwLock::new(HashMap::new()),
            logsig_engines: Mutex::new(HashMap::new()),
            batch_scratch: Pool::default(),
            stream_tables: RwLock::new(HashMap::new()),
            shards: OnceLock::new(),
            stream_scratch: Arc::new(Pool::default()),
            session_ttl: Duration::from_secs(300),
            max_sessions: 1024,
            max_session_floats: 1 << 24,
            shard_count: 0,
            mailbox_capacity: 256,
            shed_retry_ms: 25,
            journal_dir: None,
            checkpoint_every: 256,
            fsync: false,
            durability: DurabilityMode::Degraded,
            sig_cache_cap: 0,
            precision: None,
            sig_cache: OnceLock::new(),
            runtime,
            metrics: Arc::new(super::Metrics::new()),
        }
    }

    /// Create a service with a fixed shard count (used by the CLI and
    /// the shard ≡ single-table equivalence tests).
    pub fn with_shards(runtime: Option<Arc<Runtime>>, shards: usize) -> SigService {
        let mut s = SigService::new(runtime);
        s.shard_count = shards;
        s
    }

    /// The shard set, spun up on first use with the current tuning
    /// fields. `shard_count == 0` resolves to available parallelism
    /// capped at 8 (diminishing returns past that: the mailbox hop
    /// costs more than the contention it removes).
    pub fn shard_set(&self) -> &Arc<ShardSet> {
        self.shards.get_or_init(|| {
            let shards = if self.shard_count == 0 {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    .clamp(1, 8)
            } else {
                self.shard_count
            };
            Arc::new(ShardSet::new(
                ShardConfig {
                    shards,
                    mailbox_capacity: self.mailbox_capacity,
                    session_ttl: self.session_ttl,
                    max_sessions: self.max_sessions,
                    shed_retry_ms: self.shed_retry_ms,
                    durability: self.journal_dir.as_ref().map(|dir| DurabilityConfig {
                        dir: dir.clone(),
                        checkpoint_every: self.checkpoint_every,
                        fsync: self.fsync,
                        max_session_floats: self.max_session_floats,
                        mode: self.durability,
                    }),
                },
                Arc::clone(&self.metrics),
                Arc::clone(&self.stream_scratch),
            ))
        })
    }

    /// Get (or build) the native engine for a (dim, spec) pair.
    pub fn engine(&self, dim: usize, spec: &WordSpec) -> Arc<SigEngine> {
        let key = format!("{dim}:{}", spec_identity(spec));
        if let Some(e) = self.engines.read().unwrap().get(&key) {
            return e.clone();
        }
        let words = spec.words(dim);
        let mut engine = SigEngine::new(WordTable::build(dim, &words));
        if let Some(p) = self.precision {
            engine.precision = p;
        }
        let engine = Arc::new(engine);
        self.engines
            .write()
            .unwrap()
            .insert(key, engine.clone());
        engine
    }

    /// Get (or build) the log-signature engine for a (dim, depth) pair.
    pub fn logsig_engine(&self, dim: usize, depth: usize) -> Arc<LogSigEngine> {
        let mut cache = self.logsig_engines.lock().unwrap();
        cache
            .entry((dim, depth))
            .or_insert_with(|| Arc::new(LogSigEngine::new(dim, depth)))
            .clone()
    }

    /// Get (or build) the factor-closed streaming table for a
    /// (dim, spec) pair.
    pub fn stream_table(&self, dim: usize, spec: &WordSpec) -> Arc<StreamTable> {
        let key = format!("{dim}:{}", spec_identity(spec));
        if let Some(t) = self.stream_tables.read().unwrap().get(&key) {
            return t.clone();
        }
        let words = spec.words(dim);
        let table = Arc::new(StreamTable::new(dim, &words));
        self.stream_tables.write().unwrap().insert(key, table.clone());
        table
    }

    /// The content-addressed signature cache, spun up on first use with
    /// the current `sig_cache_cap`.
    fn sig_cache(&self) -> &Mutex<SigCache> {
        self.sig_cache
            .get_or_init(|| Mutex::new(SigCache::new(self.sig_cache_cap)))
    }

    /// Point-in-time counters of the content-addressed signature cache
    /// (all zero while the cache is disabled or untouched).
    pub fn cache_stats(&self) -> CacheStats {
        self.sig_cache
            .get()
            .map_or_else(CacheStats::default, |c| c.lock().unwrap().stats())
    }

    /// Live session count across all shards.
    pub fn session_count(&self) -> usize {
        self.shards.get().map_or(0, |s| s.live_sessions())
    }

    /// Ask the shard workers to run their idle-eviction sweeps now.
    /// Workers also sweep on their own idle ticks (every `ttl / 10`,
    /// clamped to 5–100 ms), so calling this is never required for
    /// reclamation — it only accelerates it.
    pub fn evict_idle(&self) {
        if let Some(s) = self.shards.get() {
            s.sweep_all();
        }
    }

    /// Per-shard counters for the `stats` verb (empty until the first
    /// stream op spins the shard set up).
    pub fn shard_stats(&self) -> Vec<ShardStat> {
        self.shards.get().map_or_else(Vec::new, |s| s.stats())
    }

    /// JSON body of the `stats` wire verb: shard count, live sessions,
    /// per-shard counters (including the journal lag — records appended
    /// since that shard's last checkpoint), and the signature-cache
    /// counters. Spins the shard set up if needed so the reply always
    /// has one row per shard.
    pub fn stats_json(&self) -> Json {
        let set = self.shard_set();
        let rows: Vec<Json> = set
            .stats()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("shard", Json::Num(s.shard as f64)),
                    ("sessions", Json::Num(s.sessions as f64)),
                    ("mailbox_depth", Json::Num(s.mailbox_depth as f64)),
                    ("sheds", Json::Num(s.sheds as f64)),
                    ("pushes", Json::Num(s.pushes as f64)),
                    ("journal_lag", Json::Num(s.journal_lag as f64)),
                ])
            })
            .collect();
        let cache = self.cache_stats();
        let relaxed = std::sync::atomic::Ordering::Relaxed;
        Json::obj(vec![
            ("shards", Json::Num(set.shard_count() as f64)),
            ("live_sessions", Json::Num(set.live_sessions() as f64)),
            // Sticky durability-health bit: true once any journal
            // append failed in degraded mode (acks without a durable
            // record). Strict mode never sets it — those ops were
            // rejected, counted below instead.
            ("degraded", Json::Bool(self.metrics.degraded.load(relaxed) != 0)),
            (
                "journal_strict_rejects",
                Json::Num(self.metrics.journal_strict_rejects.load(relaxed) as f64),
            ),
            ("per_shard", Json::Arr(rows)),
            (
                "sig_cache",
                Json::obj(vec![
                    ("hits", Json::Num(cache.hits as f64)),
                    ("misses", Json::Num(cache.misses as f64)),
                    ("evictions", Json::Num(cache.evictions as f64)),
                ]),
            ),
        ])
    }

    /// Parse an `"s<N>"` session handle. Only the canonical form is
    /// accepted — the round-trip check rejects aliases like `"s+7"` or
    /// `"s007"` that `u64::from_str` would otherwise tolerate (a
    /// malformed handle must error, never address another session).
    fn parse_session_id(handle: &str) -> Result<u64, String> {
        handle
            .strip_prefix('s')
            .and_then(|n| n.parse::<u64>().ok())
            .filter(|id| format!("s{id}") == handle)
            .ok_or_else(|| format!("malformed session handle '{handle}'"))
    }

    /// Execute one stateful stream op against the sharded session
    /// table. Stream ops bypass the batcher: they are order-sensitive
    /// per session (a connection's requests are handled sequentially,
    /// so a client observes its own pushes). A full shard mailbox
    /// returns [`StreamError::Shed`] — the server answers with a
    /// `retry-after` frame instead of blocking.
    pub fn execute_stream(&self, req: &Request) -> Result<StreamReply, StreamError> {
        match req.op {
            RequestOp::StreamOpen => {
                let table = self.stream_table(req.dim, &req.spec);
                // Bound the actual reservation, not just the window
                // count: the two-stack store scales with the table.
                let need = req
                    .window_len
                    .saturating_mul(table.state_len() + table.dim());
                if need > self.max_session_floats {
                    return Err(StreamError::Msg(format!(
                        "session would reserve {need} floats (window {} × state \
                         {}), exceeding the per-session budget of {} floats",
                        req.window_len,
                        table.state_len(),
                        self.max_session_floats
                    )));
                }
                let scratch = {
                    let mut cache = self.stream_scratch.take_at_least(0);
                    let s = cache.pop().unwrap_or_default();
                    self.stream_scratch.put(cache);
                    s
                };
                let stream = StreamEngine::with_scratch(table, req.window_len, scratch);
                self.shard_set().open(stream, req.spec.clone())
            }
            RequestOp::StreamPush => {
                let id = Self::parse_session_id(&req.session)?;
                self.shard_set().push(id, req.samples.clone())
            }
            RequestOp::StreamWindow => {
                let id = Self::parse_session_id(&req.session)?;
                self.shard_set().window(id, req.full)
            }
            RequestOp::StreamClose => {
                let id = Self::parse_session_id(&req.session)?;
                self.shard_set().close(id)
            }
            _ => Err(StreamError::Msg("not a stream op".into())),
        }
    }

    /// Name of a PJRT artifact able to serve `key` (batch size `b`), if
    /// any: kind `sig_fwd`, matching dim/depth/points, batch ≥ b,
    /// truncated projection only.
    pub fn pjrt_artifact_for(&self, key: &ConfigKey, b: usize) -> Option<String> {
        let rt = self.runtime.as_ref()?;
        if !rt.backend_available() {
            // Metadata-only runtime: routing to an artifact would burn a
            // padded input buffer per request just to hit the "no
            // backend" error and fall back.
            return None;
        }
        if key.op != "sig" || !key.spec_id.starts_with("trunc:") {
            return None;
        }
        rt.manifest
            .by_kind("sig_fwd")
            .into_iter()
            .filter(|e| {
                e.meta.get("dim").as_usize() == Some(key.dim)
                    && e.meta.get("depth").as_usize() == Some(key.depth)
                    && e.meta.get("points").as_usize() == Some(key.points)
                    && e.meta.get("batch").as_usize().unwrap_or(0) >= b
            })
            .min_by_key(|e| e.meta.get("batch").as_usize().unwrap_or(usize::MAX))
            .map(|e| e.name.clone())
    }

    /// Execute one request (no batching). Returns (flat result, shape,
    /// backend label).
    pub fn execute(&self, req: &Request) -> Result<(Vec<f64>, Vec<usize>, &'static str), String> {
        match req.op {
            RequestOp::Signature => {
                let key = ConfigKey::of(req);
                // Content-addressed cache: identical (spec, increments)
                // requests are answered without touching any engine.
                // Disabled (`sig_cache_cap == 0`) means not even a key
                // is hashed; a forced-PJRT request also bypasses it so
                // its error semantics stay exact.
                let ckey = if self.sig_cache_cap > 0 && req.backend != Backend::Pjrt {
                    let k = cache_key(req.dim, &key.spec_id, &req.path);
                    if let Some(hit) = self.sig_cache().lock().unwrap().get(&k) {
                        let out = hit.to_vec();
                        let n = out.len();
                        return Ok((out, vec![n], "cache"));
                    }
                    Some(k)
                } else {
                    None
                };
                if req.backend != Backend::Native {
                    if let Some(name) = self.pjrt_artifact_for(&key, 1) {
                        if let Ok(out) = self.execute_pjrt_batch(&name, &[req.path.as_slice()]) {
                            let dim = out[0].len();
                            self.metrics
                                .pjrt_executions
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            return Ok((out.into_iter().next().unwrap(), vec![dim], "pjrt"));
                        }
                    }
                    if req.backend == Backend::Pjrt {
                        let reason = match &self.runtime {
                            None => "no PJRT runtime configured",
                            Some(rt) if !rt.backend_available() => {
                                "artifact manifest loaded but no PJRT execution \
                                 backend attached (see DESIGN.md)"
                            }
                            Some(_) => "no matching PJRT artifact for request shape",
                        };
                        return Err(format!("cannot serve backend=\"pjrt\": {reason}"));
                    }
                }
                let eng = self.engine(req.dim, &req.spec);
                // Route through the batch kernel with B = 1: identical
                // arithmetic for short paths (scalar fallback), and long
                // paths pick up the time-parallel scheduler — a single
                // wire request no longer serializes a worker on one
                // core (see `crate::sig::schedule`).
                let mut out = vec![0.0; eng.out_dim()];
                signature_batch_into(&eng, &req.path, 1, &mut out);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if let Some(k) = ckey {
                    self.sig_cache().lock().unwrap().insert(k, out.clone());
                }
                let n = out.len();
                Ok((out, vec![n], "native"))
            }
            RequestOp::Gram => {
                // One forward sweep over the whole batch (lane-major /
                // time-parallel routing inside `gram_into`), then the
                // syrk-style reduction; the parser guaranteed equal
                // per-path lengths and a batch within `MAX_GRAM_BATCH`,
                // so the (B, B) reply fits a v2 frame.
                let eng = self.engine(req.dim, &req.spec);
                let b = req.batch;
                let mut out = vec![0.0; b * b];
                gram_into(&eng, &req.path, b, &mut out);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok((out, vec![b, b], "native"))
            }
            RequestOp::LogSig => {
                let eng = self.logsig_engine(req.dim, req.depth);
                let out = eng.logsig(&req.path);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let n = out.len();
                Ok((out, vec![n], "native"))
            }
            RequestOp::Windowed => {
                let eng = self.engine(req.dim, &req.spec);
                let wins: Vec<Window> = req
                    .windows
                    .iter()
                    .map(|&(l, r)| Window::new(l, r))
                    .collect();
                let out = windowed_signatures(&eng, &req.path, &wins);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let odim = eng.out_dim();
                Ok((out, vec![wins.len(), odim], "native"))
            }
            RequestOp::Metrics | RequestOp::Ping | RequestOp::Stats => {
                Err("control ops are handled by the server, not the service".into())
            }
            RequestOp::StreamOpen
            | RequestOp::StreamPush
            | RequestOp::StreamWindow
            | RequestOp::StreamClose => {
                Err("stream ops are stateful; use SigService::execute_stream".into())
            }
        }
    }

    /// Execute a stacked batch of same-config signature requests
    /// natively (lane-major kernel once the batch spans a lane block).
    /// `paths` must all have equal length; paths are borrowed, not
    /// cloned, and the stacking flatten plus the batch output go
    /// through pooled scratch buffers — in steady state the only
    /// allocations left are the per-request response rows the wire
    /// protocol needs.
    pub fn execute_native_batch(
        &self,
        dim: usize,
        spec: &WordSpec,
        paths: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        let eng = self.engine(dim, spec);
        let odim = eng.out_dim();
        let mut scratch = self.batch_scratch.take_at_least(1);
        let ws = &mut scratch[0];
        ws.flat.clear();
        for p in paths {
            ws.flat.extend_from_slice(p);
        }
        ws.out.clear();
        ws.out.resize(paths.len() * odim, 0.0);
        signature_batch_into(&eng, &ws.flat, paths.len(), &mut ws.out);
        self.metrics
            .native_executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let rows = ws.out.chunks(odim).map(|c| c.to_vec()).collect();
        self.batch_scratch.put(scratch);
        rows
    }

    /// Execute a stacked batch through a PJRT artifact, padding the
    /// batch axis up to the artifact's static batch size.
    pub fn execute_pjrt_batch(
        &self,
        artifact: &str,
        paths: &[&[f64]],
    ) -> Result<Vec<Vec<f64>>, String> {
        let rt = self.runtime.as_ref().ok_or("no runtime configured")?;
        let entry = rt
            .manifest
            .find(artifact)
            .ok_or_else(|| format!("artifact '{artifact}' vanished"))?;
        let art_batch = entry.meta.get("batch").as_usize().unwrap_or(paths.len());
        let per_path = entry.inputs[0].numel() / art_batch;
        if paths.len() > art_batch {
            return Err(format!(
                "batch {} exceeds artifact batch {art_batch}",
                paths.len()
            ));
        }
        let mut input = vec![0f32; entry.inputs[0].numel()];
        for (b, p) in paths.iter().enumerate() {
            if p.len() != per_path {
                return Err(format!(
                    "path length {} does not match artifact slot {per_path}",
                    p.len()
                ));
            }
            for (k, &v) in p.iter().enumerate() {
                input[b * per_path + k] = v as f32;
            }
        }
        let outs = rt
            .run_f32(artifact, &[&input])
            .map_err(|e| format!("pjrt execution failed: {e}"))?;
        let flat = &outs[0];
        let odim = entry.outputs[0].numel() / art_batch;
        Ok(paths
            .iter()
            .enumerate()
            .map(|(b, _)| flat[b * odim..(b + 1) * odim].iter().map(|&x| x as f64).collect())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse_request;

    fn svc() -> SigService {
        SigService::new(None)
    }

    #[test]
    fn engine_cache_reuses() {
        let s = svc();
        let a = s.engine(2, &WordSpec::Truncated { depth: 3 });
        let b = s.engine(2, &WordSpec::Truncated { depth: 3 });
        assert!(Arc::ptr_eq(&a, &b));
        let c = s.engine(2, &WordSpec::Truncated { depth: 4 });
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn execute_signature_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, shape, backend) = s.execute(&req).unwrap();
        assert_eq!(shape, vec![6]);
        assert_eq!(backend, "native");
        // Level 1 = total displacement (1,1).
        assert!((out[0] - 1.0).abs() < 1e-12);
        assert!((out[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn execute_windowed_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"windowed","dim":1,"depth":2,"windows":[[0,1],[0,2]],
                "path":[0,1,3]}"#,
        )
        .unwrap();
        let (out, shape, _) = s.execute(&req).unwrap();
        assert_eq!(shape, vec![2, 2]);
        assert!((out[0] - 1.0).abs() < 1e-12); // S_(0,1) level 1
        assert!((out[2] - 3.0).abs() < 1e-12); // S_(0,2) level 1
    }

    #[test]
    fn execute_logsig_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"logsig","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, shape, _) = s.execute(&req).unwrap();
        // Lyndon dim for d=2, N=2: 2 + 1 = 3.
        assert_eq!(shape, vec![3]);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn native_batch_matches_singles() {
        let s = svc();
        let spec = WordSpec::Truncated { depth: 3 };
        let mut rng = crate::util::rng::Rng::new(900);
        let paths: Vec<Vec<f64>> = (0..5).map(|_| rng.brownian_path(7, 2, 1.0)).collect();
        let path_refs: Vec<&[f64]> = paths.iter().map(|p| p.as_slice()).collect();
        let batch = s.execute_native_batch(2, &spec, &path_refs);
        let eng = s.engine(2, &spec);
        for (b, p) in paths.iter().enumerate() {
            let single = crate::sig::signature(&eng, p);
            assert_eq!(batch[b], single);
        }
    }

    #[test]
    fn stream_session_lifecycle() {
        let s = svc();
        let open = parse_request(
            r#"{"op":"stream_open","dim":1,"depth":2,"window":2}"#,
        )
        .unwrap();
        let reply = s.execute_stream(&open).unwrap();
        let (session, out_dim) = match reply {
            StreamReply::Opened { session, out_dim } => (session, out_dim),
            other => panic!("expected Opened, got {other:?}"),
        };
        assert_eq!(out_dim, 2); // (1), (1,1)
        assert_eq!(s.session_count(), 1);

        let push = parse_request(&format!(
            r#"{{"op":"stream_push","session":"{session}","samples":[0,1,3,6]}}"#
        ))
        .unwrap();
        match s.execute_stream(&push).unwrap() {
            StreamReply::Pushed { pushed, seen } => {
                assert_eq!((pushed, seen), (4, 4));
            }
            other => panic!("expected Pushed, got {other:?}"),
        }

        let query = parse_request(&format!(
            r#"{{"op":"stream_window","session":"{session}"}}"#
        ))
        .unwrap();
        match s.execute_stream(&query).unwrap() {
            StreamReply::Values { result, shape } => {
                // Window of last 2 increments: X_3 - X_1 = 5.
                assert_eq!(shape, vec![2]);
                assert!((result[0] - 5.0).abs() < 1e-12);
            }
            other => panic!("expected Values, got {other:?}"),
        }
        let full = parse_request(&format!(
            r#"{{"op":"stream_window","session":"{session}","mode":"full"}}"#
        ))
        .unwrap();
        match s.execute_stream(&full).unwrap() {
            StreamReply::Values { result, .. } => assert!((result[0] - 6.0).abs() < 1e-12),
            other => panic!("expected Values, got {other:?}"),
        }

        let close = parse_request(&format!(
            r#"{{"op":"stream_close","session":"{session}"}}"#
        ))
        .unwrap();
        assert_eq!(s.execute_stream(&close).unwrap(), StreamReply::Closed);
        assert_eq!(s.session_count(), 0);
        // Double close errors without panicking.
        let err = s.execute_stream(&close).unwrap_err().to_string();
        assert!(err.contains("unknown session"), "{err}");
        // Push to the closed session errors too.
        assert!(s.execute_stream(&push).is_err());
    }

    #[test]
    fn stream_sessions_evict_after_ttl() {
        let mut service = SigService::new(None);
        service.session_ttl = Duration::from_millis(40);
        let s = service;
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":2,"window":4}"#,
        )
        .unwrap();
        let session = match s.execute_stream(&open).unwrap() {
            StreamReply::Opened { session, .. } => session,
            other => panic!("{other:?}"),
        };
        std::thread::sleep(Duration::from_millis(150));
        let push = parse_request(&format!(
            r#"{{"op":"stream_push","session":"{session}","samples":[0,0]}}"#
        ))
        .unwrap();
        let err = s.execute_stream(&push).unwrap_err().to_string();
        assert!(err.contains("unknown session"), "{err}");
        assert_eq!(s.session_count(), 0);
        assert_eq!(
            s.metrics.sessions_evicted.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn stream_session_cap_rejects_excess_opens() {
        let mut service = SigService::new(None);
        service.max_sessions = 2;
        let s = service;
        let open = parse_request(
            r#"{"op":"stream_open","dim":1,"depth":1,"window":2}"#,
        )
        .unwrap();
        let first = match s.execute_stream(&open).unwrap() {
            StreamReply::Opened { session, .. } => session,
            other => panic!("{other:?}"),
        };
        s.execute_stream(&open).unwrap();
        let err = s.execute_stream(&open).unwrap_err().to_string();
        assert!(err.contains("session table full"), "{err}");
        // Closing one frees a slot.
        let close = parse_request(&format!(
            r#"{{"op":"stream_close","session":"{first}"}}"#
        ))
        .unwrap();
        s.execute_stream(&close).unwrap();
        assert!(s.execute_stream(&open).is_ok());
    }

    #[test]
    fn stream_open_respects_session_float_budget() {
        // The budget bounds window · (state_len + d), not the raw
        // window count — a deep table with a modest window must be
        // rejected before any reservation happens.
        let mut service = SigService::new(None);
        service.max_session_floats = 100;
        let s = service;
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":64}"#,
        )
        .unwrap();
        let err = s.execute_stream(&open).unwrap_err().to_string();
        assert!(err.contains("per-session budget"), "{err}");
        assert_eq!(s.session_count(), 0);
        // A small window over the same table fits (15 + 2 floats/slot).
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":2}"#,
        )
        .unwrap();
        assert!(s.execute_stream(&open).is_ok());
    }

    #[test]
    fn stream_push_dim_mismatch_rejected() {
        let s = svc();
        let open = parse_request(
            r#"{"op":"stream_open","dim":3,"depth":1,"window":2}"#,
        )
        .unwrap();
        let session = match s.execute_stream(&open).unwrap() {
            StreamReply::Opened { session, .. } => session,
            other => panic!("{other:?}"),
        };
        let push = parse_request(&format!(
            r#"{{"op":"stream_push","session":"{session}","samples":[1,2]}}"#
        ))
        .unwrap();
        let err = s.execute_stream(&push).unwrap_err().to_string();
        assert!(err.contains("not divisible"), "{err}");
        // Garbage and non-canonical handles are rejected before the
        // session lookup — "s+1"/"s01" must not alias session s1.
        for handle in ["nope", "s+1", "s01", "s 1", "s18446744073709551616"] {
            let bad = parse_request(&format!(
                r#"{{"op":"stream_push","session":"{handle}","samples":[1,2,3]}}"#
            ))
            .unwrap();
            assert!(
                s.execute_stream(&bad)
                    .unwrap_err()
                    .to_string()
                    .contains("malformed"),
                "handle {handle:?} must be rejected as malformed"
            );
        }
    }

    #[test]
    fn stream_open_reuses_pooled_scratch_and_caches_tables() {
        let s = svc();
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":8}"#,
        )
        .unwrap();
        let a = s.stream_table(2, &WordSpec::Truncated { depth: 3 });
        let b = s.stream_table(2, &WordSpec::Truncated { depth: 3 });
        assert!(Arc::ptr_eq(&a, &b));
        // Open → close → open round-trips the scratch pool.
        for _ in 0..2 {
            let session = match s.execute_stream(&open).unwrap() {
                StreamReply::Opened { session, .. } => session,
                other => panic!("{other:?}"),
            };
            let close = parse_request(&format!(
                r#"{{"op":"stream_close","session":"{session}"}}"#
            ))
            .unwrap();
            s.execute_stream(&close).unwrap();
        }
        assert_eq!(s.session_count(), 0);
        assert_eq!(
            s.metrics.sessions_opened.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }

    #[test]
    fn shard_equivalence_smoke() {
        // Same tiny script on 1 and 4 shards: identical handles,
        // identical values (the full property lives in
        // tests/coordinator_properties.rs).
        let mut replies = Vec::new();
        for shards in [1usize, 4] {
            let s = SigService::with_shards(None, shards);
            let open = parse_request(
                r#"{"op":"stream_open","dim":1,"depth":2,"window":3}"#,
            )
            .unwrap();
            let session = match s.execute_stream(&open).unwrap() {
                StreamReply::Opened { session, .. } => session,
                other => panic!("{other:?}"),
            };
            let push = parse_request(&format!(
                r#"{{"op":"stream_push","session":"{session}","samples":[0,2,5]}}"#
            ))
            .unwrap();
            s.execute_stream(&push).unwrap();
            let win = parse_request(&format!(
                r#"{{"op":"stream_window","session":"{session}"}}"#
            ))
            .unwrap();
            replies.push((session, s.execute_stream(&win).unwrap()));
        }
        assert_eq!(replies[0], replies[1]);
    }

    #[test]
    fn stats_json_has_one_row_per_shard() {
        let s = SigService::with_shards(None, 3);
        let open = parse_request(
            r#"{"op":"stream_open","dim":1,"depth":1,"window":2}"#,
        )
        .unwrap();
        s.execute_stream(&open).unwrap();
        let j = s.stats_json();
        assert_eq!(j.get("shards").as_usize(), Some(3));
        assert_eq!(j.get("live_sessions").as_usize(), Some(1));
        assert_eq!(j.get("per_shard").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn signature_cache_serves_repeats_and_translates() {
        let mut service = SigService::new(None);
        service.sig_cache_cap = 8;
        let s = service;
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out1, _, b1) = s.execute(&req).unwrap();
        assert_eq!(b1, "native");
        let (out2, shape2, b2) = s.execute(&req).unwrap();
        assert_eq!(b2, "cache");
        assert_eq!(out1, out2);
        assert_eq!(shape2, vec![out1.len()]);
        // A translated path has identical increments, hence the same
        // signature and the same cache entry.
        let shifted = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[5,7,6,7,6,8]}"#,
        )
        .unwrap();
        let (out3, _, b3) = s.execute(&shifted).unwrap();
        assert_eq!(b3, "cache");
        assert_eq!(out1, out3);
        let st = s.cache_stats();
        assert_eq!((st.hits, st.misses, st.evictions), (2, 1, 0));
        // A different depth misses.
        let other = parse_request(
            r#"{"op":"signature","dim":2,"depth":3,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (_, _, b4) = s.execute(&other).unwrap();
        assert_eq!(b4, "native");
    }

    #[test]
    fn cache_disabled_by_default_stays_silent() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (_, _, b1) = s.execute(&req).unwrap();
        let (_, _, b2) = s.execute(&req).unwrap();
        assert_eq!((b1, b2), ("native", "native"));
        assert_eq!(s.cache_stats(), CacheStats::default());
    }

    #[test]
    fn stats_json_carries_journal_lag_and_cache() {
        let s = SigService::with_shards(None, 2);
        let j = s.stats_json();
        let rows = j.get("per_shard").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("journal_lag").as_usize(), Some(0));
        assert_eq!(j.get("sig_cache").get("hits").as_usize(), Some(0));
    }

    #[test]
    fn pjrt_preference_without_runtime_errors() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"backend":"pjrt","path":[0,0,1,1]}"#,
        )
        .unwrap();
        assert!(s.execute(&req).is_err());
    }
}
