//! The compute service: resolves a request configuration to an engine
//! (building and caching word tables on first use), and executes either
//! natively or through a matching PJRT artifact.
//!
//! Routing policy (`backend: "auto"`):
//! * a request is PJRT-eligible if the manifest has a `sig_fwd` entry
//!   with the same `(dim, depth, steps)` and truncated projection —
//!   artifacts have static shapes, so anything else falls back;
//! * otherwise the native word-basis engine handles it (any shape, any
//!   projection).

use super::protocol::{Backend, Request, RequestOp};
use crate::logsig::LogSigEngine;
use crate::sig::{
    signature_batch_into, windowed_signatures, SigEngine, StreamEngine, StreamScratch,
    StreamTable, Window,
};
use crate::runtime::Runtime;
use crate::util::pool::Pool;
use crate::words::{WordSpec, WordTable};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Reusable flatten/output buffers for the stacked-batch execution
/// path: the service keeps them pooled so steady-state batch requests
/// re-use one allocation pair instead of flattening into a fresh
/// vector per call.
#[derive(Debug, Default)]
struct BatchScratch {
    flat: Vec<f64>,
    out: Vec<f64>,
}

/// Cache key for an engine: alphabet size + projection description +
/// depth. (`WordSpec::describe()` is injective enough for our spec set
/// once combined with the explicit fields; custom word lists hash their
/// full contents.)
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ConfigKey {
    /// Path dimension / alphabet size.
    pub dim: usize,
    /// Truncation depth.
    pub depth: usize,
    /// Canonical identity string of the word spec.
    pub spec_id: String,
    /// Operation tag (`"sig"`, `"logsig"`, `"windowed"`, …).
    pub op: &'static str,
    /// Path points (M+1); part of the key so batches stack cleanly and
    /// PJRT artifacts (static shapes) can be matched.
    pub points: usize,
}

impl ConfigKey {
    /// The configuration key of a request.
    pub fn of(req: &Request) -> ConfigKey {
        ConfigKey {
            dim: req.dim,
            depth: req.depth,
            spec_id: spec_identity(&req.spec),
            op: match req.op {
                RequestOp::Signature => "sig",
                RequestOp::LogSig => "logsig",
                RequestOp::Windowed => "windowed",
                RequestOp::Metrics => "metrics",
                RequestOp::Ping => "ping",
                RequestOp::StreamOpen
                | RequestOp::StreamPush
                | RequestOp::StreamWindow
                | RequestOp::StreamClose => "stream",
            },
            points: if req.dim == 0 { 0 } else { req.path.len() / req.dim },
        }
    }
}

/// Full identity string of a word spec (cache-key safe).
fn spec_identity(spec: &WordSpec) -> String {
    match spec {
        WordSpec::Truncated { depth } => format!("trunc:{depth}"),
        WordSpec::Lyndon { depth } => format!("lyndon:{depth}"),
        WordSpec::Anisotropic { gamma, cutoff } => {
            format!("aniso:{cutoff}:{gamma:?}")
        }
        WordSpec::Dag { depth, edges } => format!("dag:{depth}:{edges:?}"),
        WordSpec::ConcatGenerated { depth, generators } => {
            format!("gen:{depth}:{generators:?}")
        }
        WordSpec::Custom { words } => format!("custom:{words:?}"),
    }
}

/// One live streaming session: a stateful [`StreamEngine`] behind its
/// own lock (so concurrent sessions never serialize on the table
/// lock), plus the idle-eviction timestamp (milliseconds since the
/// service epoch, atomically bumped outside the engine lock).
struct StreamSession {
    stream: Mutex<StreamEngine>,
    last_used_ms: AtomicU64,
}

/// What a stream op produced (the server maps this onto the wire
/// [`super::protocol::Response`] variants).
#[derive(Clone, Debug, PartialEq)]
pub enum StreamReply {
    /// `stream_open` succeeded.
    Opened {
        /// The session handle to pass to subsequent stream ops.
        session: String,
        /// Output dimension `|I|` of the session's projection.
        out_dim: usize,
    },
    /// `stream_push` appended samples.
    Pushed {
        /// Samples appended by this request.
        pushed: usize,
        /// Total samples the session has seen.
        seen: usize,
    },
    /// `stream_window` computed a signature.
    Values {
        /// Flat result values.
        result: Vec<f64>,
        /// Logical shape (`[|I|]`).
        shape: Vec<usize>,
    },
    /// `stream_close` freed the session.
    Closed,
}

/// Engine cache + optional PJRT runtime.
pub struct SigService {
    engines: RwLock<HashMap<String, Arc<SigEngine>>>,
    logsig_engines: Mutex<HashMap<(usize, usize), Arc<LogSigEngine>>>,
    batch_scratch: Pool<BatchScratch>,
    /// Factor-closed streaming tables, cached per `(dim, spec)` like
    /// [`SigService::engine`].
    stream_tables: RwLock<HashMap<String, Arc<StreamTable>>>,
    /// Live streaming sessions keyed by numeric id. The table lock is
    /// held only for O(1) lookups/inserts; each session carries its own
    /// engine lock, so concurrent sessions compute in parallel.
    sessions: Mutex<HashMap<u64, Arc<StreamSession>>>,
    next_session: AtomicU64,
    /// Epoch for the sessions' millisecond idle timestamps.
    epoch: Instant,
    /// Recycled stream workspaces: closing (or evicting) a session
    /// returns its buffers here so the next `stream_open` reuses them.
    stream_scratch: Pool<StreamScratch>,
    /// Idle eviction threshold: sessions untouched for longer than
    /// this are dropped on the next stream op (their buffers are
    /// recycled). Set before sharing the service across threads.
    pub session_ttl: Duration,
    /// Upper bound on concurrently open sessions: `stream_open` is
    /// rejected (after an eviction sweep) once the table is full, so a
    /// client loop cannot exhaust server memory faster than the TTL
    /// reclaims it. Set before sharing the service across threads.
    pub max_sessions: usize,
    /// Per-session reservation budget in `f64` slots: `stream_open`
    /// rejects configurations whose two-stack store would reserve more
    /// than this (`window · (state_len + d)`). The wire-level
    /// `MAX_STREAM_WINDOW` caps only the increment count; this bounds
    /// the actual memory, which scales with the word-table size.
    /// Default `1 << 24` (128 MiB per session); worst-case streaming
    /// footprint is `max_sessions · max_session_floats · 8` bytes.
    pub max_session_floats: usize,
    /// Millisecond timestamp of the last idle-eviction sweep (the
    /// sweep is throttled so hot stream ops stay O(1) on the table).
    last_sweep_ms: AtomicU64,
    /// PJRT artifact runtime, if one was configured at boot.
    pub runtime: Option<Arc<Runtime>>,
    /// Shared metrics registry (also read by the server).
    pub metrics: Arc<super::Metrics>,
}

impl SigService {
    /// Create a service, optionally wired to a PJRT runtime.
    pub fn new(runtime: Option<Arc<Runtime>>) -> SigService {
        SigService {
            engines: RwLock::new(HashMap::new()),
            logsig_engines: Mutex::new(HashMap::new()),
            batch_scratch: Pool::default(),
            stream_tables: RwLock::new(HashMap::new()),
            sessions: Mutex::new(HashMap::new()),
            next_session: AtomicU64::new(1),
            epoch: Instant::now(),
            stream_scratch: Pool::default(),
            session_ttl: Duration::from_secs(300),
            max_sessions: 1024,
            max_session_floats: 1 << 24,
            last_sweep_ms: AtomicU64::new(0),
            runtime,
            metrics: Arc::new(super::Metrics::new()),
        }
    }

    /// Get (or build) the native engine for a (dim, spec) pair.
    pub fn engine(&self, dim: usize, spec: &WordSpec) -> Arc<SigEngine> {
        let key = format!("{dim}:{}", spec_identity(spec));
        if let Some(e) = self.engines.read().unwrap().get(&key) {
            return e.clone();
        }
        let words = spec.words(dim);
        let engine = Arc::new(SigEngine::new(WordTable::build(dim, &words)));
        self.engines
            .write()
            .unwrap()
            .insert(key, engine.clone());
        engine
    }

    /// Get (or build) the log-signature engine for a (dim, depth) pair.
    pub fn logsig_engine(&self, dim: usize, depth: usize) -> Arc<LogSigEngine> {
        let mut cache = self.logsig_engines.lock().unwrap();
        cache
            .entry((dim, depth))
            .or_insert_with(|| Arc::new(LogSigEngine::new(dim, depth)))
            .clone()
    }

    /// Get (or build) the factor-closed streaming table for a
    /// (dim, spec) pair.
    pub fn stream_table(&self, dim: usize, spec: &WordSpec) -> Arc<StreamTable> {
        let key = format!("{dim}:{}", spec_identity(spec));
        if let Some(t) = self.stream_tables.read().unwrap().get(&key) {
            return t.clone();
        }
        let words = spec.words(dim);
        let table = Arc::new(StreamTable::new(dim, &words));
        self.stream_tables.write().unwrap().insert(key, table.clone());
        table
    }

    /// Live session count (after eviction sweeps).
    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// Drop sessions idle for longer than [`SigService::session_ttl`],
    /// recycling their workspaces. Runs at the start of every stream
    /// op and periodically from the server's background sweeper (so
    /// memory is reclaimed even when stream traffic stops entirely);
    /// internally throttled, so callers may invoke it freely.
    pub fn evict_idle(&self) {
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let ttl_ms = self.session_ttl.as_millis() as u64;
        // Throttle: the sweep scans the whole table, so run it at most
        // every ttl/10 ms; between sweeps stream ops touch the table
        // lock only for their O(1) lookup. A CAS elects one sweeper.
        let interval = ttl_ms / 10;
        let last = self.last_sweep_ms.load(Relaxed);
        if now_ms.saturating_sub(last) < interval {
            return;
        }
        if self
            .last_sweep_ms
            .compare_exchange(last, now_ms, Relaxed, Relaxed)
            .is_err()
        {
            return; // another thread is sweeping
        }
        let mut evicted = Vec::new();
        {
            let mut sessions = self.sessions.lock().unwrap();
            let expired: Vec<u64> = sessions
                .iter()
                .filter(|(_, s)| now_ms.saturating_sub(s.last_used_ms.load(Relaxed)) > ttl_ms)
                .map(|(&id, _)| id)
                .collect();
            for id in expired {
                if let Some(s) = sessions.remove(&id) {
                    evicted.push(s);
                }
            }
        }
        if !evicted.is_empty() {
            self.metrics.sessions_evicted.fetch_add(evicted.len() as u64, Relaxed);
            self.recycle_sessions(evicted);
        }
    }

    /// Return removed sessions' buffers to the scratch pool. A session
    /// with an op still in flight (its `Arc` has another holder) is
    /// simply dropped once that op finishes — recycling is an
    /// optimisation, not a correctness requirement.
    fn recycle_sessions(&self, removed: Vec<Arc<StreamSession>>) {
        let mut cache = self.stream_scratch.take_at_least(0);
        for sess in removed {
            if let Ok(sess) = Arc::try_unwrap(sess) {
                if let Ok(stream) = sess.stream.into_inner() {
                    cache.push(stream.into_scratch());
                }
            }
        }
        self.stream_scratch.put(cache);
    }

    /// Current time in milliseconds since the service epoch.
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Parse an `"s<N>"` session handle. Only the canonical form is
    /// accepted — the round-trip check rejects aliases like `"s+7"` or
    /// `"s007"` that `u64::from_str` would otherwise tolerate (a
    /// malformed handle must error, never address another session).
    fn parse_session_id(handle: &str) -> Result<u64, String> {
        handle
            .strip_prefix('s')
            .and_then(|n| n.parse::<u64>().ok())
            .filter(|id| format!("s{id}") == handle)
            .ok_or_else(|| format!("malformed session handle '{handle}'"))
    }

    /// Execute one stateful stream op against the session table.
    /// Stream ops bypass the batcher: they are order-sensitive per
    /// session (a connection's requests are handled sequentially, so a
    /// client observes its own pushes).
    pub fn execute_stream(&self, req: &Request) -> Result<StreamReply, String> {
        self.evict_idle();
        match req.op {
            RequestOp::StreamOpen => {
                // Cheap pre-check before any table/engine work; racing
                // opens are caught again under the insert lock below.
                if self.session_count() >= self.max_sessions {
                    return Err(format!(
                        "session table full ({} live sessions); close or let idle \
                         sessions expire (ttl {:?})",
                        self.max_sessions, self.session_ttl
                    ));
                }
                let table = self.stream_table(req.dim, &req.spec);
                // Bound the actual reservation, not just the window
                // count: the two-stack store scales with the table.
                let need = req
                    .window_len
                    .saturating_mul(table.state_len() + table.dim());
                if need > self.max_session_floats {
                    return Err(format!(
                        "session would reserve {need} floats (window {} × state \
                         {}), exceeding the per-session budget of {} floats",
                        req.window_len,
                        table.state_len(),
                        self.max_session_floats
                    ));
                }
                let scratch = {
                    let mut cache = self.stream_scratch.take_at_least(0);
                    let s = cache.pop().unwrap_or_default();
                    self.stream_scratch.put(cache);
                    s
                };
                let stream = StreamEngine::with_scratch(table, req.window_len, scratch);
                let out_dim = stream.out_dim();
                let id = self.next_session.fetch_add(1, Relaxed);
                {
                    // Cap check and insert under one lock so racing
                    // opens cannot overshoot `max_sessions`.
                    let mut sessions = self.sessions.lock().unwrap();
                    if sessions.len() >= self.max_sessions {
                        return Err(format!(
                            "session table full ({} live sessions); close or let \
                             idle sessions expire (ttl {:?})",
                            self.max_sessions, self.session_ttl
                        ));
                    }
                    sessions.insert(
                        id,
                        Arc::new(StreamSession {
                            stream: Mutex::new(stream),
                            last_used_ms: AtomicU64::new(self.now_ms()),
                        }),
                    );
                }
                self.metrics.sessions_opened.fetch_add(1, Relaxed);
                Ok(StreamReply::Opened {
                    session: format!("s{id}"),
                    out_dim,
                })
            }
            RequestOp::StreamPush => self.with_session(&req.session, |stream| {
                let d = stream.dim();
                if req.samples.len() % d != 0 {
                    return Err(format!(
                        "samples length {} not divisible by session dim {d}",
                        req.samples.len()
                    ));
                }
                for sample in req.samples.chunks_exact(d) {
                    stream.push(sample);
                }
                self.metrics
                    .stream_pushes
                    .fetch_add((req.samples.len() / d) as u64, Relaxed);
                Ok(StreamReply::Pushed {
                    pushed: req.samples.len() / d,
                    seen: stream.samples_seen(),
                })
            }),
            RequestOp::StreamWindow => self.with_session(&req.session, |stream| {
                let mut result = vec![0.0; stream.out_dim()];
                if req.full {
                    stream.signature_into(&mut result);
                } else {
                    stream.window_into(&mut result);
                }
                let shape = vec![result.len()];
                Ok(StreamReply::Values { result, shape })
            }),
            RequestOp::StreamClose => {
                let id = Self::parse_session_id(&req.session)?;
                let removed = self.sessions.lock().unwrap().remove(&id);
                match removed {
                    Some(sess) => {
                        self.recycle_sessions(vec![sess]);
                        self.metrics.sessions_closed.fetch_add(1, Relaxed);
                        Ok(StreamReply::Closed)
                    }
                    None => Err(format!(
                        "unknown session '{}' (already closed or evicted)",
                        req.session
                    )),
                }
            }
            _ => Err("not a stream op".into()),
        }
    }

    /// Run `f` on a live session, bumping its idle timestamp. The
    /// global table lock is held only for the lookup; the computation
    /// runs under the session's own lock, so concurrent sessions never
    /// serialize on each other.
    fn with_session<T>(
        &self,
        handle: &str,
        f: impl FnOnce(&mut StreamEngine) -> Result<T, String>,
    ) -> Result<T, String> {
        let id = Self::parse_session_id(handle)?;
        let sess = {
            // Bump the idle stamp while still holding the table lock:
            // the sweeper scans under the same lock, so lookup-and-touch
            // is atomic w.r.t. eviction — a just-looked-up session can
            // no longer be reaped before its timestamp refresh lands
            // (which would acknowledge a push on a detached engine).
            let sessions = self.sessions.lock().unwrap();
            match sessions.get(&id) {
                Some(sess) => {
                    sess.last_used_ms.store(self.now_ms(), Relaxed);
                    Arc::clone(sess)
                }
                None => {
                    return Err(format!(
                        "unknown session '{handle}' (already closed or evicted)"
                    ))
                }
            }
        };
        let mut stream = sess.stream.lock().unwrap();
        f(&mut stream)
    }

    /// Name of a PJRT artifact able to serve `key` (batch size `b`), if
    /// any: kind `sig_fwd`, matching dim/depth/points, batch ≥ b,
    /// truncated projection only.
    pub fn pjrt_artifact_for(&self, key: &ConfigKey, b: usize) -> Option<String> {
        let rt = self.runtime.as_ref()?;
        if !rt.backend_available() {
            // Metadata-only runtime: routing to an artifact would burn a
            // padded input buffer per request just to hit the "no
            // backend" error and fall back.
            return None;
        }
        if key.op != "sig" || !key.spec_id.starts_with("trunc:") {
            return None;
        }
        rt.manifest
            .by_kind("sig_fwd")
            .into_iter()
            .filter(|e| {
                e.meta.get("dim").as_usize() == Some(key.dim)
                    && e.meta.get("depth").as_usize() == Some(key.depth)
                    && e.meta.get("points").as_usize() == Some(key.points)
                    && e.meta.get("batch").as_usize().unwrap_or(0) >= b
            })
            .min_by_key(|e| e.meta.get("batch").as_usize().unwrap_or(usize::MAX))
            .map(|e| e.name.clone())
    }

    /// Execute one request (no batching). Returns (flat result, shape,
    /// backend label).
    pub fn execute(&self, req: &Request) -> Result<(Vec<f64>, Vec<usize>, &'static str), String> {
        match req.op {
            RequestOp::Signature => {
                let key = ConfigKey::of(req);
                if req.backend != Backend::Native {
                    if let Some(name) = self.pjrt_artifact_for(&key, 1) {
                        if let Ok(out) = self.execute_pjrt_batch(&name, &[req.path.as_slice()]) {
                            let dim = out[0].len();
                            self.metrics
                                .pjrt_executions
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            return Ok((out.into_iter().next().unwrap(), vec![dim], "pjrt"));
                        }
                    }
                    if req.backend == Backend::Pjrt {
                        let reason = match &self.runtime {
                            None => "no PJRT runtime configured",
                            Some(rt) if !rt.backend_available() => {
                                "artifact manifest loaded but no PJRT execution \
                                 backend attached (see DESIGN.md)"
                            }
                            Some(_) => "no matching PJRT artifact for request shape",
                        };
                        return Err(format!("cannot serve backend=\"pjrt\": {reason}"));
                    }
                }
                let eng = self.engine(req.dim, &req.spec);
                // Route through the batch kernel with B = 1: identical
                // arithmetic for short paths (scalar fallback), and long
                // paths pick up the time-parallel scheduler — a single
                // wire request no longer serializes a worker on one
                // core (see `crate::sig::schedule`).
                let mut out = vec![0.0; eng.out_dim()];
                signature_batch_into(&eng, &req.path, 1, &mut out);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let n = out.len();
                Ok((out, vec![n], "native"))
            }
            RequestOp::LogSig => {
                let eng = self.logsig_engine(req.dim, req.depth);
                let out = eng.logsig(&req.path);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let n = out.len();
                Ok((out, vec![n], "native"))
            }
            RequestOp::Windowed => {
                let eng = self.engine(req.dim, &req.spec);
                let wins: Vec<Window> = req
                    .windows
                    .iter()
                    .map(|&(l, r)| Window::new(l, r))
                    .collect();
                let out = windowed_signatures(&eng, &req.path, &wins);
                self.metrics
                    .native_executions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let odim = eng.out_dim();
                Ok((out, vec![wins.len(), odim], "native"))
            }
            RequestOp::Metrics | RequestOp::Ping => {
                Err("control ops are handled by the server, not the service".into())
            }
            RequestOp::StreamOpen
            | RequestOp::StreamPush
            | RequestOp::StreamWindow
            | RequestOp::StreamClose => {
                Err("stream ops are stateful; use SigService::execute_stream".into())
            }
        }
    }

    /// Execute a stacked batch of same-config signature requests
    /// natively (lane-major kernel once the batch spans a lane block).
    /// `paths` must all have equal length; paths are borrowed, not
    /// cloned, and the stacking flatten plus the batch output go
    /// through pooled scratch buffers — in steady state the only
    /// allocations left are the per-request response rows the wire
    /// protocol needs.
    pub fn execute_native_batch(
        &self,
        dim: usize,
        spec: &WordSpec,
        paths: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        let eng = self.engine(dim, spec);
        let odim = eng.out_dim();
        let mut scratch = self.batch_scratch.take_at_least(1);
        let ws = &mut scratch[0];
        ws.flat.clear();
        for p in paths {
            ws.flat.extend_from_slice(p);
        }
        ws.out.clear();
        ws.out.resize(paths.len() * odim, 0.0);
        signature_batch_into(&eng, &ws.flat, paths.len(), &mut ws.out);
        self.metrics
            .native_executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let rows = ws.out.chunks(odim).map(|c| c.to_vec()).collect();
        self.batch_scratch.put(scratch);
        rows
    }

    /// Execute a stacked batch through a PJRT artifact, padding the
    /// batch axis up to the artifact's static batch size.
    pub fn execute_pjrt_batch(
        &self,
        artifact: &str,
        paths: &[&[f64]],
    ) -> Result<Vec<Vec<f64>>, String> {
        let rt = self.runtime.as_ref().ok_or("no runtime configured")?;
        let entry = rt
            .manifest
            .find(artifact)
            .ok_or_else(|| format!("artifact '{artifact}' vanished"))?;
        let art_batch = entry.meta.get("batch").as_usize().unwrap_or(paths.len());
        let per_path = entry.inputs[0].numel() / art_batch;
        if paths.len() > art_batch {
            return Err(format!(
                "batch {} exceeds artifact batch {art_batch}",
                paths.len()
            ));
        }
        let mut input = vec![0f32; entry.inputs[0].numel()];
        for (b, p) in paths.iter().enumerate() {
            if p.len() != per_path {
                return Err(format!(
                    "path length {} does not match artifact slot {per_path}",
                    p.len()
                ));
            }
            for (k, &v) in p.iter().enumerate() {
                input[b * per_path + k] = v as f32;
            }
        }
        let outs = rt
            .run_f32(artifact, &[&input])
            .map_err(|e| format!("pjrt execution failed: {e}"))?;
        let flat = &outs[0];
        let odim = entry.outputs[0].numel() / art_batch;
        Ok(paths
            .iter()
            .enumerate()
            .map(|(b, _)| flat[b * odim..(b + 1) * odim].iter().map(|&x| x as f64).collect())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse_request;

    fn svc() -> SigService {
        SigService::new(None)
    }

    #[test]
    fn engine_cache_reuses() {
        let s = svc();
        let a = s.engine(2, &WordSpec::Truncated { depth: 3 });
        let b = s.engine(2, &WordSpec::Truncated { depth: 3 });
        assert!(Arc::ptr_eq(&a, &b));
        let c = s.engine(2, &WordSpec::Truncated { depth: 4 });
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn execute_signature_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, shape, backend) = s.execute(&req).unwrap();
        assert_eq!(shape, vec![6]);
        assert_eq!(backend, "native");
        // Level 1 = total displacement (1,1).
        assert!((out[0] - 1.0).abs() < 1e-12);
        assert!((out[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn execute_windowed_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"windowed","dim":1,"depth":2,"windows":[[0,1],[0,2]],
                "path":[0,1,3]}"#,
        )
        .unwrap();
        let (out, shape, _) = s.execute(&req).unwrap();
        assert_eq!(shape, vec![2, 2]);
        assert!((out[0] - 1.0).abs() < 1e-12); // S_(0,1) level 1
        assert!((out[2] - 3.0).abs() < 1e-12); // S_(0,2) level 1
    }

    #[test]
    fn execute_logsig_request() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"logsig","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, shape, _) = s.execute(&req).unwrap();
        // Lyndon dim for d=2, N=2: 2 + 1 = 3.
        assert_eq!(shape, vec![3]);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn native_batch_matches_singles() {
        let s = svc();
        let spec = WordSpec::Truncated { depth: 3 };
        let mut rng = crate::util::rng::Rng::new(900);
        let paths: Vec<Vec<f64>> = (0..5).map(|_| rng.brownian_path(7, 2, 1.0)).collect();
        let path_refs: Vec<&[f64]> = paths.iter().map(|p| p.as_slice()).collect();
        let batch = s.execute_native_batch(2, &spec, &path_refs);
        let eng = s.engine(2, &spec);
        for (b, p) in paths.iter().enumerate() {
            let single = crate::sig::signature(&eng, p);
            assert_eq!(batch[b], single);
        }
    }

    #[test]
    fn stream_session_lifecycle() {
        let s = svc();
        let open = parse_request(
            r#"{"op":"stream_open","dim":1,"depth":2,"window":2}"#,
        )
        .unwrap();
        let reply = s.execute_stream(&open).unwrap();
        let (session, out_dim) = match reply {
            StreamReply::Opened { session, out_dim } => (session, out_dim),
            other => panic!("expected Opened, got {other:?}"),
        };
        assert_eq!(out_dim, 2); // (1), (1,1)
        assert_eq!(s.session_count(), 1);

        let push = parse_request(&format!(
            r#"{{"op":"stream_push","session":"{session}","samples":[0,1,3,6]}}"#
        ))
        .unwrap();
        match s.execute_stream(&push).unwrap() {
            StreamReply::Pushed { pushed, seen } => {
                assert_eq!((pushed, seen), (4, 4));
            }
            other => panic!("expected Pushed, got {other:?}"),
        }

        let query = parse_request(&format!(
            r#"{{"op":"stream_window","session":"{session}"}}"#
        ))
        .unwrap();
        match s.execute_stream(&query).unwrap() {
            StreamReply::Values { result, shape } => {
                // Window of last 2 increments: X_3 - X_1 = 5.
                assert_eq!(shape, vec![2]);
                assert!((result[0] - 5.0).abs() < 1e-12);
            }
            other => panic!("expected Values, got {other:?}"),
        }
        let full = parse_request(&format!(
            r#"{{"op":"stream_window","session":"{session}","mode":"full"}}"#
        ))
        .unwrap();
        match s.execute_stream(&full).unwrap() {
            StreamReply::Values { result, .. } => assert!((result[0] - 6.0).abs() < 1e-12),
            other => panic!("expected Values, got {other:?}"),
        }

        let close = parse_request(&format!(
            r#"{{"op":"stream_close","session":"{session}"}}"#
        ))
        .unwrap();
        assert_eq!(s.execute_stream(&close).unwrap(), StreamReply::Closed);
        assert_eq!(s.session_count(), 0);
        // Double close errors without panicking.
        let err = s.execute_stream(&close).unwrap_err();
        assert!(err.contains("unknown session"), "{err}");
        // Push to the closed session errors too.
        assert!(s.execute_stream(&push).is_err());
    }

    #[test]
    fn stream_sessions_evict_after_ttl() {
        let mut service = SigService::new(None);
        service.session_ttl = Duration::from_millis(40);
        let s = service;
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":2,"window":4}"#,
        )
        .unwrap();
        let session = match s.execute_stream(&open).unwrap() {
            StreamReply::Opened { session, .. } => session,
            other => panic!("{other:?}"),
        };
        std::thread::sleep(Duration::from_millis(150));
        let push = parse_request(&format!(
            r#"{{"op":"stream_push","session":"{session}","samples":[0,0]}}"#
        ))
        .unwrap();
        let err = s.execute_stream(&push).unwrap_err();
        assert!(err.contains("unknown session"), "{err}");
        assert_eq!(s.session_count(), 0);
        assert_eq!(
            s.metrics.sessions_evicted.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn stream_session_cap_rejects_excess_opens() {
        let mut service = SigService::new(None);
        service.max_sessions = 2;
        let s = service;
        let open = parse_request(
            r#"{"op":"stream_open","dim":1,"depth":1,"window":2}"#,
        )
        .unwrap();
        let first = match s.execute_stream(&open).unwrap() {
            StreamReply::Opened { session, .. } => session,
            other => panic!("{other:?}"),
        };
        s.execute_stream(&open).unwrap();
        let err = s.execute_stream(&open).unwrap_err();
        assert!(err.contains("session table full"), "{err}");
        // Closing one frees a slot.
        let close = parse_request(&format!(
            r#"{{"op":"stream_close","session":"{first}"}}"#
        ))
        .unwrap();
        s.execute_stream(&close).unwrap();
        assert!(s.execute_stream(&open).is_ok());
    }

    #[test]
    fn stream_open_respects_session_float_budget() {
        // The budget bounds window · (state_len + d), not the raw
        // window count — a deep table with a modest window must be
        // rejected before any reservation happens.
        let mut service = SigService::new(None);
        service.max_session_floats = 100;
        let s = service;
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":64}"#,
        )
        .unwrap();
        let err = s.execute_stream(&open).unwrap_err();
        assert!(err.contains("per-session budget"), "{err}");
        assert_eq!(s.session_count(), 0);
        // A small window over the same table fits (15 + 2 floats/slot).
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":2}"#,
        )
        .unwrap();
        assert!(s.execute_stream(&open).is_ok());
    }

    #[test]
    fn stream_push_dim_mismatch_rejected() {
        let s = svc();
        let open = parse_request(
            r#"{"op":"stream_open","dim":3,"depth":1,"window":2}"#,
        )
        .unwrap();
        let session = match s.execute_stream(&open).unwrap() {
            StreamReply::Opened { session, .. } => session,
            other => panic!("{other:?}"),
        };
        let push = parse_request(&format!(
            r#"{{"op":"stream_push","session":"{session}","samples":[1,2]}}"#
        ))
        .unwrap();
        let err = s.execute_stream(&push).unwrap_err();
        assert!(err.contains("not divisible"), "{err}");
        // Garbage and non-canonical handles are rejected before the
        // session lookup — "s+1"/"s01" must not alias session s1.
        for handle in ["nope", "s+1", "s01", "s 1", "s18446744073709551616"] {
            let bad = parse_request(&format!(
                r#"{{"op":"stream_push","session":"{handle}","samples":[1,2,3]}}"#
            ))
            .unwrap();
            assert!(
                s.execute_stream(&bad).unwrap_err().contains("malformed"),
                "handle {handle:?} must be rejected as malformed"
            );
        }
    }

    #[test]
    fn stream_open_reuses_pooled_scratch_and_caches_tables() {
        let s = svc();
        let open = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":8}"#,
        )
        .unwrap();
        let a = s.stream_table(2, &WordSpec::Truncated { depth: 3 });
        let b = s.stream_table(2, &WordSpec::Truncated { depth: 3 });
        assert!(Arc::ptr_eq(&a, &b));
        // Open → close → open round-trips the scratch pool.
        for _ in 0..2 {
            let session = match s.execute_stream(&open).unwrap() {
                StreamReply::Opened { session, .. } => session,
                other => panic!("{other:?}"),
            };
            let close = parse_request(&format!(
                r#"{{"op":"stream_close","session":"{session}"}}"#
            ))
            .unwrap();
            s.execute_stream(&close).unwrap();
        }
        assert_eq!(s.session_count(), 0);
        assert_eq!(
            s.metrics.sessions_opened.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }

    #[test]
    fn pjrt_preference_without_runtime_errors() {
        let s = svc();
        let req = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"backend":"pjrt","path":[0,0,1,1]}"#,
        )
        .unwrap();
        assert!(s.execute(&req).is_err());
    }
}
