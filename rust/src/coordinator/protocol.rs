//! Wire protocol of the feature server: one JSON object per line.
//!
//! Request:
//! ```json
//! {"op": "signature", "dim": 3, "depth": 4,
//!  "projection": {"type": "truncated"},
//!  "path": [/* (M+1)·dim floats, row-major */],
//!  "id": "client-chosen", "backend": "auto"}
//! ```
//!
//! Projection variants (§7):
//! * `{"type": "truncated"}` — full `W_{≤N}`;
//! * `{"type": "anisotropic", "gamma": [...], "cutoff": r}`;
//! * `{"type": "dag", "edges": [[..], ..]}`;
//! * `{"type": "lyndon"}` — log-signature output basis;
//! * `{"type": "words", "words": [[0,2,1], ...]}` — explicit word list;
//! * `{"type": "sparse_leadlag", "base_dim": d}` — §8 generator set
//!   (alphabet must be 2·base_dim).
//!
//! Extra ops: `"logsig"`, `"windowed"` (+ `"windows": [[l, r], …]`),
//! `"gram"` (+ `"paths": [[…], …]` — a batch of equal-length paths;
//! returns the `B×B` signature-kernel Gram matrix), `"metrics"`,
//! `"ping"`.
//!
//! Stateful streaming sessions (amortized-O(1) sliding windows, see
//! `sig::stream`):
//! * `{"op": "stream_open", "dim": d, "depth": N, "projection": {…},
//!   "window": w}` → `{"ok": true, "body": {"session": "s1", …}}`;
//! * `{"op": "stream_push", "session": "s1", "samples": [/* k·d */]}`
//!   — appends `k` samples in order;
//! * `{"op": "stream_window", "session": "s1"}` → sliding-window
//!   signature (`"mode": "full"` returns the running `S_{0,t}`
//!   instead);
//! * `{"op": "stream_close", "session": "s1"}` — frees the session
//!   (sessions also expire after the server's idle TTL).
//!
//! Response: `{"id": …, "ok": true, "result": [...], "shape": [...],
//! "backend": "native"|"pjrt", "latency_us": ...}` or
//! `{"ok": false, "error": "..."}`.

use crate::util::json::Json;
use crate::words::{generate::sparse_leadlag_generators, Word, WordSpec};

/// Upper bound on a session's sliding-window length (increments). The
/// per-session two-stack store costs `O(window · state_len)` memory
/// reserved at `stream_open`, so the wire protocol rejects windows
/// beyond this before any allocation happens.
pub const MAX_STREAM_WINDOW: usize = 1 << 16;

/// Upper bound on a `gram` request's batch size. The response carries
/// `B²` floats, so the cap keeps the worst-case reply (8 MiB at
/// `B = 1024`) inside protocol v2's 16 MiB frame limit with room to
/// spare; it is validated before any engine work happens.
pub const MAX_GRAM_BATCH: usize = 1024;

/// Reject non-finite path coordinates at the protocol boundary. A NaN
/// poisons every signature coordinate it touches (and a NaN key would
/// also defeat the content-addressed cache, since NaN ≠ NaN), so both
/// protocols refuse the request up front — v1 here, v2 in
/// [`super::wire`] — with **byte-identical** error strings, which the
/// golden suite pins.
pub fn check_finite(field: &str, vals: &[f64]) -> Result<(), String> {
    if let Some(i) = vals.iter().position(|v| !v.is_finite()) {
        return Err(format!(
            "non-finite value (NaN or Inf) at index {i} of '{field}'"
        ));
    }
    Ok(())
}

/// Operation requested by the client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestOp {
    /// Projected signature of one path.
    Signature,
    /// Log-signature in the Lyndon basis.
    LogSig,
    /// Windowed signatures (`windows` holds the index pairs).
    Windowed,
    /// Signature-kernel Gram matrix of a batch of paths (`path` holds
    /// the flattened batch, `batch` the path count; result is `B×B`).
    Gram,
    /// Metrics snapshot (control op, handled by the server).
    Metrics,
    /// Health check (control op, handled by the server).
    Ping,
    /// Per-shard coordinator stats — sessions, mailbox depth, sheds,
    /// pushes (control op, handled by the server; protocol v2's
    /// flagship verb, also reachable from v1 as `{"op":"stats"}`).
    Stats,
    /// Open a stateful streaming session (`window` = sliding-window
    /// length in increments).
    StreamOpen,
    /// Push samples into an open session (`samples`, `session`).
    StreamPush,
    /// Query a session's sliding-window (or, with `mode: "full"`,
    /// running) signature.
    StreamWindow,
    /// Close a session and free its workspace.
    StreamClose,
}

impl RequestOp {
    /// Whether this op addresses a stateful streaming session (routed
    /// to the session table, never to the batcher).
    pub fn is_stream(self) -> bool {
        matches!(
            self,
            RequestOp::StreamOpen
                | RequestOp::StreamPush
                | RequestOp::StreamWindow
                | RequestOp::StreamClose
        )
    }
}

/// Backend preference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when an artifact matches the request shape, else native.
    Auto,
    /// Native word-basis engine only.
    Native,
    /// PJRT only — error if no artifact matches.
    Pjrt,
}

/// A parsed client request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen correlation id, echoed in the response.
    pub id: String,
    /// Requested operation.
    pub op: RequestOp,
    /// Path dimension / alphabet size `d`.
    pub dim: usize,
    /// Truncation depth `N`.
    pub depth: usize,
    /// Word-set projection (§7).
    pub spec: WordSpec,
    /// Backend routing preference.
    pub backend: Backend,
    /// Row-major `(M+1, dim)` path samples.
    pub path: Vec<f64>,
    /// For `Windowed`: index pairs.
    pub windows: Vec<(usize, usize)>,
    /// For stream ops: the session handle (empty for `stream_open`).
    pub session: String,
    /// For `StreamPush`: flat `(k, dim)` samples to append.
    pub samples: Vec<f64>,
    /// For `StreamOpen`: sliding-window length in increments.
    pub window_len: usize,
    /// For `StreamWindow`: query the running `S_{0,t}` instead of the
    /// sliding window (`"mode": "full"`).
    pub full: bool,
    /// For `Gram`: number of equal-length paths flattened into `path`
    /// (0 for every other op).
    pub batch: usize,
}

/// Parse a JSON-line request.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    let id = j.get("id").as_str().unwrap_or("").to_string();
    let op = match j.get("op").as_str().unwrap_or("signature") {
        "signature" => RequestOp::Signature,
        "logsig" => RequestOp::LogSig,
        "windowed" => RequestOp::Windowed,
        "gram" => RequestOp::Gram,
        "metrics" => RequestOp::Metrics,
        "ping" => RequestOp::Ping,
        "stats" => RequestOp::Stats,
        "stream_open" => RequestOp::StreamOpen,
        "stream_push" => RequestOp::StreamPush,
        "stream_window" => RequestOp::StreamWindow,
        "stream_close" => RequestOp::StreamClose,
        other => return Err(format!("unknown op '{other}'")),
    };
    let blank = |id: String, op: RequestOp| Request {
        id,
        op,
        dim: 0,
        depth: 0,
        spec: WordSpec::Truncated { depth: 0 },
        backend: Backend::Auto,
        path: Vec::new(),
        windows: Vec::new(),
        session: String::new(),
        samples: Vec::new(),
        window_len: 0,
        full: false,
        batch: 0,
    };
    if matches!(op, RequestOp::Metrics | RequestOp::Ping | RequestOp::Stats) {
        return Ok(blank(id, op));
    }
    if op.is_stream() && op != RequestOp::StreamOpen {
        // Session-addressed ops: the session carries the configuration,
        // so no dim/projection is parsed here.
        let session = j.get("session").as_str().unwrap_or("").to_string();
        if session.is_empty() {
            return Err("stream op needs a 'session' handle".into());
        }
        let mut req = blank(id, op);
        req.session = session;
        if op == RequestOp::StreamPush {
            req.samples = j.f64_vec("samples");
            if req.samples.is_empty() {
                return Err("stream_push needs a non-empty 'samples' array".into());
            }
            check_finite("samples", &req.samples)?;
        }
        if op == RequestOp::StreamWindow {
            req.full = match j.get("mode").as_str().unwrap_or("window") {
                "window" => false,
                "full" => true,
                other => return Err(format!("unknown stream_window mode '{other}'")),
            };
        }
        return Ok(req);
    }
    let dim = j
        .get("dim")
        .as_usize()
        .ok_or_else(|| "missing 'dim'".to_string())?;
    let depth = j.get("depth").as_usize().unwrap_or(2);
    if dim == 0 {
        return Err("dim must be ≥ 1".into());
    }
    let spec = parse_projection(j.get("projection"), depth, dim)?;
    let backend = match j.get("backend").as_str().unwrap_or("auto") {
        "auto" => Backend::Auto,
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt,
        other => return Err(format!("unknown backend '{other}'")),
    };
    if op == RequestOp::StreamOpen {
        let window_len = j.get("window").as_usize().ok_or_else(|| {
            "stream_open needs 'window' (sliding-window length in increments, ≥ 1)".to_string()
        })?;
        if window_len == 0 {
            return Err("'window' must be ≥ 1".into());
        }
        if window_len > MAX_STREAM_WINDOW {
            // The two-stack store reserves O(window · state_len) up
            // front; an unbounded window would let one request abort
            // the server on allocation failure.
            return Err(format!(
                "'window' {window_len} exceeds the server cap {MAX_STREAM_WINDOW}"
            ));
        }
        let mut req = blank(id, op);
        req.dim = dim;
        req.depth = depth;
        req.spec = spec;
        req.backend = backend;
        req.window_len = window_len;
        return Ok(req);
    }
    if op == RequestOp::Gram {
        let rows = j.get("paths").as_arr().unwrap_or(&[]);
        if rows.is_empty() {
            return Err("gram needs a non-empty 'paths' array of paths".into());
        }
        if rows.len() > MAX_GRAM_BATCH {
            return Err(format!(
                "gram batch {} exceeds the server cap {MAX_GRAM_BATCH}",
                rows.len()
            ));
        }
        let mut flat = Vec::new();
        let mut per_path = 0usize;
        for (k, row) in rows.iter().enumerate() {
            let vals = row.as_arr().unwrap_or(&[]);
            if k == 0 {
                per_path = vals.len();
            } else if vals.len() != per_path {
                return Err("gram paths must all have the same length".into());
            }
            for v in vals {
                flat.push(v.as_f64().ok_or("non-numeric value in gram path")?);
            }
        }
        if per_path == 0 || per_path % dim != 0 {
            return Err(format!(
                "each gram path must be a non-empty flat (M+1)·dim array (got {per_path} floats, dim {dim})"
            ));
        }
        check_finite("paths", &flat)?;
        let mut req = blank(id, op);
        req.dim = dim;
        req.depth = depth;
        req.spec = spec;
        req.backend = backend;
        req.batch = rows.len();
        req.path = flat;
        return Ok(req);
    }
    let path = j.f64_vec("path");
    if path.is_empty() || path.len() % dim != 0 {
        return Err(format!(
            "path must be a non-empty flat (M+1)·dim array (got {} floats, dim {})",
            path.len(),
            dim
        ));
    }
    check_finite("path", &path)?;
    let mut windows = Vec::new();
    if op == RequestOp::Windowed {
        for wj in j.get("windows").as_arr().unwrap_or(&[]) {
            let pair = wj.as_arr().unwrap_or(&[]);
            if pair.len() != 2 {
                return Err("each window must be [l, r]".into());
            }
            let (l, r) = (
                pair[0].as_usize().ok_or("bad window index")?,
                pair[1].as_usize().ok_or("bad window index")?,
            );
            if l >= r {
                return Err(format!("window [{l}, {r}] must satisfy l < r"));
            }
            windows.push((l, r));
        }
        if windows.is_empty() {
            return Err("windowed op needs a non-empty 'windows' list".into());
        }
        let m = path.len() / dim - 1;
        if let Some(&(_, rmax)) = windows.iter().max_by_key(|w| w.1) {
            if rmax > m {
                return Err(format!("window right edge {rmax} exceeds M={m}"));
            }
        }
    }
    let mut req = blank(id, op);
    req.dim = dim;
    req.depth = depth;
    req.spec = spec;
    req.backend = backend;
    req.path = path;
    req.windows = windows;
    Ok(req)
}

fn parse_projection(j: &Json, depth: usize, dim: usize) -> Result<WordSpec, String> {
    let ty = j.get("type").as_str().unwrap_or("truncated");
    match ty {
        "truncated" => Ok(WordSpec::Truncated { depth }),
        "lyndon" => Ok(WordSpec::Lyndon { depth }),
        "anisotropic" => {
            let gamma = j.f64_vec("gamma");
            if gamma.len() != dim {
                return Err(format!(
                    "anisotropic projection needs {dim} weights, got {}",
                    gamma.len()
                ));
            }
            if gamma.iter().any(|&g| g <= 0.0) {
                return Err("anisotropic weights must be positive".into());
            }
            let cutoff = j.get("cutoff").as_f64().unwrap_or(depth as f64);
            Ok(WordSpec::Anisotropic { gamma, cutoff })
        }
        "dag" => {
            let mut edges = Vec::new();
            for row in j.get("edges").as_arr().unwrap_or(&[]) {
                let r: Vec<u16> = row
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_usize().map(|u| u as u16))
                    .collect();
                if r.iter().any(|&l| l as usize >= dim) {
                    return Err("dag edge letter out of range".into());
                }
                edges.push(r);
            }
            if edges.len() != dim {
                return Err(format!("dag needs {dim} adjacency rows"));
            }
            Ok(WordSpec::Dag { depth, edges })
        }
        "words" => {
            let mut words = Vec::new();
            for row in j.get("words").as_arr().unwrap_or(&[]) {
                let w: Vec<u16> = row
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_usize().map(|u| u as u16))
                    .collect();
                if w.is_empty() {
                    return Err("empty word in projection".into());
                }
                if w.iter().any(|&l| l as usize >= dim) {
                    return Err("word letter out of range".into());
                }
                words.push(Word(w));
            }
            if words.is_empty() {
                return Err("words projection needs a non-empty list".into());
            }
            Ok(WordSpec::Custom { words })
        }
        "sparse_leadlag" => {
            let base = j
                .get("base_dim")
                .as_usize()
                .ok_or("sparse_leadlag needs base_dim")?;
            if 2 * base != dim {
                return Err(format!(
                    "sparse_leadlag: dim must be 2·base_dim (dim={dim}, base={base})"
                ));
            }
            Ok(WordSpec::ConcatGenerated {
                depth,
                generators: sparse_leadlag_generators(base),
            })
        }
        other => Err(format!("unknown projection type '{other}'")),
    }
}

/// A server response.
#[derive(Clone, Debug)]
pub enum Response {
    /// Successful compute result.
    Ok {
        /// Echoed request id.
        id: String,
        /// Flat result values.
        result: Vec<f64>,
        /// Logical result shape (e.g. `[K, |I|]` for windowed).
        shape: Vec<usize>,
        /// Which backend served the request (`"native"` / `"pjrt"`).
        backend: &'static str,
        /// Wall time spent computing, microseconds.
        latency_us: u64,
    },
    /// Successful control result with a free-form JSON body.
    Json {
        /// Echoed request id.
        id: String,
        /// Response payload.
        body: Json,
    },
    /// Failure.
    Err {
        /// Echoed request id (empty if the request didn't parse).
        id: String,
        /// Error description.
        error: String,
    },
    /// Load-shed: the target shard's mailbox was full, so the request
    /// was dropped before doing any work. Clients should retry after
    /// the indicated backoff.
    Shed {
        /// Echoed request id.
        id: String,
        /// Human-readable shed description.
        error: String,
        /// Suggested client backoff in milliseconds.
        retry_after_ms: u64,
    },
}

impl Response {
    /// Serialize as one JSON line (without trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Response::Ok {
                id,
                result,
                shape,
                backend,
                latency_us,
            } => Json::obj(vec![
                ("id", Json::str(id)),
                ("ok", Json::Bool(true)),
                ("result", Json::arr_f64(result)),
                ("shape", Json::arr_usize(shape)),
                ("backend", Json::str(backend)),
                ("latency_us", Json::Num(*latency_us as f64)),
            ])
            .to_string(),
            Response::Json { id, body } => Json::obj(vec![
                ("id", Json::str(id)),
                ("ok", Json::Bool(true)),
                ("body", body.clone()),
            ])
            .to_string(),
            Response::Err { id, error } => Json::obj(vec![
                ("id", Json::str(id)),
                ("ok", Json::Bool(false)),
                ("error", Json::str(error)),
            ])
            .to_string(),
            Response::Shed {
                id,
                error,
                retry_after_ms,
            } => Json::obj(vec![
                ("id", Json::str(id)),
                ("ok", Json::Bool(false)),
                ("error", Json::str(error)),
                ("retry_after_ms", Json::Num(*retry_after_ms as f64)),
                // Machine-readable marker so clients can branch on shed
                // vs hard error without string-matching `error` (parity
                // with protocol v2's dedicated `Shed` frame kind).
                ("status", Json::str("shed")),
            ])
            .to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_signature_request() {
        let r = parse_request(
            r#"{"op":"signature","dim":2,"depth":3,"path":[0,0,1,1,2,0]}"#,
        )
        .unwrap();
        assert_eq!(r.op, RequestOp::Signature);
        assert_eq!(r.dim, 2);
        assert_eq!(r.depth, 3);
        assert_eq!(r.spec, WordSpec::Truncated { depth: 3 });
        assert_eq!(r.path.len(), 6);
    }

    #[test]
    fn parse_projection_variants() {
        let r = parse_request(
            r#"{"op":"signature","dim":2,"depth":4,
                "projection":{"type":"anisotropic","gamma":[1.0,2.0],"cutoff":3.5},
                "path":[0,0,1,1]}"#,
        )
        .unwrap();
        match r.spec {
            WordSpec::Anisotropic { gamma, cutoff } => {
                assert_eq!(gamma, vec![1.0, 2.0]);
                assert_eq!(cutoff, 3.5);
            }
            other => panic!("wrong spec {other:?}"),
        }
        let r = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,
                "projection":{"type":"words","words":[[0,1],[1]]},
                "path":[0,0,1,1]}"#,
        )
        .unwrap();
        match r.spec {
            WordSpec::Custom { words } => assert_eq!(words.len(), 2),
            other => panic!("wrong spec {other:?}"),
        }
    }

    #[test]
    fn parse_windowed() {
        let r = parse_request(
            r#"{"op":"windowed","dim":1,"depth":2,"windows":[[0,2],[1,3]],
                "path":[0,1,2,3]}"#,
        )
        .unwrap();
        assert_eq!(r.windows, vec![(0, 2), (1, 3)]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"signature","dim":2,"path":[1,2,3]}"#).is_err()); // 3 % 2 != 0
        assert!(
            parse_request(r#"{"op":"windowed","dim":1,"depth":2,"windows":[[2,2]],"path":[0,1,2]}"#)
                .is_err()
        );
        assert!(
            parse_request(r#"{"op":"windowed","dim":1,"depth":2,"windows":[[0,9]],"path":[0,1,2]}"#)
                .is_err()
        );
        assert!(parse_request(
            r#"{"op":"signature","dim":2,"depth":2,
               "projection":{"type":"words","words":[[7]]},"path":[0,0,1,1]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_non_finite_coordinates() {
        // JSON has no NaN literal, but an overflowing exponent parses
        // to ±Inf — the boundary check must catch it for every
        // path-carrying op, with the error string the goldens pin.
        let err = parse_request(
            r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1e999,1]}"#,
        )
        .unwrap_err();
        assert_eq!(err, "non-finite value (NaN or Inf) at index 2 of 'path'");
        let err = parse_request(
            r#"{"op":"gram","dim":2,"depth":2,"paths":[[0,0,1,1],[0,-1e999,2,0]]}"#,
        )
        .unwrap_err();
        // Index 5 in the *flattened* batch — same indexing as v2.
        assert_eq!(err, "non-finite value (NaN or Inf) at index 5 of 'paths'");
        let err = parse_request(
            r#"{"op":"stream_push","session":"s1","samples":[0.5,1e999]}"#,
        )
        .unwrap_err();
        assert_eq!(err, "non-finite value (NaN or Inf) at index 1 of 'samples'");
        // The helper itself sees NaN too (reachable from v2 frames,
        // where IEEE bits come in raw).
        let err = check_finite("path", &[0.0, f64::NAN]).unwrap_err();
        assert_eq!(err, "non-finite value (NaN or Inf) at index 1 of 'path'");
        assert!(check_finite("path", &[0.0, 1.5, -2.0]).is_ok());
    }

    #[test]
    fn parse_gram() {
        let r = parse_request(
            r#"{"op":"gram","dim":2,"depth":3,"paths":[[0,0,1,1],[0,0,2,0]]}"#,
        )
        .unwrap();
        assert_eq!(r.op, RequestOp::Gram);
        assert_eq!((r.dim, r.depth, r.batch), (2, 3, 2));
        assert_eq!(r.path, vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 2.0, 0.0]);
        // Projections apply to gram like any compute op.
        let r = parse_request(
            r#"{"op":"gram","dim":2,"depth":3,
                "projection":{"type":"anisotropic","gamma":[1.0,2.0],"cutoff":3.0},
                "paths":[[0,0,1,1]]}"#,
        )
        .unwrap();
        assert!(matches!(r.spec, WordSpec::Anisotropic { .. }));
        assert_eq!(r.batch, 1);
    }

    #[test]
    fn rejects_malformed_gram() {
        // Missing / empty / ragged / non-divisible / oversized batches.
        assert!(parse_request(r#"{"op":"gram","dim":2,"depth":2}"#).is_err());
        assert!(parse_request(r#"{"op":"gram","dim":2,"depth":2,"paths":[]}"#).is_err());
        assert!(
            parse_request(r#"{"op":"gram","dim":2,"depth":2,"paths":[[0,0,1,1],[0,0]]}"#).is_err()
        );
        assert!(parse_request(r#"{"op":"gram","dim":2,"depth":2,"paths":[[0,0,1]]}"#).is_err());
        assert!(parse_request(r#"{"op":"gram","dim":2,"depth":2,"paths":[[],[]]}"#).is_err());
        let mut big = String::from(r#"{"op":"gram","dim":1,"depth":1,"paths":["#);
        for k in 0..=MAX_GRAM_BATCH {
            if k > 0 {
                big.push(',');
            }
            big.push_str("[0,1]");
        }
        big.push_str("]}");
        assert!(parse_request(&big).unwrap_err().contains("cap"));
    }

    #[test]
    fn parse_stream_verbs() {
        let r = parse_request(
            r#"{"op":"stream_open","dim":2,"depth":3,"window":16,
                "projection":{"type":"truncated"}}"#,
        )
        .unwrap();
        assert_eq!(r.op, RequestOp::StreamOpen);
        assert_eq!((r.dim, r.depth, r.window_len), (2, 3, 16));

        let r = parse_request(
            r#"{"op":"stream_push","session":"s7","samples":[0.5,1.5,2.5,3.5]}"#,
        )
        .unwrap();
        assert_eq!(r.op, RequestOp::StreamPush);
        assert_eq!(r.session, "s7");
        assert_eq!(r.samples.len(), 4);

        let r = parse_request(r#"{"op":"stream_window","session":"s7"}"#).unwrap();
        assert!(!r.full);
        let r = parse_request(r#"{"op":"stream_window","session":"s7","mode":"full"}"#).unwrap();
        assert!(r.full);

        let r = parse_request(r#"{"op":"stream_close","session":"s7"}"#).unwrap();
        assert_eq!(r.op, RequestOp::StreamClose);
        assert!(r.op.is_stream() && !RequestOp::Signature.is_stream());
    }

    #[test]
    fn rejects_malformed_stream_verbs() {
        // Missing/zero window, missing session, empty samples, bad mode.
        assert!(parse_request(r#"{"op":"stream_open","dim":2,"depth":2}"#).is_err());
        assert!(parse_request(r#"{"op":"stream_open","dim":2,"depth":2,"window":0}"#).is_err());
        // Windows beyond the cap are rejected before any allocation.
        let big = format!(
            r#"{{"op":"stream_open","dim":2,"depth":2,"window":{}}}"#,
            MAX_STREAM_WINDOW + 1
        );
        assert!(parse_request(&big).unwrap_err().contains("cap"));
        let at_cap = format!(
            r#"{{"op":"stream_open","dim":2,"depth":2,"window":{MAX_STREAM_WINDOW}}}"#
        );
        assert_eq!(parse_request(&at_cap).unwrap().window_len, MAX_STREAM_WINDOW);
        assert!(parse_request(r#"{"op":"stream_open","window":4}"#).is_err()); // no dim
        assert!(parse_request(r#"{"op":"stream_push","samples":[1.0]}"#).is_err());
        assert!(parse_request(r#"{"op":"stream_push","session":"s1"}"#).is_err());
        assert!(parse_request(r#"{"op":"stream_window","session":""}"#).is_err());
        assert!(
            parse_request(r#"{"op":"stream_window","session":"s1","mode":"sideways"}"#).is_err()
        );
        assert!(parse_request(r#"{"op":"stream_close"}"#).is_err());
    }

    #[test]
    fn parse_stats_and_shed_roundtrip() {
        let r = parse_request(r#"{"op":"stats","id":"m1"}"#).unwrap();
        assert_eq!(r.op, RequestOp::Stats);
        assert!(!r.op.is_stream());
        let shed = Response::Shed {
            id: "r9".into(),
            error: "overloaded; retry after 25 ms".into(),
            retry_after_ms: 25,
        };
        let j = Json::parse(&shed.to_line()).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("retry_after_ms").as_usize(), Some(25));
        assert_eq!(j.get("status").as_str(), Some("shed"));
        assert!(j.get("error").as_str().unwrap().contains("retry"));
    }

    #[test]
    fn response_roundtrips_as_json() {
        let resp = Response::Ok {
            id: "r1".into(),
            result: vec![1.0, 2.5],
            shape: vec![2],
            backend: "native",
            latency_us: 42,
        };
        let j = Json::parse(&resp.to_line()).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.f64_vec("result"), vec![1.0, 2.5]);
        let err = Response::Err {
            id: "r2".into(),
            error: "boom".into(),
        };
        let j = Json::parse(&err.to_line()).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("error").as_str(), Some("boom"));
    }
}
