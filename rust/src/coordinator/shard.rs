//! Actor-sharded session table: N shard workers, each exclusively
//! owning a slice of the streaming sessions.
//!
//! PR 4's coordinator kept one global session table — a mutex-guarded
//! `HashMap` plus per-session engine locks and a CAS-throttled TTL
//! sweeper. Correct, but every stream op still rendezvoused on the
//! table lock, and the sweeper scanned all sessions from whatever
//! thread got elected. This module moves the table into [`ShardSet`]:
//! sessions are routed by `splitmix64(id) % shards` to a worker thread
//! that owns its slice outright, so within a shard there are **no
//! locks at all** — no per-session mutex, no table mutex, and the TTL
//! sweep is a shard-local scan on the worker's own idle ticks.
//!
//! Flow control is explicit: each worker reads from a bounded
//! [`Mailbox`], and a full mailbox makes the producer **load-shed** —
//! the client gets a [`StreamError::Shed`] carrying `retry_after_ms`
//! instead of the acceptor thread blocking. Shutdown is a graceful
//! drain: closing the mailboxes lets queued requests finish (every
//! in-flight reply is delivered) before the workers exit and return
//! their sessions' scratch buffers to the shared pool.

//!
//! With a [`DurabilityConfig`] present, each worker additionally owns
//! an append-only journal + checkpoint file pair (see
//! [`crate::persist`]): session ops are journaled *before* their reply
//! is sent, checkpoints of the full engine state are written every
//! `checkpoint_every` ops, and [`ShardSet::new`] recovers every live
//! session from disk before the workers spawn. Without the config, no
//! persistence code runs at all.

use super::mailbox::{Mailbox, Recv, SendError};
use super::service::StreamReply;
use super::Metrics;
use crate::persist::{self, DurabilityConfig, DurabilityMode, JournalWriter};
use crate::sig::{StreamEngine, StreamScratch, StreamTable};
use crate::util::pool::Pool;
use crate::util::rng::splitmix64;
use crate::words::WordSpec;
use std::collections::HashMap;
use std::io;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Stream-op failure, split so the server can answer a shed with a
/// distinct `retry-after` frame instead of a generic error.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamError {
    /// The target shard's mailbox was full — the request was dropped
    /// *before* doing any work; the client should retry after the
    /// indicated backoff.
    Shed {
        /// Suggested client backoff in milliseconds.
        retry_after_ms: u64,
    },
    /// Any other failure (unknown session, malformed handle, budget…).
    Msg(String),
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Shed { retry_after_ms } => {
                write!(f, "overloaded; retry after {retry_after_ms} ms")
            }
            StreamError::Msg(m) => f.write_str(m),
        }
    }
}

impl From<String> for StreamError {
    fn from(m: String) -> StreamError {
        StreamError::Msg(m)
    }
}

impl From<&str> for StreamError {
    fn from(m: &str) -> StreamError {
        StreamError::Msg(m.to_string())
    }
}

/// Reply channel carried inside a [`ShardMsg`].
pub type ReplyTx = mpsc::Sender<Result<StreamReply, String>>;

/// A typed message to a shard worker. Session-addressed variants carry
/// the numeric id (already parsed and routed); `Open` carries the
/// fully-built engine so the worker only files it — table construction
/// and budget checks stay on the caller's thread.
pub enum ShardMsg {
    /// File a new session under `id` and acknowledge with `Opened`.
    Open {
        /// Pre-allocated global session id.
        id: u64,
        /// The session's engine, built by the service.
        stream: Box<StreamEngine>,
        /// The declarative word-set spec the engine was built from —
        /// journaled so recovery can rebuild the table.
        spec: WordSpec,
        /// Where to send the acknowledgement.
        reply: ReplyTx,
    },
    /// Append samples to session `id`.
    Push {
        /// Target session id.
        id: u64,
        /// Flat `(k, dim)` samples.
        samples: Vec<f64>,
        /// Where to send the acknowledgement.
        reply: ReplyTx,
    },
    /// Query session `id`'s sliding-window (or running) signature.
    Window {
        /// Target session id.
        id: u64,
        /// `true` → running `S_{0,t}` instead of the sliding window.
        full: bool,
        /// Where to send the values.
        reply: ReplyTx,
    },
    /// Close session `id`, recycling its workspace.
    Close {
        /// Target session id.
        id: u64,
        /// Where to send the acknowledgement.
        reply: ReplyTx,
    },
    /// Force an idle-eviction sweep now (bypasses the worker's own
    /// sweep throttle; sent by [`ShardSet::sweep_all`]).
    Sweep,
    /// Diagnostic verb (tests/benches only): park the worker for the
    /// given duration so its mailbox can be filled deterministically to
    /// exercise the load-shed path. Never produced from wire traffic.
    Stall(Duration),
}

/// Point-in-time counters for one shard (the `stats` wire verb).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardStat {
    /// Shard index.
    pub shard: usize,
    /// Sessions currently owned by this shard.
    pub sessions: u64,
    /// Messages queued in the shard's mailbox right now.
    pub mailbox_depth: u64,
    /// Requests load-shed because the mailbox was full.
    pub sheds: u64,
    /// Samples pushed into this shard's sessions.
    pub pushes: u64,
    /// Journal records appended since the last checkpoint (0 when
    /// durability is off — the shard never lags what it never writes).
    pub journal_lag: u64,
}

/// Lock-free per-shard counters, written by the worker (sessions,
/// pushes, journal_lag) and by producers (sheds).
#[derive(Debug, Default)]
struct ShardCounters {
    sessions: AtomicU64,
    sheds: AtomicU64,
    pushes: AtomicU64,
    journal_lag: AtomicU64,
}

struct Shard {
    mailbox: Mailbox<ShardMsg>,
    counters: Arc<ShardCounters>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Configuration captured when the shard set spins up.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Number of shard workers (≥ 1).
    pub shards: usize,
    /// Bounded mailbox capacity per shard; a full mailbox load-sheds.
    pub mailbox_capacity: usize,
    /// Idle TTL after which a worker evicts a session.
    pub session_ttl: Duration,
    /// Global cap on concurrently open sessions (admission-controlled
    /// across all shards, so the single-shard and sharded coordinators
    /// reject the same N+1'th open).
    pub max_sessions: usize,
    /// Backoff hint carried in [`StreamError::Shed`] replies.
    pub shed_retry_ms: u64,
    /// Crash-safety knobs; `None` (the default) disables persistence
    /// entirely — no files are touched and every code path is bitwise
    /// identical to the pre-durability coordinator.
    pub durability: Option<DurabilityConfig>,
}

impl Default for ShardConfig {
    fn default() -> ShardConfig {
        ShardConfig {
            shards: 1,
            mailbox_capacity: 256,
            session_ttl: Duration::from_secs(300),
            max_sessions: 1024,
            shed_retry_ms: 25,
            durability: None,
        }
    }
}

/// The sharded session table: owns the worker threads and routes
/// session ops to them. Dropping the set closes every mailbox, drains
/// the backlog, and joins the workers.
pub struct ShardSet {
    shards: Vec<Shard>,
    /// Global live-session count (admission control; workers decrement
    /// on close/evict).
    live: Arc<AtomicUsize>,
    /// Globally sequential session ids — identical handles regardless
    /// of shard count, which is what makes the shard ≡ single-table
    /// equivalence tests possible.
    next_session: AtomicU64,
    config: ShardConfig,
}

impl std::fmt::Debug for ShardSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ShardSet({} shards, {} live)",
            self.shards.len(),
            self.live.load(Relaxed)
        )
    }
}

/// Which shard owns session `id` among `n` shards. `splitmix64` gives
/// a full-avalanche mix, so sequential ids spread uniformly.
pub fn shard_of(id: u64, n: usize) -> usize {
    let mut x = id;
    (splitmix64(&mut x) % n as u64) as usize
}

impl ShardSet {
    /// Spin up `config.shards` workers sharing `metrics` and the
    /// scratch `pool`.
    ///
    /// With durability configured, recovery runs synchronously first:
    /// every session found in the journal directory is rebuilt
    /// (checkpoint load + tail replay), re-admitted in ascending-id
    /// order under the `max_sessions` / `max_session_floats` budgets,
    /// re-partitioned onto the current shard count, and re-persisted as
    /// a fresh checkpoint per shard before any worker starts serving.
    ///
    /// # Panics
    ///
    /// Panics if the journal directory cannot be created, scanned or
    /// rewritten — an unusable `--journal-dir` is an operator error the
    /// server must refuse to boot over, not silently run without.
    pub fn new(
        config: ShardConfig,
        metrics: Arc<Metrics>,
        pool: Arc<Pool<StreamScratch>>,
    ) -> ShardSet {
        let n = config.shards.max(1);
        let epoch = Instant::now();
        let mut by_shard: Vec<Vec<(u64, WordSpec, StreamEngine)>> =
            (0..n).map(|_| Vec::new()).collect();
        let mut durables: Vec<Option<Durable>> = (0..n).map(|_| None).collect();
        let mut admitted = 0usize;
        let mut max_id = 0u64;
        if let Some(dur) = &config.durability {
            std::fs::create_dir_all(&dur.dir).expect("create journal dir");
            let mut memo: HashMap<String, Arc<StreamTable>> = HashMap::new();
            let mut resolve = |dim: usize, spec: &WordSpec| {
                memo.entry(format!("{dim}:{spec:?}"))
                    .or_insert_with(|| Arc::new(StreamTable::new(dim, &spec.words(dim))))
                    .clone()
            };
            let rec = persist::recover_dir(&dur.dir, &mut resolve).expect("scan journal dir");
            metrics
                .journal_torn_tails
                .fetch_add(rec.stats.torn_tails, Relaxed);
            metrics.journal_corrupt_dropped.fetch_add(
                rec.stats.corrupt_checkpoints + rec.stats.tombstone_hits,
                Relaxed,
            );
            max_id = rec.max_id;
            let mut dropped = 0u64;
            for s in rec.sessions {
                // Re-admit under the same budgets a fresh open faces:
                // global session cap, per-session float budget.
                let need = s
                    .window
                    .saturating_mul(s.stream.table().state_len() + s.dim);
                if admitted >= config.max_sessions || need > dur.max_session_floats {
                    dropped += 1;
                    continue;
                }
                admitted += 1;
                by_shard[shard_of(s.id, n)].push((s.id, s.spec, s.stream));
            }
            metrics.sessions_recovered.fetch_add(admitted as u64, Relaxed);
            metrics.recovery_dropped.fetch_add(dropped, Relaxed);
            // Re-persist under the current topology: the old files may
            // describe a different shard count (or dropped sessions).
            // `repartition` stages the whole new generation and flips
            // to it with one atomic marker rename — a crash at any
            // instant leaves either the complete old generation or the
            // complete new one, never a gap (recover_dir above has
            // already resumed any rewrite a previous boot left
            // half-finished).
            {
                let staged: Vec<Vec<(u64, &WordSpec, &StreamEngine)>> = by_shard
                    .iter()
                    .map(|v| v.iter().map(|(id, spec, stream)| (*id, spec, stream)).collect())
                    .collect();
                persist::repartition(&dur.dir, &staged)
                    .expect("re-persist recovered sessions");
            }
            for (i, durable) in durables.iter_mut().enumerate() {
                let writer = JournalWriter::create(&persist::journal_path(&dur.dir, i), dur.fsync, 0)
                    .expect("create shard journal");
                *durable = Some(Durable {
                    writer,
                    dir: dur.dir.clone(),
                    shard: i,
                    checkpoint_every: dur.checkpoint_every.max(1),
                    since_ckpt: 0,
                    mode: dur.mode,
                });
            }
        }
        let live = Arc::new(AtomicUsize::new(admitted));
        let shards = (0..n)
            .map(|i| {
                let mailbox: Mailbox<ShardMsg> = Mailbox::new(config.mailbox_capacity);
                let counters = Arc::new(ShardCounters::default());
                let now_ms = epoch.elapsed().as_millis() as u64;
                let sessions: HashMap<u64, Slot> = by_shard[i]
                    .drain(..)
                    .map(|(id, spec, stream)| {
                        (
                            id,
                            Slot {
                                stream,
                                spec,
                                last_used_ms: now_ms,
                            },
                        )
                    })
                    .collect();
                counters.sessions.store(sessions.len() as u64, Relaxed);
                let worker = ShardWorker {
                    mailbox: mailbox.clone(),
                    counters: Arc::clone(&counters),
                    live: Arc::clone(&live),
                    metrics: Arc::clone(&metrics),
                    pool: Arc::clone(&pool),
                    ttl: config.session_ttl,
                    epoch,
                    sessions,
                    durable: durables[i].take(),
                };
                let handle = std::thread::Builder::new()
                    .name(format!("pathsig-shard-{i}"))
                    .spawn(move || worker.run())
                    .expect("spawn shard worker");
                Shard {
                    mailbox,
                    counters,
                    worker: Some(handle),
                }
            })
            .collect();
        ShardSet {
            shards,
            live,
            next_session: AtomicU64::new(max_id + 1),
            config,
        }
    }

    /// Number of shard workers.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Sessions currently live across all shards.
    pub fn live_sessions(&self) -> usize {
        self.live.load(Relaxed)
    }

    /// The configuration this set was built with.
    pub fn config(&self) -> &ShardConfig {
        &self.config
    }

    /// Admit and file a new session built from `stream` (described by
    /// `spec`, which the durable path journals so recovery can rebuild
    /// the table). Fails with the table-full error when `max_sessions`
    /// are live, or sheds when the target shard's mailbox is full.
    pub fn open(&self, stream: StreamEngine, spec: WordSpec) -> Result<StreamReply, StreamError> {
        // Reserve a slot first so racing opens can never overshoot the
        // global cap; release it on any subsequent failure.
        if self
            .live
            .fetch_update(Relaxed, Relaxed, |c| {
                (c < self.config.max_sessions).then(|| c + 1)
            })
            .is_err()
        {
            return Err(StreamError::Msg(format!(
                "session table full ({} live sessions); close or let idle \
                 sessions expire (ttl {:?})",
                self.config.max_sessions, self.config.session_ttl
            )));
        }
        let id = self.next_session.fetch_add(1, Relaxed);
        let (reply, rx) = mpsc::channel();
        let msg = ShardMsg::Open {
            id,
            stream: Box::new(stream),
            spec,
            reply,
        };
        if let Err(e) = self.send(id, msg) {
            self.live.fetch_sub(1, Relaxed);
            return Err(e);
        }
        Self::wait(rx)
    }

    /// Append `samples` to session `id`.
    pub fn push(&self, id: u64, samples: Vec<f64>) -> Result<StreamReply, StreamError> {
        let (reply, rx) = mpsc::channel();
        self.send(id, ShardMsg::Push { id, samples, reply })?;
        Self::wait(rx)
    }

    /// Query session `id`'s window (or, with `full`, running) signature.
    pub fn window(&self, id: u64, full: bool) -> Result<StreamReply, StreamError> {
        let (reply, rx) = mpsc::channel();
        self.send(id, ShardMsg::Window { id, full, reply })?;
        Self::wait(rx)
    }

    /// Close session `id`.
    pub fn close(&self, id: u64) -> Result<StreamReply, StreamError> {
        let (reply, rx) = mpsc::channel();
        self.send(id, ShardMsg::Close { id, reply })?;
        Self::wait(rx)
    }

    /// Ask every shard to run its idle-eviction sweep now. Best-effort:
    /// a full mailbox is skipped (that shard is busy and will sweep on
    /// its own ticks anyway).
    pub fn sweep_all(&self) {
        for s in &self.shards {
            let _ = s.mailbox.try_send(ShardMsg::Sweep);
        }
    }

    /// Park shard `shard` for `d` (diagnostic; see [`ShardMsg::Stall`]).
    pub fn stall_shard(&self, shard: usize, d: Duration) {
        let _ = self.shards[shard].mailbox.try_send(ShardMsg::Stall(d));
    }

    /// Point-in-time per-shard counters for the `stats` verb.
    pub fn stats(&self) -> Vec<ShardStat> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| ShardStat {
                shard: i,
                sessions: s.counters.sessions.load(Relaxed),
                mailbox_depth: s.mailbox.len() as u64,
                sheds: s.counters.sheds.load(Relaxed),
                pushes: s.counters.pushes.load(Relaxed),
                journal_lag: s.counters.journal_lag.load(Relaxed),
            })
            .collect()
    }

    fn send(&self, id: u64, msg: ShardMsg) -> Result<(), StreamError> {
        let shard = &self.shards[shard_of(id, self.shards.len())];
        match shard.mailbox.try_send(msg) {
            Ok(()) => Ok(()),
            Err(SendError::Full(_)) => {
                shard.counters.sheds.fetch_add(1, Relaxed);
                Err(StreamError::Shed {
                    retry_after_ms: self.config.shed_retry_ms,
                })
            }
            Err(SendError::Closed(_)) => {
                Err(StreamError::Msg("coordinator is shutting down".into()))
            }
        }
    }

    fn wait(rx: mpsc::Receiver<Result<StreamReply, String>>) -> Result<StreamReply, StreamError> {
        match rx.recv() {
            Ok(res) => res.map_err(StreamError::Msg),
            Err(_) => Err(StreamError::Msg(
                "shard worker exited before replying".into(),
            )),
        }
    }
}

impl Drop for ShardSet {
    fn drop(&mut self) {
        for s in &self.shards {
            s.mailbox.close();
        }
        for s in &mut self.shards {
            if let Some(h) = s.worker.take() {
                let _ = h.join();
            }
        }
    }
}

/// One session slot owned by a worker. No locks: the worker is the
/// only thread that ever touches the engine.
struct Slot {
    stream: StreamEngine,
    /// Declarative spec the engine was built from, kept so checkpoints
    /// can describe the session without reverse-engineering the table.
    spec: WordSpec,
    last_used_ms: u64,
}

/// A worker's durable half: the journal writer plus checkpoint cadence
/// bookkeeping. Absent entirely when durability is off.
struct Durable {
    writer: JournalWriter,
    dir: PathBuf,
    shard: usize,
    checkpoint_every: u64,
    since_ckpt: u64,
    /// Journal-failure policy: strict (reject the op) or degraded
    /// (ack from memory, flip the sticky health bit).
    mode: DurabilityMode,
}

struct ShardWorker {
    mailbox: Mailbox<ShardMsg>,
    counters: Arc<ShardCounters>,
    live: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
    pool: Arc<Pool<StreamScratch>>,
    ttl: Duration,
    epoch: Instant,
    sessions: HashMap<u64, Slot>,
    durable: Option<Durable>,
}

impl ShardWorker {
    fn run(mut self) {
        let ttl_ms = self.ttl.as_millis() as u64;
        // Sweep at most every ttl/10 ms (same cadence as the old global
        // CAS-throttled sweeper, now shard-local and contention-free);
        // idle ticks are clamped so a short test TTL still sweeps
        // promptly and a production TTL doesn't spin.
        let interval_ms = ttl_ms / 10;
        let tick = Duration::from_millis(interval_ms.clamp(5, 100));
        let mut last_sweep_ms = 0u64;
        loop {
            match self.mailbox.recv_timeout(tick) {
                Recv::Msg(msg) => {
                    let force = matches!(msg, ShardMsg::Sweep);
                    self.handle(msg);
                    let now = self.now_ms();
                    if force || now.saturating_sub(last_sweep_ms) >= interval_ms {
                        last_sweep_ms = now;
                        self.sweep(ttl_ms);
                    }
                    // Cadence checkpoints run only here, between
                    // messages: a checkpoint snapshots `sessions` and
                    // truncates the journal, so running one mid-handler
                    // (e.g. after Open journaled but before it inserted)
                    // would discard an acked record without capturing
                    // the session it described.
                    self.checkpoint_if_due();
                }
                Recv::Timeout => {
                    let now = self.now_ms();
                    if now.saturating_sub(last_sweep_ms) >= interval_ms {
                        last_sweep_ms = now;
                        self.sweep(ttl_ms);
                    }
                    self.checkpoint_if_due();
                }
                Recv::Closed => break,
            }
        }
        // Graceful exit: the mailbox has already drained (Closed is
        // only reported on an empty queue), so every queued request got
        // its reply above. A final checkpoint captures the surviving
        // sessions (so a clean restart replays nothing), then their
        // workspaces go back to the pool.
        self.write_checkpoint();
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            if let Some(slot) = self.sessions.remove(&id) {
                self.recycle(slot.stream);
                self.live.fetch_sub(1, Relaxed);
            }
        }
        self.counters.sessions.store(0, Relaxed);
    }

    fn handle(&mut self, msg: ShardMsg) {
        match msg {
            ShardMsg::Open {
                id,
                stream,
                spec,
                reply,
            } => {
                let out_dim = stream.out_dim();
                let now = self.now_ms();
                // Journal before filing *or* acknowledging: under
                // strict durability a failed OPEN record means the
                // session must not exist — release the admission slot
                // the caller reserved and recycle the engine.
                match self.journal(|w| w.append_open(id, stream.dim(), stream.window_len(), &spec))
                {
                    Err(e) => {
                        self.recycle(*stream);
                        self.live.fetch_sub(1, Relaxed);
                        let _ = reply.send(Err(e));
                    }
                    Ok(()) => {
                        self.sessions.insert(
                            id,
                            Slot {
                                stream: *stream,
                                spec,
                                last_used_ms: now,
                            },
                        );
                        self.counters.sessions.store(self.sessions.len() as u64, Relaxed);
                        self.metrics.sessions_opened.fetch_add(1, Relaxed);
                        let _ = reply.send(Ok(StreamReply::Opened {
                            session: format!("s{id}"),
                            out_dim,
                        }));
                    }
                }
            }
            ShardMsg::Push { id, samples, reply } => {
                let now = self.now_ms();
                // Validate → journal → apply, in that order. The
                // record is written before the engine advances, so a
                // strict-mode append failure rejects the op with
                // memory exactly matching disk (no rollback needed),
                // and the reply is only ever sent for replayable work.
                // (A crash between journal and reply replays an op the
                // client never saw acked — at-least-once is fine; the
                // invariant is acked ⇒ durable.)
                let res = match self.sessions.get(&id) {
                    None => Err(unknown_session(id)),
                    Some(slot) if samples.len() % slot.stream.dim() != 0 => Err(format!(
                        "samples length {} not divisible by session dim {}",
                        samples.len(),
                        slot.stream.dim()
                    )),
                    Some(_) => match self.journal(|w| w.append_push(id, &samples)) {
                        Err(e) => Err(e),
                        Ok(()) => {
                            let slot = self.sessions.get_mut(&id).expect("validated above");
                            slot.last_used_ms = now;
                            let d = slot.stream.dim();
                            for sample in samples.chunks_exact(d) {
                                slot.stream.push(sample);
                            }
                            let pushed = samples.len() / d;
                            self.counters.pushes.fetch_add(pushed as u64, Relaxed);
                            self.metrics.stream_pushes.fetch_add(pushed as u64, Relaxed);
                            Ok(StreamReply::Pushed {
                                pushed,
                                seen: slot.stream.samples_seen(),
                            })
                        }
                    },
                };
                let _ = reply.send(res);
            }
            ShardMsg::Window { id, full, reply } => {
                let now = self.now_ms();
                let res = match self.sessions.get_mut(&id) {
                    Some(slot) => {
                        slot.last_used_ms = now;
                        let mut result = vec![0.0; slot.stream.out_dim()];
                        if full {
                            slot.stream.signature_into(&mut result);
                        } else {
                            slot.stream.window_into(&mut result);
                        }
                        let shape = vec![result.len()];
                        Ok(StreamReply::Values { result, shape })
                    }
                    None => Err(unknown_session(id)),
                };
                let _ = reply.send(res);
            }
            ShardMsg::Close { id, reply } => {
                // Tombstone before removing: a close acked without a
                // durable CLOSE record would resurrect after a crash.
                // Strict mode keeps the session live on append failure
                // (the client can retry the close); degraded proceeds.
                let res = if !self.sessions.contains_key(&id) {
                    Err(unknown_session(id))
                } else {
                    match self.journal(|w| w.append_close(id)) {
                        Err(e) => Err(e),
                        Ok(()) => {
                            let slot = self.sessions.remove(&id).expect("checked above");
                            self.recycle(slot.stream);
                            self.live.fetch_sub(1, Relaxed);
                            self.counters.sessions.store(self.sessions.len() as u64, Relaxed);
                            self.metrics.sessions_closed.fetch_add(1, Relaxed);
                            Ok(StreamReply::Closed)
                        }
                    }
                };
                let _ = reply.send(res);
            }
            ShardMsg::Sweep => {} // sweep runs in the loop after handling
            ShardMsg::Stall(d) => std::thread::sleep(d),
        }
    }

    fn sweep(&mut self, ttl_ms: u64) {
        let now = self.now_ms();
        let expired: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| now.saturating_sub(s.last_used_ms) > ttl_ms)
            .map(|(&id, _)| id)
            .collect();
        if expired.is_empty() {
            return;
        }
        for id in expired {
            // Tombstone first: an eviction must survive a crash, or
            // the evicted session would resurrect from its OPEN/PUSH
            // history on replay. Under strict durability a failed
            // tombstone keeps the session live — it stays expired, so
            // the next sweep retries once the disk recovers.
            if self.journal(|w| w.append_evict(id)).is_err() {
                continue;
            }
            if let Some(slot) = self.sessions.remove(&id) {
                self.recycle(slot.stream);
                self.live.fetch_sub(1, Relaxed);
                self.metrics.sessions_evicted.fetch_add(1, Relaxed);
            }
        }
        self.counters.sessions.store(self.sessions.len() as u64, Relaxed);
    }

    fn recycle(&self, stream: StreamEngine) {
        let mut cache = self.pool.take_at_least(0);
        cache.push(stream.into_scratch());
        self.pool.put(cache);
    }

    /// Run one journal append (no-op → `Ok` when durability is off).
    ///
    /// On append failure the configured [`DurabilityMode`] decides:
    /// **strict** returns `Err` with a client-facing message — the
    /// caller must reject the op *without applying it*, so nothing is
    /// ever acked that disk cannot replay; **degraded** absorbs the
    /// failure (today's pre-ISSUE-10 behavior), but flips the sticky
    /// `degraded` health bit so v1 `stats` / the v2 `health` verb
    /// announce that acks are no longer crash-safe. Either way the
    /// failure is logged and counted in `journal_errors`.
    ///
    /// Deliberately does NOT checkpoint: the cadence check runs in
    /// [`ShardWorker::run`] once the current message handler has fully
    /// applied its op, so a checkpoint always snapshots a state that
    /// covers every journaled record it is about to truncate.
    fn journal<F>(&mut self, append: F) -> Result<(), String>
    where
        F: FnOnce(&mut JournalWriter) -> io::Result<usize>,
    {
        let d = match self.durable.as_mut() {
            Some(d) => d,
            None => return Ok(()),
        };
        match append(&mut d.writer) {
            Ok(bytes) => {
                d.since_ckpt += 1;
                self.counters.journal_lag.store(d.since_ckpt, Relaxed);
                self.metrics.journal_appends.fetch_add(1, Relaxed);
                self.metrics.journal_bytes.fetch_add(bytes as u64, Relaxed);
                Ok(())
            }
            Err(e) => {
                eprintln!("pathsig: journal append failed on shard {}: {e}", d.shard);
                self.metrics.journal_errors.fetch_add(1, Relaxed);
                match d.mode {
                    DurabilityMode::Strict => {
                        self.metrics.journal_strict_rejects.fetch_add(1, Relaxed);
                        Err(format!(
                            "journal append failed ({e}); op rejected under strict durability"
                        ))
                    }
                    DurabilityMode::Degraded => {
                        self.metrics.degraded.store(1, Relaxed);
                        Ok(())
                    }
                }
            }
        }
    }

    /// Write a cadence checkpoint if `checkpoint_every` appends have
    /// accumulated. Only called between messages (see [`Self::run`]).
    fn checkpoint_if_due(&mut self) {
        let due = self
            .durable
            .as_ref()
            .is_some_and(|d| d.since_ckpt >= d.checkpoint_every);
        if due {
            self.write_checkpoint();
        }
    }

    /// Snapshot every live session into the shard's checkpoint file
    /// (atomic tmp → rename), then truncate the journal it covers.
    /// No-op when durability is off; best-effort on IO failure.
    fn write_checkpoint(&mut self) {
        let d = match self.durable.as_mut() {
            Some(d) => d,
            None => return,
        };
        let sessions: Vec<(u64, &WordSpec, &StreamEngine)> = self
            .sessions
            .iter()
            .map(|(&id, slot)| (id, &slot.spec, &slot.stream))
            .collect();
        match persist::write_checkpoint(&d.dir, d.shard, d.writer.seq(), &sessions) {
            Ok(()) => {
                match d.writer.truncate() {
                    Ok(()) => {
                        d.since_ckpt = 0;
                        self.counters.journal_lag.store(0, Relaxed);
                    }
                    Err(e) => {
                        // Keep the counters honest: the checkpoint
                        // landed, but every record it covers is still
                        // physically in the journal. Leaving
                        // `since_ckpt`/`journal_lag` untouched keeps
                        // the lag visible to operators and makes the
                        // next cadence tick retry the truncate
                        // (replay is safe either way — records with
                        // seq ≤ watermark are skipped).
                        eprintln!(
                            "pathsig: journal truncate failed on shard {}: {e}",
                            d.shard
                        );
                        self.metrics.journal_errors.fetch_add(1, Relaxed);
                    }
                }
                self.metrics.checkpoints_written.fetch_add(1, Relaxed);
            }
            Err(e) => {
                eprintln!("pathsig: checkpoint failed on shard {}: {e}", d.shard);
                self.metrics.journal_errors.fetch_add(1, Relaxed);
            }
        }
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }
}

/// The exact error string PR 4's single-table coordinator used — kept
/// byte-identical so v1 clients matching on it keep working.
fn unknown_session(id: u64) -> String {
    format!("unknown session 's{id}' (already closed or evicted)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::StreamTable;
    use crate::words::WordSpec;

    fn engine(dim: usize, depth: usize, window: usize) -> StreamEngine {
        let words = WordSpec::Truncated { depth }.words(dim);
        let table = Arc::new(StreamTable::new(dim, &words));
        StreamEngine::with_scratch(table, window, StreamScratch::default())
    }

    fn open_on(
        s: &ShardSet,
        dim: usize,
        depth: usize,
        window: usize,
    ) -> Result<StreamReply, StreamError> {
        s.open(engine(dim, depth, window), WordSpec::Truncated { depth })
    }

    fn set(shards: usize) -> ShardSet {
        let cfg = ShardConfig {
            shards,
            ..ShardConfig::default()
        };
        ShardSet::new(cfg, Arc::new(Metrics::new()), Arc::new(Pool::default()))
    }

    #[test]
    fn lifecycle_roundtrip_across_shards() {
        for shards in [1, 4] {
            let s = set(shards);
            let opened = open_on(&s, 1, 2, 2).unwrap();
            let id = match opened {
                StreamReply::Opened { session, out_dim } => {
                    assert_eq!(out_dim, 2);
                    session.strip_prefix('s').unwrap().parse::<u64>().unwrap()
                }
                other => panic!("{other:?}"),
            };
            assert_eq!(s.live_sessions(), 1);
            match s.push(id, vec![0.0, 1.0, 3.0, 6.0]).unwrap() {
                StreamReply::Pushed { pushed, seen } => assert_eq!((pushed, seen), (4, 4)),
                other => panic!("{other:?}"),
            }
            match s.window(id, false).unwrap() {
                StreamReply::Values { result, shape } => {
                    assert_eq!(shape, vec![2]);
                    assert!((result[0] - 5.0).abs() < 1e-12);
                }
                other => panic!("{other:?}"),
            }
            assert_eq!(s.close(id).unwrap(), StreamReply::Closed);
            assert_eq!(s.live_sessions(), 0);
            let err = s.close(id).unwrap_err();
            assert!(err.to_string().contains("unknown session"), "{err}");
        }
    }

    #[test]
    fn admission_cap_is_global_across_shards() {
        let cfg = ShardConfig {
            shards: 4,
            max_sessions: 2,
            ..ShardConfig::default()
        };
        let s = ShardSet::new(cfg, Arc::new(Metrics::new()), Arc::new(Pool::default()));
        open_on(&s, 1, 1, 2).unwrap();
        open_on(&s, 1, 1, 2).unwrap();
        let err = open_on(&s, 1, 1, 2).unwrap_err();
        assert!(err.to_string().contains("session table full"), "{err}");
        assert_eq!(s.live_sessions(), 2);
    }

    #[test]
    fn full_mailbox_sheds_with_retry_hint() {
        let cfg = ShardConfig {
            shards: 1,
            mailbox_capacity: 2,
            shed_retry_ms: 7,
            ..ShardConfig::default()
        };
        let s = ShardSet::new(cfg, Arc::new(Metrics::new()), Arc::new(Pool::default()));
        let id = match open_on(&s, 1, 1, 2).unwrap() {
            StreamReply::Opened { session, .. } => {
                session.strip_prefix('s').unwrap().parse::<u64>().unwrap()
            }
            other => panic!("{other:?}"),
        };
        // Park the worker, then flood the 2-slot mailbox: the stall
        // occupies the worker, two pushes queue, the next one sheds.
        s.stall_shard(shard_of(id, 1), Duration::from_millis(300));
        std::thread::sleep(Duration::from_millis(30)); // worker picks up the stall
        let sender = {
            let mut shed = None;
            for _ in 0..4 {
                let (reply, _rx) = mpsc::channel();
                if let Err(e) = s.send(
                    id,
                    ShardMsg::Push {
                        id,
                        samples: vec![1.0],
                        reply,
                    },
                ) {
                    shed = Some(e);
                    break;
                }
            }
            shed
        };
        match sender {
            Some(StreamError::Shed { retry_after_ms }) => assert_eq!(retry_after_ms, 7),
            other => panic!("expected shed, got {other:?}"),
        }
        assert!(s.stats()[0].sheds >= 1);
    }

    #[test]
    fn ttl_sweep_is_shard_local() {
        let cfg = ShardConfig {
            shards: 2,
            session_ttl: Duration::from_millis(40),
            ..ShardConfig::default()
        };
        let metrics = Arc::new(Metrics::new());
        let s = ShardSet::new(cfg, Arc::clone(&metrics), Arc::new(Pool::default()));
        let id = match open_on(&s, 2, 2, 4).unwrap() {
            StreamReply::Opened { session, .. } => {
                session.strip_prefix('s').unwrap().parse::<u64>().unwrap()
            }
            other => panic!("{other:?}"),
        };
        std::thread::sleep(Duration::from_millis(200));
        let err = s.push(id, vec![0.0, 0.0]).unwrap_err();
        assert!(err.to_string().contains("unknown session"), "{err}");
        assert_eq!(s.live_sessions(), 0);
        assert_eq!(metrics.sessions_evicted.load(Relaxed), 1);
    }

    #[test]
    fn drop_drains_and_joins() {
        let pool = Arc::new(Pool::default());
        let cfg = ShardConfig {
            shards: 3,
            ..ShardConfig::default()
        };
        let s = ShardSet::new(cfg, Arc::new(Metrics::new()), Arc::clone(&pool));
        for _ in 0..6 {
            open_on(&s, 1, 2, 4).unwrap();
        }
        drop(s); // closes mailboxes, drains, joins, recycles scratch
        assert_eq!(pool.take_at_least(0).len(), 6);
    }

    #[test]
    fn ids_are_global_and_sequential() {
        let s = set(8);
        for expect in 1..=16u64 {
            match open_on(&s, 1, 1, 2).unwrap() {
                StreamReply::Opened { session, .. } => {
                    assert_eq!(session, format!("s{expect}"));
                }
                other => panic!("{other:?}"),
            }
        }
    }
}
