//! Dynamic batcher — groups concurrent same-configuration requests into
//! one engine invocation.
//!
//! The batch axis is the parallelism the paper's kernels are built
//! around; serving single requests one-by-one leaves it idle. Policy:
//! a request joins the pending queue of its [`ConfigKey`]; a queue is
//! flushed when it reaches `max_batch` or when its oldest request has
//! waited `max_wait`. Responses are scattered back in arrival order
//! through per-request channels.

use super::protocol::{Backend, Request, RequestOp};
use super::service::{ConfigKey, SigService};
use crate::sig::{plan, TimeMode};
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush a queue as soon as it holds this many requests.
    pub max_batch: usize,
    /// Flush a queue once its oldest request has waited this long.
    pub max_wait: Duration,
    /// Signature requests with at least this many path points skip the
    /// queue and execute immediately — provided the engine's scheduler
    /// ([`crate::sig::schedule`]) will actually serve them
    /// time-parallel, so the path saturates the engine alone. Queueing
    /// such a request would add `max_wait` of latency for nothing, and
    /// stacking several long paths into one batch would serialize a
    /// huge unit on a single flush. With the tree disabled
    /// (`PATHSIG_TIME_CHUNK=off`) requests queue normally.
    pub long_path_points: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            long_path_points: 2048,
        }
    }
}

/// One queued request + its response channel.
struct Pending {
    req: Request,
    enqueued: Instant,
    reply: Sender<Result<(Vec<f64>, Vec<usize>, &'static str), String>>,
}

#[derive(Default)]
struct QueueState {
    queues: HashMap<ConfigKey, Vec<Pending>>,
    shutdown: bool,
}

/// Dynamic batcher: a flusher thread drains per-config queues into the
/// service.
pub struct Batcher {
    state: Arc<(Mutex<QueueState>, Condvar)>,
    service: Arc<SigService>,
    /// The policy this batcher runs with.
    pub config: BatcherConfig,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Start a batcher (and its flusher thread) over a service.
    pub fn new(service: Arc<SigService>, config: BatcherConfig) -> Batcher {
        let state = Arc::new((Mutex::new(QueueState::default()), Condvar::new()));
        let flusher = {
            let state = Arc::clone(&state);
            let service = Arc::clone(&service);
            std::thread::spawn(move || flusher_loop(state, service, config))
        };
        Batcher {
            state,
            service,
            config,
            flusher: Some(flusher),
        }
    }

    /// Submit a request; blocks until its batch executes and returns the
    /// result. Batchable ops: plain signatures (same config key) below
    /// the long-path threshold. Other ops — and long-path signatures,
    /// which saturate the engine alone — execute immediately.
    pub fn submit(&self, req: Request) -> Result<(Vec<f64>, Vec<usize>, &'static str), String> {
        if req.op != RequestOp::Signature {
            return self.service.execute(&req);
        }
        let points = if req.dim == 0 { 0 } else { req.path.len() / req.dim };
        // Requests that may route to a PJRT artifact keep queueing —
        // artifacts batch natively, and probing the native engine here
        // would build and cache it for nothing.
        let native_only = req.backend == Backend::Native || self.service.runtime.is_none();
        if native_only && points >= self.config.long_path_points {
            // Bypass only when the engine will actually serve this
            // request time-parallel; with the tree unavailable
            // (PATHSIG_TIME_CHUNK=off, degenerate shapes) a B=1
            // execution would run single-lane, and queueing for batch
            // parallelism remains the right call.
            let eng = self.service.engine(req.dim, &req.spec);
            if matches!(
                plan(&eng, 1, points.saturating_sub(1)),
                TimeMode::TimeParallel { .. }
            ) {
                self.service
                    .metrics
                    .long_path_bypass
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return self.service.execute(&req);
            }
        }
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let key = ConfigKey::of(&req);
            let (lock, cv) = &*self.state;
            let mut st = lock.lock().unwrap();
            if st.shutdown {
                return Err("batcher shut down".into());
            }
            st.queues.entry(key).or_default().push(Pending {
                req,
                enqueued: Instant::now(),
                reply: tx,
            });
            cv.notify_one();
        }
        rx.recv().map_err(|_| "batcher dropped request".to_string())?
    }

    /// Current total queue depth (for tests / backpressure).
    pub fn queued(&self) -> usize {
        self.state.0.lock().unwrap().queues.values().map(|q| q.len()).sum()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.state;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
    }
}

fn flusher_loop(
    state: Arc<(Mutex<QueueState>, Condvar)>,
    service: Arc<SigService>,
    config: BatcherConfig,
) {
    let (lock, cv) = &*state;
    loop {
        // Collect ready batches under the lock, execute outside it.
        let mut ready: Vec<(ConfigKey, Vec<Pending>)> = Vec::new();
        {
            let mut st = lock.lock().unwrap();
            loop {
                if st.shutdown {
                    // Drain everything with an error.
                    for (_, q) in st.queues.drain() {
                        for p in q {
                            let _ = p.reply.send(Err("server shutting down".into()));
                        }
                    }
                    return;
                }
                let now = Instant::now();
                let mut next_deadline: Option<Duration> = None;
                let keys: Vec<ConfigKey> = st.queues.keys().cloned().collect();
                for key in keys {
                    let q = st.queues.get_mut(&key).unwrap();
                    if q.is_empty() {
                        continue;
                    }
                    let oldest = q[0].enqueued;
                    let expired = now.duration_since(oldest) >= config.max_wait;
                    if q.len() >= config.max_batch || expired {
                        let take = q.len().min(config.max_batch);
                        let batch: Vec<Pending> = q.drain(..take).collect();
                        ready.push((key.clone(), batch));
                    } else {
                        let remain = config.max_wait - now.duration_since(oldest);
                        next_deadline = Some(match next_deadline {
                            Some(d) => d.min(remain),
                            None => remain,
                        });
                    }
                }
                st.queues.retain(|_, q| !q.is_empty());
                if !ready.is_empty() {
                    break;
                }
                let wait = next_deadline.unwrap_or(Duration::from_millis(50));
                let (guard, _) = cv.wait_timeout(st, wait).unwrap();
                st = guard;
            }
        }
        for (_key, batch) in ready.drain(..) {
            execute_batch(&service, batch, &config);
        }
    }
}

fn execute_batch(service: &SigService, batch: Vec<Pending>, _config: &BatcherConfig) {
    let t0 = Instant::now();
    let dim = batch[0].req.dim;
    let spec = batch[0].req.spec.clone();
    let key = ConfigKey::of(&batch[0].req);
    // Borrow the queued paths — the lane-major batch kernel reads them
    // in place, so there is no reason to clone every request's payload.
    let paths: Vec<&[f64]> = batch.iter().map(|p| p.req.path.as_slice()).collect();
    // Route: PJRT artifact if one fits the whole stacked batch,
    // otherwise native.
    let result: Result<(Vec<Vec<f64>>, &'static str), String> =
        match service.pjrt_artifact_for(&key, paths.len()) {
            Some(name) => match service.execute_pjrt_batch(&name, &paths) {
                Ok(rows) => {
                    service
                        .metrics
                        .pjrt_executions
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    Ok((rows, "pjrt"))
                }
                Err(_) => Ok((service.execute_native_batch(dim, &spec, &paths), "native")),
            },
            None => Ok((service.execute_native_batch(dim, &spec, &paths), "native")),
        };
    drop(paths); // release the borrows before `batch` is consumed below
    let elapsed = t0.elapsed();
    service.metrics.record_batch(batch.len(), elapsed);
    match result {
        Ok((rows, backend)) => {
            for (p, row) in batch.into_iter().zip(rows) {
                let shape = vec![row.len()];
                let _ = p.reply.send(Ok((row, shape, backend)));
                let _ = p.enqueued; // latency recorded at server level
            }
        }
        Err(e) => {
            for p in batch {
                let _ = p.reply.send(Err(e.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse_request;

    fn make_req(dim: usize, path: &[f64]) -> Request {
        let path_json: Vec<String> = path.iter().map(|x| x.to_string()).collect();
        parse_request(&format!(
            r#"{{"op":"signature","dim":{dim},"depth":2,"path":[{}]}}"#,
            path_json.join(",")
        ))
        .unwrap()
    }

    #[test]
    fn single_request_flushes_on_timeout() {
        let svc = Arc::new(SigService::new(None));
        let b = Batcher::new(
            svc,
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(1),
                ..BatcherConfig::default()
            },
        );
        let (out, shape, backend) = b.submit(make_req(2, &[0.0, 0.0, 1.0, 1.0])).unwrap();
        assert_eq!(shape, vec![6]);
        assert_eq!(backend, "native");
        assert!((out[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_same_config_requests_batch_together() {
        let svc = Arc::new(SigService::new(None));
        let b = Arc::new(Batcher::new(
            Arc::clone(&svc),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
                ..BatcherConfig::default()
            },
        ));
        let mut handles = Vec::new();
        for k in 0..8 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                let scale = (k + 1) as f64;
                let req = make_req(2, &[0.0, 0.0, scale, 0.0, scale, scale]);
                b.submit(req).unwrap()
            }));
        }
        let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Results must be per-request correct (order-preserving scatter).
        for (k, (out, _, _)) in outs.iter().enumerate() {
            let scale = (k + 1) as f64;
            assert!(
                (out[0] - scale).abs() < 1e-9,
                "request {k} got wrong level-1 x: {}",
                out[0]
            );
            assert!((out[1] - scale).abs() < 1e-9);
        }
        // With 8 concurrent submissions and max_batch 8 they should land
        // in few batches (≥1 multi-request batch ⇒ mean > 1).
        assert!(svc.metrics.batches_total.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }

    #[test]
    fn different_configs_do_not_mix() {
        let svc = Arc::new(SigService::new(None));
        let b = Arc::new(Batcher::new(
            Arc::clone(&svc),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(5),
                ..BatcherConfig::default()
            },
        ));
        let b1 = Arc::clone(&b);
        let h1 = std::thread::spawn(move || b1.submit(make_req(2, &[0.0, 0.0, 1.0, 1.0])));
        let b2 = Arc::clone(&b);
        let h2 =
            std::thread::spawn(move || b2.submit(make_req(3, &[0.0, 0.0, 0.0, 1.0, 1.0, 1.0])));
        let r1 = h1.join().unwrap().unwrap();
        let r2 = h2.join().unwrap().unwrap();
        assert_eq!(r1.1, vec![6]); // d=2, N=2 → 6
        assert_eq!(r2.1, vec![12]); // d=3, N=2 → 12
    }

    #[test]
    fn long_paths_bypass_the_queue() {
        // A path over the threshold AND over the scheduler's
        // time-parallel gate must be served immediately (no max_wait
        // stall, no engine batch) and counted in the metric.
        let svc = Arc::new(SigService::new(None));
        let b = Batcher::new(
            Arc::clone(&svc),
            BatcherConfig {
                max_batch: 64,
                // Long enough that a queued request would visibly stall.
                max_wait: Duration::from_secs(5),
                long_path_points: 16,
            },
        );
        let m1 = 97; // ≥ threshold, and ≥ MIN_TIME_STEPS increments
        let path: Vec<f64> = (0..m1 * 2).map(|i| (i as f64) * 0.01).collect();
        // The bypass defers to the scheduler; under an ambient
        // PATHSIG_TIME_CHUNK that disables the tree (e.g. `off`) the
        // request would rightly queue instead — skip the latency
        // assertions there rather than inherit env flakiness.
        let eng = svc.engine(2, &crate::words::WordSpec::Truncated { depth: 2 });
        if !matches!(plan(&eng, 1, m1 - 1), TimeMode::TimeParallel { .. }) {
            return;
        }
        let t0 = Instant::now();
        let (out, shape, backend) = b.submit(make_req(2, &path)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(2), "long path was queued");
        assert_eq!(shape, vec![6]);
        assert_eq!(backend, "native");
        // Level 1 = total displacement.
        assert!((out[0] - (m1 - 1) as f64 * 0.02).abs() < 1e-9);
        assert_eq!(
            svc.metrics.long_path_bypass.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn non_signature_ops_bypass_batching() {
        let svc = Arc::new(SigService::new(None));
        let b = Batcher::new(svc, BatcherConfig::default());
        let req = parse_request(
            r#"{"op":"logsig","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#,
        )
        .unwrap();
        let (out, _, _) = b.submit(req).unwrap();
        assert_eq!(out.len(), 3);
    }
}
