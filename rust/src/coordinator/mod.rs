//! L3 coordinator — the serving layer around the pathsig engines.
//!
//! The paper ships pathsig as a PyTorch library; its §6 benchmarks imply
//! the deployment shape this module provides: a **signature feature
//! server** that accepts path-valued requests over TCP, routes them to
//! a compiled PJRT artifact (when one matches the request shape) or the
//! native Rust engine (any shape), and **dynamically batches**
//! concurrent requests for the same configuration — the batch axis
//! being exactly the parallelism the paper's CUDA kernels exploit
//! (§3.2, §5).
//!
//! Stateless compute ops are dynamically batched; **stateful streaming
//! sessions** (`stream_open` / `stream_push` / `stream_window` /
//! `stream_close`) live in a sharded actor core: N shard workers each
//! exclusively own a slice of the session table (hash of the session
//! id picks the shard), so session state needs no locks at all —
//! commands arrive through bounded per-shard [`mailbox`]es that shed
//! load with a retry hint instead of blocking the acceptor, and TTL
//! sweeping happens on each worker's own idle ticks.
//!
//! Two wire protocols share one port, disambiguated per message by the
//! first byte: v1 JSON-lines (a line starts with `{`) and v2
//! length-prefixed binary frames (first byte `0x02`, see [`wire`]).
//!
//! With `--journal-dir` set, the shard workers journal every session op
//! and periodically checkpoint engine state to disk (see
//! [`crate::persist`]), so a crash or restart recovers every live
//! session instead of losing them.
//!
//! * [`protocol`] — v1 wire types (requests, responses, projections).
//! * [`wire`]     — v2 binary frames + the `stats` verb + [`wire::WireClient`].
//! * [`service`]  — engine cache + request execution (native / PJRT).
//! * [`shard`]    — shard workers owning the streaming session table.
//! * [`mailbox`]  — bounded MPSC channel backing each shard.
//! * [`batcher`]  — dynamic batching with size/latency policy.
//! * [`server`]   — TCP front end speaking both protocols.
//! * [`metrics`]  — counters and latency histograms.

pub mod batcher;
pub mod mailbox;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod service;
pub mod shard;
pub mod wire;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use protocol::{parse_request, Request, RequestOp, Response};
pub use server::{serve, ServerConfig};
pub use service::{ConfigKey, SigService, StreamReply};
pub use shard::{ShardConfig, ShardSet, ShardStat, StreamError};
pub use wire::WireClient;

pub use crate::persist::{DurabilityConfig, DurabilityMode};
