//! L3 coordinator — the serving layer around the pathsig engines.
//!
//! The paper ships pathsig as a PyTorch library; its §6 benchmarks imply
//! the deployment shape this module provides: a **signature feature
//! server** that accepts path-valued requests over TCP (JSON-lines),
//! routes them to a compiled PJRT artifact (when one matches the request
//! shape) or the native Rust engine (any shape), and **dynamically
//! batches** concurrent requests for the same configuration — the
//! batch axis being exactly the parallelism the paper's CUDA kernels
//! exploit (§3.2, §5).
//!
//! Stateless compute ops are dynamically batched; **stateful streaming
//! sessions** (`stream_open` / `stream_push` / `stream_window` /
//! `stream_close`) hold a per-session [`crate::sig::StreamEngine`] in
//! the service's session table, giving amortized-O(1) sliding-window
//! serving with idle-TTL eviction and pooled per-session workspaces.
//!
//! * [`protocol`] — wire types (requests, responses, projections).
//! * [`service`]  — engine cache + request execution (native / PJRT)
//!   + the streaming session table.
//! * [`batcher`]  — dynamic batching with size/latency policy.
//! * [`server`]   — TCP JSON-lines front end.
//! * [`metrics`]  — counters and latency histograms.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use protocol::{parse_request, Request, RequestOp, Response};
pub use server::{serve, ServerConfig};
pub use service::{ConfigKey, SigService, StreamReply};
