//! Server metrics: lock-free counters + log-bucket latency histograms.

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Metrics registry shared across server threads.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests received (all ops).
    pub requests_total: AtomicU64,
    /// Requests answered with an error.
    pub requests_failed: AtomicU64,
    /// Engine batches executed.
    pub batches_total: AtomicU64,
    /// Requests served through batches (Σ batch sizes).
    pub batched_requests_total: AtomicU64,
    /// Batches executed through a PJRT artifact.
    pub pjrt_executions: AtomicU64,
    /// Batches / requests executed on the native engine.
    pub native_executions: AtomicU64,
    /// Streaming sessions opened (`stream_open`).
    pub sessions_opened: AtomicU64,
    /// Streaming sessions closed by the client (`stream_close`).
    pub sessions_closed: AtomicU64,
    /// Streaming sessions dropped by the idle-TTL sweep.
    pub sessions_evicted: AtomicU64,
    /// Samples pushed across all streaming sessions.
    pub stream_pushes: AtomicU64,
    /// Stream requests load-shed because a shard mailbox was full
    /// (the client got a `retry_after_ms` hint instead of blocking).
    pub requests_shed: AtomicU64,
    /// Signature requests that bypassed the batch queue because their
    /// path exceeded the batcher's long-path threshold (they saturate
    /// the engine alone via the time-parallel scheduler).
    pub long_path_bypass: AtomicU64,
    /// Journal records appended across all shards.
    pub journal_appends: AtomicU64,
    /// Journal bytes written across all shards.
    pub journal_bytes: AtomicU64,
    /// Shard checkpoints written (each truncates its journal).
    pub checkpoints_written: AtomicU64,
    /// Journal/checkpoint IO failures (append, truncate, checkpoint).
    /// Non-fatal: the coordinator keeps serving from memory.
    pub journal_errors: AtomicU64,
    /// Torn journal tails truncated during recovery (crash mid-write).
    pub journal_torn_tails: AtomicU64,
    /// Records/checkpoints dropped during recovery as corrupt or
    /// tombstoned (CRC failures, inadmissible specs, resurrections).
    pub journal_corrupt_dropped: AtomicU64,
    /// Sessions rebuilt from checkpoint + journal replay at boot.
    pub sessions_recovered: AtomicU64,
    /// Recovered sessions dropped at re-admission because they
    /// exceeded the session-count or per-session float budget.
    pub recovery_dropped: AtomicU64,
    /// Ops rejected (or evictions deferred) by strict durability
    /// because their journal record could not be made durable.
    pub journal_strict_rejects: AtomicU64,
    /// Sticky health bit (0/1): set when a journal append failed in
    /// degraded mode — acks are being served from memory without a
    /// durable record. Surfaced in v1 `stats` and the v2 `health`
    /// verb; never clears while the process lives.
    pub degraded: AtomicU64,
    /// Connections rejected at accept because `--max-conns` live
    /// connections already existed.
    pub conns_rejected: AtomicU64,
    /// Connections closed because a read/write hit the per-connection
    /// timeout or a frame overran its slow-frame budget.
    pub conn_timeouts: AtomicU64,
    /// Connections currently being served (gauge, not a counter).
    pub conns_active: AtomicU64,
    /// End-to-end per-request latency.
    pub request_latency: LatencyHistogram,
    /// Per-batch execution latency.
    pub batch_latency: LatencyHistogram,
}

impl Metrics {
    /// Fresh all-zero registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one finished request and its end-to-end latency.
    pub fn record_request(&self, d: std::time::Duration, ok: bool) {
        self.requests_total.fetch_add(1, Relaxed);
        if !ok {
            self.requests_failed.fetch_add(1, Relaxed);
        }
        self.request_latency.record(d);
    }

    /// Record one executed batch (its size and execution latency).
    pub fn record_batch(&self, size: usize, d: std::time::Duration) {
        self.batches_total.fetch_add(1, Relaxed);
        self.batched_requests_total.fetch_add(size as u64, Relaxed);
        self.batch_latency.record(d);
    }

    /// Mean requests per executed batch (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_total.load(Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests_total.load(Relaxed) as f64 / b as f64
        }
    }

    /// JSON snapshot (served for `{"op": "metrics"}`).
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "requests_total",
                Json::Num(self.requests_total.load(Relaxed) as f64),
            ),
            (
                "requests_failed",
                Json::Num(self.requests_failed.load(Relaxed) as f64),
            ),
            (
                "batches_total",
                Json::Num(self.batches_total.load(Relaxed) as f64),
            ),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            (
                "pjrt_executions",
                Json::Num(self.pjrt_executions.load(Relaxed) as f64),
            ),
            (
                "native_executions",
                Json::Num(self.native_executions.load(Relaxed) as f64),
            ),
            (
                "sessions_opened",
                Json::Num(self.sessions_opened.load(Relaxed) as f64),
            ),
            (
                "sessions_closed",
                Json::Num(self.sessions_closed.load(Relaxed) as f64),
            ),
            (
                "sessions_evicted",
                Json::Num(self.sessions_evicted.load(Relaxed) as f64),
            ),
            (
                "stream_pushes",
                Json::Num(self.stream_pushes.load(Relaxed) as f64),
            ),
            (
                "requests_shed",
                Json::Num(self.requests_shed.load(Relaxed) as f64),
            ),
            (
                "long_path_bypass",
                Json::Num(self.long_path_bypass.load(Relaxed) as f64),
            ),
            (
                "journal_appends",
                Json::Num(self.journal_appends.load(Relaxed) as f64),
            ),
            (
                "journal_bytes",
                Json::Num(self.journal_bytes.load(Relaxed) as f64),
            ),
            (
                "checkpoints_written",
                Json::Num(self.checkpoints_written.load(Relaxed) as f64),
            ),
            (
                "journal_errors",
                Json::Num(self.journal_errors.load(Relaxed) as f64),
            ),
            (
                "journal_torn_tails",
                Json::Num(self.journal_torn_tails.load(Relaxed) as f64),
            ),
            (
                "journal_corrupt_dropped",
                Json::Num(self.journal_corrupt_dropped.load(Relaxed) as f64),
            ),
            (
                "sessions_recovered",
                Json::Num(self.sessions_recovered.load(Relaxed) as f64),
            ),
            (
                "recovery_dropped",
                Json::Num(self.recovery_dropped.load(Relaxed) as f64),
            ),
            (
                "journal_strict_rejects",
                Json::Num(self.journal_strict_rejects.load(Relaxed) as f64),
            ),
            (
                "degraded",
                Json::Bool(self.degraded.load(Relaxed) != 0),
            ),
            (
                "conns_rejected",
                Json::Num(self.conns_rejected.load(Relaxed) as f64),
            ),
            (
                "conn_timeouts",
                Json::Num(self.conn_timeouts.load(Relaxed) as f64),
            ),
            (
                "conns_active",
                Json::Num(self.conns_active.load(Relaxed) as f64),
            ),
            (
                "request_latency_p50_us",
                Json::Num(self.request_latency.quantile_ns(0.5) as f64 / 1e3),
            ),
            (
                "request_latency_p99_us",
                Json::Num(self.request_latency.quantile_ns(0.99) as f64 / 1e3),
            ),
            (
                "request_latency_mean_us",
                Json::Num(self.request_latency.mean_ns() / 1e3),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(Duration::from_micros(100), true);
        m.record_request(Duration::from_micros(200), false);
        m.record_batch(8, Duration::from_micros(500));
        m.record_batch(4, Duration::from_micros(500));
        assert_eq!(m.requests_total.load(Relaxed), 2);
        assert_eq!(m.requests_failed.load(Relaxed), 1);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests_total").as_usize(), Some(2));
        assert!(snap.get("request_latency_p50_us").as_f64().unwrap() > 0.0);
    }
}
