//! Bounded actor mailboxes for the sharded coordinator.
//!
//! A [`Mailbox`] is a fixed-capacity MPSC queue: any number of producer
//! handles (the connection threads) feed one logical consumer (the
//! shard worker). Sends never block — a full mailbox is reported back
//! to the producer as [`SendError::Full`] so the server can load-shed
//! with a `retry-after` error frame instead of stalling the acceptor.
//! Closing the mailbox flips it into **drain mode**: queued messages
//! are still delivered (so every in-flight request gets its reply), new
//! sends are rejected, and once the queue is empty the consumer sees
//! [`Recv::Closed`] and exits.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Why a send was rejected (the message is handed back untouched).
#[derive(Debug)]
pub enum SendError<T> {
    /// The mailbox is at capacity — the shard is overloaded; callers
    /// should answer with a load-shed / retry-after error.
    Full(T),
    /// The mailbox was closed (coordinator shutting down).
    Closed(T),
}

/// Outcome of a [`Mailbox::recv_timeout`] call.
#[derive(Debug)]
pub enum Recv<T> {
    /// A message was dequeued.
    Msg(T),
    /// The timeout elapsed with the queue empty (idle tick — shard
    /// workers use this to run their TTL sweep).
    Timeout,
    /// The mailbox is closed and fully drained; the worker should exit.
    Closed,
}

struct Inner<T> {
    queue: Mutex<Queue<T>>,
    recv_cv: Condvar,
    capacity: usize,
}

struct Queue<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded MPSC mailbox handle. Cloning yields another producer
/// handle over the same queue; by convention exactly one thread (the
/// shard worker) calls [`Mailbox::recv_timeout`].
pub struct Mailbox<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Mailbox<T> {
    fn clone(&self) -> Self {
        Mailbox {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> std::fmt::Debug for Mailbox<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mailbox({}/{})", self.len(), self.inner.capacity)
    }
}

impl<T> Mailbox<T> {
    /// Create a mailbox holding at most `capacity` queued messages
    /// (`capacity` ≥ 1; 0 is clamped to 1 — a zero-capacity mailbox
    /// could never deliver anything).
    pub fn new(capacity: usize) -> Mailbox<T> {
        Mailbox {
            inner: Arc::new(Inner {
                queue: Mutex::new(Queue {
                    items: VecDeque::with_capacity(capacity.max(1)),
                    closed: false,
                }),
                recv_cv: Condvar::new(),
                capacity: capacity.max(1),
            }),
        }
    }

    /// Enqueue without blocking. `Err(Full)` when at capacity (the
    /// caller load-sheds), `Err(Closed)` after [`Mailbox::close`].
    pub fn try_send(&self, msg: T) -> Result<(), SendError<T>> {
        // Chaos hook (no-op without the `failpoints` feature):
        // `mailbox.send=delayNms@…` stalls the producer before the
        // queue lock (modelling a descheduled connection thread), and
        // `=err@…` maps to a load-shed `Full` — the only failure this
        // API can express, exercising the caller's retry-after path.
        if crate::util::failpoint::check("mailbox.send").is_some() {
            return Err(SendError::Full(msg));
        }
        let mut q = self.inner.queue.lock().unwrap();
        if q.closed {
            return Err(SendError::Closed(msg));
        }
        if q.items.len() >= self.inner.capacity {
            return Err(SendError::Full(msg));
        }
        q.items.push_back(msg);
        drop(q);
        self.inner.recv_cv.notify_one();
        Ok(())
    }

    /// Dequeue one message, waiting up to `timeout` for one to arrive.
    /// A closed mailbox keeps delivering its backlog (drain mode) and
    /// reports [`Recv::Closed`] only once empty.
    pub fn recv_timeout(&self, timeout: Duration) -> Recv<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(msg) = q.items.pop_front() {
                return Recv::Msg(msg);
            }
            if q.closed {
                return Recv::Closed;
            }
            let (guard, res) = self.inner.recv_cv.wait_timeout(q, timeout).unwrap();
            q = guard;
            if res.timed_out() && q.items.is_empty() && !q.closed {
                return Recv::Timeout;
            }
        }
    }

    /// Close the mailbox: new sends are rejected, queued messages still
    /// drain, and the consumer is woken.
    pub fn close(&self) {
        self.inner.queue.lock().unwrap().closed = true;
        self.inner.recv_cv.notify_all();
    }

    /// Whether [`Mailbox::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.queue.lock().unwrap().closed
    }

    /// Messages currently queued (the per-shard `mailbox_depth` stat).
    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The fixed capacity this mailbox admits.
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_recv_fifo() {
        let mb: Mailbox<u32> = Mailbox::new(4);
        for k in 0..4 {
            mb.try_send(k).unwrap();
        }
        for k in 0..4 {
            match mb.recv_timeout(Duration::from_millis(10)) {
                Recv::Msg(v) => assert_eq!(v, k),
                other => panic!("expected Msg({k}), got {other:?}"),
            }
        }
        assert!(matches!(
            mb.recv_timeout(Duration::from_millis(1)),
            Recv::Timeout
        ));
    }

    #[test]
    fn full_mailbox_sheds_instead_of_blocking() {
        let mb: Mailbox<u32> = Mailbox::new(2);
        mb.try_send(1).unwrap();
        mb.try_send(2).unwrap();
        let t0 = std::time::Instant::now();
        match mb.try_send(3) {
            Err(SendError::Full(v)) => assert_eq!(v, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        // The rejection must be immediate — that is the whole point.
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(mb.len(), 2);
    }

    #[test]
    fn close_drains_backlog_then_reports_closed() {
        let mb: Mailbox<u32> = Mailbox::new(4);
        mb.try_send(7).unwrap();
        mb.try_send(8).unwrap();
        mb.close();
        match mb.try_send(9) {
            Err(SendError::Closed(v)) => assert_eq!(v, 9),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert!(matches!(mb.recv_timeout(Duration::from_millis(5)), Recv::Msg(7)));
        assert!(matches!(mb.recv_timeout(Duration::from_millis(5)), Recv::Msg(8)));
        assert!(matches!(mb.recv_timeout(Duration::from_millis(5)), Recv::Closed));
    }

    #[test]
    fn recv_wakes_on_cross_thread_send() {
        let mb: Mailbox<u32> = Mailbox::new(1);
        let tx = mb.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.try_send(42).unwrap();
        });
        match mb.recv_timeout(Duration::from_secs(2)) {
            Recv::Msg(v) => assert_eq!(v, 42),
            other => panic!("expected Msg, got {other:?}"),
        }
        h.join().unwrap();
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mb: Mailbox<u32> = Mailbox::new(0);
        assert_eq!(mb.capacity(), 1);
        mb.try_send(1).unwrap();
        assert!(matches!(mb.try_send(2), Err(SendError::Full(2))));
    }
}
