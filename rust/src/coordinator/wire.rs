//! Wire protocol v2: versioned, length-prefixed binary frames.
//!
//! v1 (JSON-lines, [`super::protocol`]) stays fully supported — the
//! server disambiguates per message on the first byte (`0x02` = a v2
//! frame; anything else, e.g. `{` = `0x7B`, is a v1 JSON line), so v1
//! clients keep working against a v2 server unchanged, and a single
//! connection may even interleave both.
//!
//! ## Frame layout
//!
//! Every frame — request and response — is
//!
//! ```text
//! ┌──────┬──────────────┬────────────────┬─────────┐
//! │ 0x02 │ verb/status  │ len: u32 LE    │ payload │
//! │ u8   │ u8           │ (payload only) │ len B   │
//! └──────┴──────────────┴────────────────┴─────────┘
//! ```
//!
//! All integers are little-endian; floats are IEEE-754 `f64` bits LE.
//! `len` counts payload bytes only (the header is always 6 bytes) and
//! is capped at [`MAX_FRAME_LEN`] — an oversized prefix is answered
//! with a `bad_frame` error and the connection is closed (the stream
//! can no longer be trusted to be in sync).
//!
//! Request verbs: `ping` 0x01, `stats` 0x02, `signature` 0x03,
//! `stats2` 0x04, `gram` 0x05, `health` 0x06, `stream_open` 0x10,
//! `stream_push` 0x11, `stream_window` 0x12, `stream_close` 0x13.
//! Response status: `ok` 0, `err` 1, `shed` 2; every response payload
//! leads with the request verb it answers.
//!
//! The stats verbs return per-shard counters from the actor-sharded
//! session table ([`super::shard`]). `stats` keeps the layout it
//! shipped with — `(shard, sessions, mailbox_depth, sheds, pushes)`
//! rows, nothing else — **frozen**: clients deployed against that
//! layout reject trailing bytes, so the durability counters could not
//! be added in place without misdecoding across versions. `stats2`
//! carries the extended body instead: the same rows each followed by
//! `journal_lag`, then the content-addressed signature-cache counters
//! (`hits`, `misses`, `evictions`; see [`crate::persist`]). New fields
//! get a new verb, never a relayout.

use super::protocol::{Backend, Request, RequestOp, MAX_GRAM_BATCH, MAX_STREAM_WINDOW};
use super::shard::ShardStat;
use crate::persist::CacheStats;
use crate::util::rng::Rng;
use crate::words::{generate::sparse_leadlag_generators, Word, WordSpec};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// The protocol version byte leading every v2 frame.
pub const WIRE_V2: u8 = 0x02;

/// Upper bound on a frame's payload length (16 MiB). Anything larger
/// is rejected before allocation as a `bad_frame` error.
pub const MAX_FRAME_LEN: usize = 1 << 24;

/// Request verb bytes.
pub mod verb {
    /// Health check.
    pub const PING: u8 = 0x01;
    /// Per-shard coordinator stats (frozen original layout).
    pub const STATS: u8 = 0x02;
    /// One-shot projected signature.
    pub const SIGNATURE: u8 = 0x03;
    /// Extended stats: per-shard rows with `journal_lag` plus the
    /// signature-cache counters. A separate verb so `stats` decoders
    /// built before durability existed keep working unchanged.
    pub const STATS2: u8 = 0x04;
    /// Batched signature-kernel Gram matrix. Its own verb — not a
    /// field grafted onto `signature` — because that frame's layout is
    /// frozen (deployed decoders reject trailing bytes).
    pub const GRAM: u8 = 0x05;
    /// Durability health: failure policy, the sticky degraded bit, and
    /// journal-failure counters. Its own verb for the same frozen-
    /// layout reason as `stats2` — existing frames never grow fields.
    pub const HEALTH: u8 = 0x06;
    /// Open a streaming session.
    pub const STREAM_OPEN: u8 = 0x10;
    /// Push samples into a session.
    pub const STREAM_PUSH: u8 = 0x11;
    /// Query a session's window/running signature.
    pub const STREAM_WINDOW: u8 = 0x12;
    /// Close a session.
    pub const STREAM_CLOSE: u8 = 0x13;
}

/// Response status bytes.
pub mod status {
    /// Success; payload = verb byte + verb-specific body.
    pub const OK: u8 = 0;
    /// Failure; payload = verb, error code, message.
    pub const ERR: u8 = 1;
    /// Load-shed; payload = verb, retry-after hint, message.
    pub const SHED: u8 = 2;
}

/// Error codes carried in `err` response frames.
pub mod errcode {
    /// The frame itself was malformed (bad length, truncated payload,
    /// trailing bytes). The server closes the connection after this.
    pub const BAD_FRAME: u8 = 1;
    /// The frame decoded but the request was invalid (bad dim, window
    /// over the cap, …).
    pub const BAD_REQUEST: u8 = 2;
    /// The addressed session does not exist (closed or evicted).
    pub const UNKNOWN_SESSION: u8 = 3;
    /// Unknown verb byte.
    pub const UNSUPPORTED: u8 = 4;
    /// The server failed internally.
    pub const INTERNAL: u8 = 5;
}

/// Map a service error message onto a wire error code. Error strings
/// are the stable v1 surface, so matching on them here keeps the two
/// protocols consistent without a parallel error enum through the
/// service layer.
pub fn code_for(msg: &str) -> u8 {
    if msg.contains("unknown session") {
        errcode::UNKNOWN_SESSION
    } else {
        errcode::BAD_REQUEST
    }
}

/// Projection spec as encoded on the wire (tag byte + variant body).
#[derive(Clone, Debug, PartialEq)]
pub enum SpecFrame {
    /// Tag 0: full truncated tensor algebra at the request's depth.
    Truncated,
    /// Tag 1: Lyndon-word basis at the request's depth.
    Lyndon,
    /// Tag 2: anisotropic weights + cutoff.
    Anisotropic {
        /// Per-letter weights (length = dim).
        gamma: Vec<f64>,
        /// Weighted-degree cutoff.
        cutoff: f64,
    },
    /// Tag 3: DAG-restricted words (adjacency rows, one per letter).
    Dag {
        /// `edges[a]` = letters allowed to follow `a`.
        edges: Vec<Vec<u16>>,
    },
    /// Tag 4: explicit word list.
    Words {
        /// The projection's words.
        words: Vec<Vec<u16>>,
    },
    /// Tag 5: sparse lead-lag generator set (§8); alphabet must be
    /// `2 · base_dim`.
    SparseLeadLag {
        /// Base path dimension before lead-lag doubling.
        base_dim: u32,
    },
}

/// A decoded v2 request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestFrame {
    /// Health check.
    Ping,
    /// Per-shard stats (frozen original layout).
    Stats,
    /// Per-shard stats, extended with journal lag + cache counters.
    Stats2,
    /// Durability health: failure policy, degraded bit, counters.
    Health,
    /// One-shot signature of a path.
    Signature {
        /// Path dimension.
        dim: u32,
        /// Truncation depth.
        depth: u32,
        /// Projection.
        spec: SpecFrame,
        /// Row-major `(M+1)·dim` samples.
        path: Vec<f64>,
    },
    /// Batched Gram matrix `G[i][j] = ⟨S(x_i), S(x_j)⟩` over the
    /// projected word set.
    Gram {
        /// Path dimension.
        dim: u32,
        /// Truncation depth.
        depth: u32,
        /// Projection.
        spec: SpecFrame,
        /// The batch: each entry a flat `(M+1)·dim` path. All paths
        /// must have the same length (one forward sweep services the
        /// whole batch).
        paths: Vec<Vec<f64>>,
    },
    /// Open a streaming session.
    StreamOpen {
        /// Path dimension.
        dim: u32,
        /// Truncation depth.
        depth: u32,
        /// Sliding-window length in increments.
        window: u32,
        /// Projection.
        spec: SpecFrame,
    },
    /// Push samples into session `session`.
    StreamPush {
        /// Numeric session id (v1's `"s<N>"` without the prefix).
        session: u64,
        /// Flat `(k, dim)` samples.
        samples: Vec<f64>,
    },
    /// Query session `session`'s signature.
    StreamWindow {
        /// Numeric session id.
        session: u64,
        /// `true` → running `S_{0,t}` instead of the sliding window.
        full: bool,
    },
    /// Close session `session`.
    StreamClose {
        /// Numeric session id.
        session: u64,
    },
}

/// A decoded v2 response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseFrame {
    /// Success.
    Ok {
        /// The request verb this answers.
        verb: u8,
        /// Verb-specific body.
        body: OkBody,
    },
    /// Failure.
    Err {
        /// The request verb this answers (0 if it never decoded).
        verb: u8,
        /// One of [`errcode`]'s codes.
        code: u8,
        /// Human-readable message.
        message: String,
    },
    /// Load-shed: retry after the hint.
    Shed {
        /// The request verb this answers.
        verb: u8,
        /// Suggested backoff in milliseconds.
        retry_after_ms: u32,
        /// Human-readable message.
        message: String,
    },
}

/// Body of an `ok` response, shaped by the verb it answers.
#[derive(Clone, Debug, PartialEq)]
pub enum OkBody {
    /// `ping` / `stream_close`: no body.
    Empty,
    /// `health`: the durability failure policy and its consequences.
    Health {
        /// Failure-policy byte: 0 = degraded, 1 = strict
        /// (`--durability`).
        mode: u8,
        /// Sticky degraded bit — set the first time a journal append
        /// failed in degraded mode (some acked ops have no durable
        /// record); never clears while the process lives.
        degraded: bool,
        /// Journal/checkpoint IO failures so far (all shards).
        journal_errors: u64,
        /// Ops rejected (or evictions deferred) by strict durability.
        strict_rejects: u64,
    },
    /// `stats`: per-shard counters + signature-cache counters.
    Stats {
        /// One row per shard.
        shards: Vec<ShardStat>,
        /// Content-addressed signature-cache counters (all zero when
        /// the cache is disabled).
        cache: CacheStats,
    },
    /// `signature` / `stream_window`: shaped values.
    Values {
        /// Logical shape.
        shape: Vec<u32>,
        /// Flat values.
        values: Vec<f64>,
    },
    /// `stream_open`: the new session.
    Opened {
        /// Numeric session id.
        session: u64,
        /// Output dimension `|I|`.
        out_dim: u32,
    },
    /// `stream_push`: acknowledgement.
    Pushed {
        /// Samples appended by this request.
        pushed: u64,
        /// Total samples seen by the session.
        seen: u64,
    },
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u32(out, vs.len() as u32);
    for &v in vs {
        put_f64(out, v);
    }
}

/// Wrap a payload in the 6-byte v2 header.
pub fn frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(6 + payload.len());
    out.push(WIRE_V2);
    out.push(kind);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

impl SpecFrame {
    fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            SpecFrame::Truncated => out.push(0),
            SpecFrame::Lyndon => out.push(1),
            SpecFrame::Anisotropic { gamma, cutoff } => {
                out.push(2);
                put_f64s(out, gamma);
                put_f64(out, *cutoff);
            }
            SpecFrame::Dag { edges } => {
                out.push(3);
                put_u32(out, edges.len() as u32);
                for row in edges {
                    put_u32(out, row.len() as u32);
                    for &l in row {
                        out.extend_from_slice(&l.to_le_bytes());
                    }
                }
            }
            SpecFrame::Words { words } => {
                out.push(4);
                put_u32(out, words.len() as u32);
                for w in words {
                    put_u32(out, w.len() as u32);
                    for &l in w {
                        out.extend_from_slice(&l.to_le_bytes());
                    }
                }
            }
            SpecFrame::SparseLeadLag { base_dim } => {
                out.push(5);
                put_u32(out, *base_dim);
            }
        }
    }
}

impl RequestFrame {
    /// The verb byte of this request.
    pub fn verb(&self) -> u8 {
        match self {
            RequestFrame::Ping => verb::PING,
            RequestFrame::Stats => verb::STATS,
            RequestFrame::Stats2 => verb::STATS2,
            RequestFrame::Health => verb::HEALTH,
            RequestFrame::Signature { .. } => verb::SIGNATURE,
            RequestFrame::Gram { .. } => verb::GRAM,
            RequestFrame::StreamOpen { .. } => verb::STREAM_OPEN,
            RequestFrame::StreamPush { .. } => verb::STREAM_PUSH,
            RequestFrame::StreamWindow { .. } => verb::STREAM_WINDOW,
            RequestFrame::StreamClose { .. } => verb::STREAM_CLOSE,
        }
    }

    /// Encode as a complete frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            RequestFrame::Ping
            | RequestFrame::Stats
            | RequestFrame::Stats2
            | RequestFrame::Health => {}
            RequestFrame::Signature {
                dim,
                depth,
                spec,
                path,
            } => {
                put_u32(&mut p, *dim);
                put_u32(&mut p, *depth);
                spec.encode_into(&mut p);
                put_f64s(&mut p, path);
            }
            RequestFrame::Gram {
                dim,
                depth,
                spec,
                paths,
            } => {
                put_u32(&mut p, *dim);
                put_u32(&mut p, *depth);
                spec.encode_into(&mut p);
                put_u32(&mut p, paths.len() as u32);
                for path in paths {
                    put_f64s(&mut p, path);
                }
            }
            RequestFrame::StreamOpen {
                dim,
                depth,
                window,
                spec,
            } => {
                put_u32(&mut p, *dim);
                put_u32(&mut p, *depth);
                put_u32(&mut p, *window);
                spec.encode_into(&mut p);
            }
            RequestFrame::StreamPush { session, samples } => {
                put_u64(&mut p, *session);
                put_f64s(&mut p, samples);
            }
            RequestFrame::StreamWindow { session, full } => {
                put_u64(&mut p, *session);
                p.push(u8::from(*full));
            }
            RequestFrame::StreamClose { session } => {
                put_u64(&mut p, *session);
            }
        }
        frame(self.verb(), &p)
    }

    /// Decode a request payload for `verb_byte`. The payload must be
    /// consumed exactly — trailing bytes are a `bad_frame` error.
    pub fn decode(verb_byte: u8, payload: &[u8]) -> Result<RequestFrame, String> {
        let mut c = Cur::new(payload);
        let req = match verb_byte {
            verb::PING => RequestFrame::Ping,
            verb::STATS => RequestFrame::Stats,
            verb::STATS2 => RequestFrame::Stats2,
            verb::HEALTH => RequestFrame::Health,
            verb::SIGNATURE => {
                let dim = c.u32()?;
                let depth = c.u32()?;
                let spec = decode_spec(&mut c)?;
                let path = c.f64s()?;
                RequestFrame::Signature {
                    dim,
                    depth,
                    spec,
                    path,
                }
            }
            verb::GRAM => {
                let dim = c.u32()?;
                let depth = c.u32()?;
                let spec = decode_spec(&mut c)?;
                let count = c.u32()? as usize;
                if count > MAX_GRAM_BATCH {
                    return Err(format!(
                        "gram batch {count} exceeds the server cap {MAX_GRAM_BATCH}"
                    ));
                }
                let mut paths = Vec::with_capacity(count);
                for _ in 0..count {
                    paths.push(c.f64s()?);
                }
                RequestFrame::Gram {
                    dim,
                    depth,
                    spec,
                    paths,
                }
            }
            verb::STREAM_OPEN => {
                let dim = c.u32()?;
                let depth = c.u32()?;
                let window = c.u32()?;
                let spec = decode_spec(&mut c)?;
                RequestFrame::StreamOpen {
                    dim,
                    depth,
                    window,
                    spec,
                }
            }
            verb::STREAM_PUSH => {
                let session = c.u64()?;
                let samples = c.f64s()?;
                RequestFrame::StreamPush { session, samples }
            }
            verb::STREAM_WINDOW => {
                let session = c.u64()?;
                let full = match c.u8()? {
                    0 => false,
                    1 => true,
                    m => return Err(format!("unknown stream_window mode byte {m}")),
                };
                RequestFrame::StreamWindow { session, full }
            }
            verb::STREAM_CLOSE => {
                let session = c.u64()?;
                RequestFrame::StreamClose { session }
            }
            other => return Err(format!("unknown verb byte 0x{other:02x}")),
        };
        c.finish()?;
        Ok(req)
    }

    /// Lower into the protocol-level [`Request`] the service executes,
    /// running the same validations v1's JSON parser applies (so both
    /// protocols reject exactly the same requests).
    pub fn into_request(self) -> Result<Request, String> {
        let blank = |op: RequestOp| Request {
            id: String::new(),
            op,
            dim: 0,
            depth: 0,
            spec: WordSpec::Truncated { depth: 0 },
            backend: Backend::Auto,
            path: Vec::new(),
            batch: 0,
            windows: Vec::new(),
            session: String::new(),
            samples: Vec::new(),
            window_len: 0,
            full: false,
        };
        match self {
            RequestFrame::Ping => Ok(blank(RequestOp::Ping)),
            // Both stats verbs run the same service op; the reply's
            // verb byte (mirroring the request) picks the body layout.
            RequestFrame::Stats | RequestFrame::Stats2 => Ok(blank(RequestOp::Stats)),
            // Health is answered straight from the metrics registry in
            // the server's frame handler — it never becomes a service
            // request, so lowering it is a (server) programming error.
            RequestFrame::Health => {
                Err("health is a control verb answered by the server".into())
            }
            RequestFrame::Signature {
                dim,
                depth,
                spec,
                path,
            } => {
                let (dim, depth) = (dim as usize, depth as usize);
                if dim == 0 {
                    return Err("dim must be ≥ 1".into());
                }
                if path.is_empty() || path.len() % dim != 0 {
                    return Err(format!(
                        "path must be a non-empty flat (M+1)·dim array (got {} floats, dim {})",
                        path.len(),
                        dim
                    ));
                }
                // Unlike v1's JSON (where only an overflowing literal
                // can smuggle an Inf in), raw IEEE bits arrive here —
                // same check, byte-identical error string.
                super::protocol::check_finite("path", &path)?;
                let mut req = blank(RequestOp::Signature);
                req.dim = dim;
                req.depth = depth;
                req.spec = spec.into_word_spec(depth, dim)?;
                req.path = path;
                Ok(req)
            }
            RequestFrame::Gram {
                dim,
                depth,
                spec,
                paths,
            } => {
                let (dim, depth) = (dim as usize, depth as usize);
                if dim == 0 {
                    return Err("dim must be ≥ 1".into());
                }
                if paths.is_empty() {
                    return Err("gram needs a non-empty 'paths' array of paths".into());
                }
                if paths.len() > MAX_GRAM_BATCH {
                    return Err(format!(
                        "gram batch {} exceeds the server cap {MAX_GRAM_BATCH}",
                        paths.len()
                    ));
                }
                let per_path = paths[0].len();
                if paths.iter().any(|p| p.len() != per_path) {
                    return Err("gram paths must all have the same length".into());
                }
                if per_path == 0 || per_path % dim != 0 {
                    return Err(format!(
                        "each gram path must be a non-empty flat (M+1)·dim array \
                         (got {per_path} floats, dim {dim})"
                    ));
                }
                let mut req = blank(RequestOp::Gram);
                req.dim = dim;
                req.depth = depth;
                req.spec = spec.into_word_spec(depth, dim)?;
                req.batch = paths.len();
                req.path = paths.into_iter().flatten().collect();
                // Checked on the flattened batch so the reported index
                // matches v1's (which flattens rows the same way).
                super::protocol::check_finite("paths", &req.path)?;
                Ok(req)
            }
            RequestFrame::StreamOpen {
                dim,
                depth,
                window,
                spec,
            } => {
                let (dim, depth, window) = (dim as usize, depth as usize, window as usize);
                if dim == 0 {
                    return Err("dim must be ≥ 1".into());
                }
                if window == 0 {
                    return Err("'window' must be ≥ 1".into());
                }
                if window > MAX_STREAM_WINDOW {
                    return Err(format!(
                        "'window' {window} exceeds the server cap {MAX_STREAM_WINDOW}"
                    ));
                }
                let mut req = blank(RequestOp::StreamOpen);
                req.dim = dim;
                req.depth = depth;
                req.spec = spec.into_word_spec(depth, dim)?;
                req.window_len = window;
                Ok(req)
            }
            RequestFrame::StreamPush { session, samples } => {
                if samples.is_empty() {
                    return Err("stream_push needs a non-empty 'samples' array".into());
                }
                super::protocol::check_finite("samples", &samples)?;
                let mut req = blank(RequestOp::StreamPush);
                req.session = format!("s{session}");
                req.samples = samples;
                Ok(req)
            }
            RequestFrame::StreamWindow { session, full } => {
                let mut req = blank(RequestOp::StreamWindow);
                req.session = format!("s{session}");
                req.full = full;
                Ok(req)
            }
            RequestFrame::StreamClose { session } => {
                let mut req = blank(RequestOp::StreamClose);
                req.session = format!("s{session}");
                Ok(req)
            }
        }
    }
}

impl SpecFrame {
    /// Lower into a [`WordSpec`], applying the same validation v1's
    /// projection parser applies.
    pub fn into_word_spec(self, depth: usize, dim: usize) -> Result<WordSpec, String> {
        match self {
            SpecFrame::Truncated => Ok(WordSpec::Truncated { depth }),
            SpecFrame::Lyndon => Ok(WordSpec::Lyndon { depth }),
            SpecFrame::Anisotropic { gamma, cutoff } => {
                if gamma.len() != dim {
                    return Err(format!(
                        "anisotropic projection needs {dim} weights, got {}",
                        gamma.len()
                    ));
                }
                if gamma.iter().any(|&g| g <= 0.0) {
                    return Err("anisotropic weights must be positive".into());
                }
                Ok(WordSpec::Anisotropic { gamma, cutoff })
            }
            SpecFrame::Dag { edges } => {
                if edges.len() != dim {
                    return Err(format!("dag needs {dim} adjacency rows"));
                }
                if edges.iter().flatten().any(|&l| l as usize >= dim) {
                    return Err("dag edge letter out of range".into());
                }
                Ok(WordSpec::Dag { depth, edges })
            }
            SpecFrame::Words { words } => {
                if words.is_empty() {
                    return Err("words projection needs a non-empty list".into());
                }
                for w in &words {
                    if w.is_empty() {
                        return Err("empty word in projection".into());
                    }
                    if w.iter().any(|&l| l as usize >= dim) {
                        return Err("word letter out of range".into());
                    }
                }
                Ok(WordSpec::Custom {
                    words: words.into_iter().map(Word).collect(),
                })
            }
            SpecFrame::SparseLeadLag { base_dim } => {
                let base = base_dim as usize;
                if 2 * base != dim {
                    return Err(format!(
                        "sparse_leadlag: dim must be 2·base_dim (dim={dim}, base={base})"
                    ));
                }
                Ok(WordSpec::ConcatGenerated {
                    depth,
                    generators: sparse_leadlag_generators(base),
                })
            }
        }
    }
}

fn decode_spec(c: &mut Cur<'_>) -> Result<SpecFrame, String> {
    Ok(match c.u8()? {
        0 => SpecFrame::Truncated,
        1 => SpecFrame::Lyndon,
        2 => {
            let gamma = c.f64s()?;
            let cutoff = c.f64()?;
            SpecFrame::Anisotropic { gamma, cutoff }
        }
        3 => {
            let rows = c.u32()? as usize;
            let mut edges = Vec::new();
            for _ in 0..rows {
                edges.push(c.u16s()?);
            }
            SpecFrame::Dag { edges }
        }
        4 => {
            let count = c.u32()? as usize;
            let mut words = Vec::new();
            for _ in 0..count {
                words.push(c.u16s()?);
            }
            SpecFrame::Words { words }
        }
        5 => SpecFrame::SparseLeadLag { base_dim: c.u32()? },
        t => return Err(format!("unknown projection tag {t}")),
    })
}

impl ResponseFrame {
    /// Encode as a complete frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        let kind = match self {
            ResponseFrame::Ok { verb: v, body } => {
                p.push(*v);
                match body {
                    OkBody::Empty => {}
                    OkBody::Health {
                        mode,
                        degraded,
                        journal_errors,
                        strict_rejects,
                    } => {
                        p.push(*mode);
                        p.push(u8::from(*degraded));
                        put_u64(&mut p, *journal_errors);
                        put_u64(&mut p, *strict_rejects);
                    }
                    OkBody::Stats { shards, cache } => {
                        // The `stats` layout is frozen exactly as it
                        // first shipped (deployed decoders reject
                        // trailing bytes); only `stats2` carries the
                        // durability fields.
                        let extended = *v == verb::STATS2;
                        put_u32(&mut p, shards.len() as u32);
                        for r in shards {
                            put_u32(&mut p, r.shard as u32);
                            put_u64(&mut p, r.sessions);
                            put_u64(&mut p, r.mailbox_depth);
                            put_u64(&mut p, r.sheds);
                            put_u64(&mut p, r.pushes);
                            if extended {
                                put_u64(&mut p, r.journal_lag);
                            }
                        }
                        if extended {
                            put_u64(&mut p, cache.hits);
                            put_u64(&mut p, cache.misses);
                            put_u64(&mut p, cache.evictions);
                        }
                    }
                    OkBody::Values { shape, values } => {
                        put_u32(&mut p, shape.len() as u32);
                        for &s in shape {
                            put_u32(&mut p, s);
                        }
                        put_f64s(&mut p, values);
                    }
                    OkBody::Opened { session, out_dim } => {
                        put_u64(&mut p, *session);
                        put_u32(&mut p, *out_dim);
                    }
                    OkBody::Pushed { pushed, seen } => {
                        put_u64(&mut p, *pushed);
                        put_u64(&mut p, *seen);
                    }
                }
                status::OK
            }
            ResponseFrame::Err {
                verb: v,
                code,
                message,
            } => {
                p.push(*v);
                p.push(*code);
                put_u32(&mut p, message.len() as u32);
                p.extend_from_slice(message.as_bytes());
                status::ERR
            }
            ResponseFrame::Shed {
                verb: v,
                retry_after_ms,
                message,
            } => {
                p.push(*v);
                put_u32(&mut p, *retry_after_ms);
                put_u32(&mut p, message.len() as u32);
                p.extend_from_slice(message.as_bytes());
                status::SHED
            }
        };
        frame(kind, &p)
    }

    /// Decode a response payload for `status_byte`. Ok bodies are
    /// shaped by the verb byte leading the payload.
    pub fn decode(status_byte: u8, payload: &[u8]) -> Result<ResponseFrame, String> {
        let mut c = Cur::new(payload);
        let resp = match status_byte {
            status::OK => {
                let v = c.u8()?;
                let body = match v {
                    verb::PING | verb::STREAM_CLOSE => OkBody::Empty,
                    verb::HEALTH => {
                        let mode = c.u8()?;
                        let degraded = match c.u8()? {
                            0 => false,
                            1 => true,
                            b => return Err(format!("bad health degraded byte {b}")),
                        };
                        OkBody::Health {
                            mode,
                            degraded,
                            journal_errors: c.u64()?,
                            strict_rejects: c.u64()?,
                        }
                    }
                    verb::STATS | verb::STATS2 => {
                        let extended = v == verb::STATS2;
                        let n = c.u32()? as usize;
                        let mut rows = Vec::new();
                        for _ in 0..n {
                            rows.push(ShardStat {
                                shard: c.u32()? as usize,
                                sessions: c.u64()?,
                                mailbox_depth: c.u64()?,
                                sheds: c.u64()?,
                                pushes: c.u64()?,
                                journal_lag: if extended { c.u64()? } else { 0 },
                            });
                        }
                        let cache = if extended {
                            CacheStats {
                                hits: c.u64()?,
                                misses: c.u64()?,
                                evictions: c.u64()?,
                            }
                        } else {
                            CacheStats::default()
                        };
                        OkBody::Stats { shards: rows, cache }
                    }
                    verb::SIGNATURE | verb::GRAM | verb::STREAM_WINDOW => {
                        let n = c.u32()? as usize;
                        let mut shape = Vec::new();
                        for _ in 0..n {
                            shape.push(c.u32()?);
                        }
                        let values = c.f64s()?;
                        OkBody::Values { shape, values }
                    }
                    verb::STREAM_OPEN => OkBody::Opened {
                        session: c.u64()?,
                        out_dim: c.u32()?,
                    },
                    verb::STREAM_PUSH => OkBody::Pushed {
                        pushed: c.u64()?,
                        seen: c.u64()?,
                    },
                    other => return Err(format!("unknown ok verb byte 0x{other:02x}")),
                };
                ResponseFrame::Ok { verb: v, body }
            }
            status::ERR => {
                let v = c.u8()?;
                let code = c.u8()?;
                let message = c.string()?;
                ResponseFrame::Err {
                    verb: v,
                    code,
                    message,
                }
            }
            status::SHED => {
                let v = c.u8()?;
                let retry_after_ms = c.u32()?;
                let message = c.string()?;
                ResponseFrame::Shed {
                    verb: v,
                    retry_after_ms,
                    message,
                }
            }
            other => return Err(format!("unknown status byte 0x{other:02x}")),
        };
        c.finish()?;
        Ok(resp)
    }
}

// ---------------------------------------------------------------------
// Byte cursor
// ---------------------------------------------------------------------

/// Bounds-checked little-endian reader over a frame payload. Every
/// length is validated against the remaining bytes *before* any
/// allocation, so a hostile length field cannot trigger an OOM.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.b.len() - self.i < n {
            return Err("truncated frame payload".into());
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(8).ok_or("count overflow")?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u16s(&mut self) -> Result<Vec<u16>, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(2).ok_or("count overflow")?)?;
        Ok(bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn string(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "non-utf8 string".to_string())
    }

    fn finish(&self) -> Result<(), String> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(format!(
                "trailing bytes in frame payload ({} unread)",
                self.b.len() - self.i
            ))
        }
    }
}

// ---------------------------------------------------------------------
// Binary client
// ---------------------------------------------------------------------

/// Client-side retry policy: capped exponential backoff with full
/// jitter, driven by a **seeded** RNG so a test (or a reproduced
/// incident) replays the exact same sleep schedule.
///
/// Attempt `k` (0-based) sleeps a uniform draw from
/// `[0, min(base · 2^k, max))` before retrying; when the server
/// answered with a shed frame, its `retry_after_ms` hint becomes the
/// *floor* of that draw — the client never retries earlier than the
/// server asked, and the jitter on top de-synchronizes a thundering
/// herd of shed clients.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts (first try included); `1` disables retrying.
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base_delay: Duration,
    /// Ceiling the exponential curve saturates at.
    pub max_delay: Duration,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_secs(1),
            seed: 0x7265_7472_79,
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry `attempt` (0-based), jittered by `rng`,
    /// never below `floor_ms` (a server's `retry_after_ms` hint; 0
    /// when there is none).
    pub fn backoff(&self, attempt: u32, floor_ms: u64, rng: &mut Rng) -> Duration {
        let cap = self
            .base_delay
            .saturating_mul(1u32.checked_shl(attempt).unwrap_or(u32::MAX))
            .min(self.max_delay);
        let jittered = cap.mul_f64(rng.uniform());
        jittered.max(Duration::from_millis(floor_ms))
    }
}

/// Minimal blocking v2 client (tests, benches, and the CLI). The v1
/// JSON client is [`super::server::Client`].
pub struct WireClient {
    stream: TcpStream,
    addr: String,
}

impl WireClient {
    /// Open a TCP connection to a running feature server.
    pub fn connect(addr: &str) -> std::io::Result<WireClient> {
        Ok(WireClient {
            stream: TcpStream::connect(addr)?,
            addr: addr.to_string(),
        })
    }

    /// Open a connection, retrying transient connect failures
    /// (refused/reset while a server restarts) under `policy`.
    pub fn connect_retry(addr: &str, policy: &RetryPolicy) -> std::io::Result<WireClient> {
        let mut rng = Rng::new(policy.seed);
        let mut last = None;
        for attempt in 0..policy.max_attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(policy.backoff(attempt - 1, 0, &mut rng));
            }
            match WireClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| std::io::Error::other("no connect attempts made")))
    }

    /// Send one request frame, read one response frame back.
    pub fn call(&mut self, req: &RequestFrame) -> std::io::Result<ResponseFrame> {
        self.stream.write_all(&req.encode())?;
        read_response(&mut self.stream)
    }

    /// [`WireClient::call`] with bounded retries: a shed response is
    /// retried after at least its `retry_after_ms` hint, and an IO
    /// error (server restarting, connection dropped mid-flight) is
    /// retried on a **fresh** connection. The last shed frame (or IO
    /// error) is returned once attempts are exhausted. Only safe for
    /// requests that are idempotent or rejected-before-effect (sheds
    /// are, by construction — the op was dropped before any work).
    pub fn call_retry(
        &mut self,
        req: &RequestFrame,
        policy: &RetryPolicy,
    ) -> std::io::Result<ResponseFrame> {
        let mut rng = Rng::new(policy.seed);
        let mut attempt = 0u32;
        loop {
            let outcome = self.call(req);
            attempt += 1;
            let floor_ms = match &outcome {
                Ok(ResponseFrame::Shed { retry_after_ms, .. }) => u64::from(*retry_after_ms),
                Ok(resp) => return Ok(resp.clone()),
                Err(_) => 0,
            };
            if attempt >= policy.max_attempts.max(1) {
                return outcome;
            }
            std::thread::sleep(policy.backoff(attempt - 1, floor_ms, &mut rng));
            if outcome.is_err() {
                // The stream may be half-dead; reconnect before the
                // next attempt (a connect failure keeps the old stream
                // and lets the next call surface the error).
                if let Ok(fresh) = WireClient::connect(&self.addr) {
                    *self = fresh;
                }
            }
        }
    }
}

/// Read one complete response frame from `r`.
pub fn read_response(r: &mut impl Read) -> std::io::Result<ResponseFrame> {
    let mut header = [0u8; 6];
    r.read_exact(&mut header)?;
    let bad = |m: String| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
    if header[0] != WIRE_V2 {
        return Err(bad(format!("bad version byte 0x{:02x}", header[0])));
    }
    let len = u32::from_le_bytes(header[2..6].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(bad(format!("frame length {len} exceeds cap")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    ResponseFrame::decode(header[1], &payload).map_err(bad)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(f: RequestFrame) {
        let bytes = f.encode();
        assert_eq!(bytes[0], WIRE_V2);
        assert_eq!(bytes[1], f.verb());
        let len = u32::from_le_bytes(bytes[2..6].try_into().unwrap()) as usize;
        assert_eq!(len, bytes.len() - 6);
        let back = RequestFrame::decode(bytes[1], &bytes[6..]).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn request_frames_roundtrip() {
        roundtrip_req(RequestFrame::Ping);
        roundtrip_req(RequestFrame::Stats);
        roundtrip_req(RequestFrame::Stats2);
        roundtrip_req(RequestFrame::Health);
        roundtrip_req(RequestFrame::Signature {
            dim: 2,
            depth: 3,
            spec: SpecFrame::Truncated,
            path: vec![0.0, 0.0, 1.0, 0.5],
        });
        roundtrip_req(RequestFrame::Signature {
            dim: 2,
            depth: 4,
            spec: SpecFrame::Anisotropic {
                gamma: vec![1.0, 2.0],
                cutoff: 3.5,
            },
            path: vec![0.0, 0.0, 1.0, 1.0],
        });
        roundtrip_req(RequestFrame::Signature {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Dag {
                edges: vec![vec![0, 1], vec![1]],
            },
            path: vec![0.0, 0.0, 1.0, 1.0],
        });
        roundtrip_req(RequestFrame::Signature {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Words {
                words: vec![vec![0, 1], vec![1]],
            },
            path: vec![0.0, 0.0, 1.0, 1.0],
        });
        roundtrip_req(RequestFrame::Signature {
            dim: 4,
            depth: 2,
            spec: SpecFrame::SparseLeadLag { base_dim: 2 },
            path: vec![0.0; 8],
        });
        roundtrip_req(RequestFrame::Gram {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Truncated,
            paths: vec![vec![0.0, 0.0, 1.0, 0.5], vec![0.0, 0.0, -1.0, 2.0]],
        });
        roundtrip_req(RequestFrame::Gram {
            dim: 2,
            depth: 3,
            spec: SpecFrame::Anisotropic {
                gamma: vec![1.0, 2.0],
                cutoff: 2.5,
            },
            paths: vec![vec![0.0, 0.0, 1.0, 1.0]],
        });
        roundtrip_req(RequestFrame::StreamOpen {
            dim: 1,
            depth: 2,
            window: 16,
            spec: SpecFrame::Lyndon,
        });
        roundtrip_req(RequestFrame::StreamPush {
            session: 7,
            samples: vec![0.25, -1.5],
        });
        roundtrip_req(RequestFrame::StreamWindow {
            session: 7,
            full: true,
        });
        roundtrip_req(RequestFrame::StreamClose { session: 7 });
    }

    #[test]
    fn response_frames_roundtrip() {
        let cases = vec![
            ResponseFrame::Ok {
                verb: verb::PING,
                body: OkBody::Empty,
            },
            // `stats` carries the frozen base layout only, so a
            // roundtrip preserves exactly the base fields (journal_lag
            // and cache decode as zero).
            ResponseFrame::Ok {
                verb: verb::STATS,
                body: OkBody::Stats {
                    shards: vec![ShardStat {
                        shard: 0,
                        sessions: 3,
                        mailbox_depth: 1,
                        sheds: 0,
                        pushes: 42,
                        journal_lag: 0,
                    }],
                    cache: CacheStats::default(),
                },
            },
            // `stats2` roundtrips the durability fields too.
            ResponseFrame::Ok {
                verb: verb::STATS2,
                body: OkBody::Stats {
                    shards: vec![ShardStat {
                        shard: 0,
                        sessions: 3,
                        mailbox_depth: 1,
                        sheds: 0,
                        pushes: 42,
                        journal_lag: 5,
                    }],
                    cache: CacheStats {
                        hits: 7,
                        misses: 2,
                        evictions: 1,
                    },
                },
            },
            // `health` is its own verb so the frozen frames above
            // never grow fields; both mode bytes and the sticky bit
            // roundtrip.
            ResponseFrame::Ok {
                verb: verb::HEALTH,
                body: OkBody::Health {
                    mode: 1,
                    degraded: false,
                    journal_errors: 0,
                    strict_rejects: 3,
                },
            },
            ResponseFrame::Ok {
                verb: verb::HEALTH,
                body: OkBody::Health {
                    mode: 0,
                    degraded: true,
                    journal_errors: 7,
                    strict_rejects: 0,
                },
            },
            ResponseFrame::Ok {
                verb: verb::STREAM_WINDOW,
                body: OkBody::Values {
                    shape: vec![2],
                    values: vec![5.0, 12.5],
                },
            },
            // A gram response is a shaped (B, B) matrix — same Values
            // body as `signature`, selected by the verb byte.
            ResponseFrame::Ok {
                verb: verb::GRAM,
                body: OkBody::Values {
                    shape: vec![2, 2],
                    values: vec![1.25, 0.0, 0.0, 8.0],
                },
            },
            ResponseFrame::Ok {
                verb: verb::STREAM_OPEN,
                body: OkBody::Opened {
                    session: 9,
                    out_dim: 6,
                },
            },
            ResponseFrame::Ok {
                verb: verb::STREAM_PUSH,
                body: OkBody::Pushed { pushed: 4, seen: 8 },
            },
            ResponseFrame::Err {
                verb: verb::STREAM_PUSH,
                code: errcode::UNKNOWN_SESSION,
                message: "unknown session 's9' (already closed or evicted)".into(),
            },
            ResponseFrame::Shed {
                verb: verb::STREAM_PUSH,
                retry_after_ms: 25,
                message: "overloaded; retry after 25 ms".into(),
            },
        ];
        for f in cases {
            let bytes = f.encode();
            let back = ResponseFrame::decode(bytes[1], &bytes[6..]).unwrap();
            assert_eq!(back, f);
        }
    }

    #[test]
    fn decoder_rejects_malformed() {
        // Truncated payload.
        assert!(RequestFrame::decode(verb::STREAM_CLOSE, &[1, 2, 3]).is_err());
        // Trailing bytes.
        let mut bytes = RequestFrame::StreamClose { session: 1 }.encode();
        bytes.push(0xFF);
        assert!(RequestFrame::decode(bytes[1], &bytes[6..]).is_err());
        // Unknown verb.
        assert!(RequestFrame::decode(0x77, &[]).is_err());
        // Hostile count field: claims 2^31 floats in a 12-byte payload.
        let mut p = Vec::new();
        p.extend_from_slice(&7u64.to_le_bytes());
        p.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        assert!(RequestFrame::decode(verb::STREAM_PUSH, &p).is_err());
        // Bad projection tag.
        let mut p = Vec::new();
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&2u32.to_le_bytes());
        p.push(9); // tag 9 does not exist
        assert!(RequestFrame::decode(verb::SIGNATURE, &p).is_err());
    }

    #[test]
    fn into_request_validates_like_v1() {
        // dim 0.
        assert!(RequestFrame::Signature {
            dim: 0,
            depth: 2,
            spec: SpecFrame::Truncated,
            path: vec![1.0],
        }
        .into_request()
        .is_err());
        // Path not divisible by dim.
        assert!(RequestFrame::Signature {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Truncated,
            path: vec![1.0, 2.0, 3.0],
        }
        .into_request()
        .is_err());
        // Window over the cap.
        assert!(RequestFrame::StreamOpen {
            dim: 1,
            depth: 2,
            window: (MAX_STREAM_WINDOW + 1) as u32,
            spec: SpecFrame::Truncated,
        }
        .into_request()
        .is_err());
        // Empty push.
        assert!(RequestFrame::StreamPush {
            session: 1,
            samples: vec![],
        }
        .into_request()
        .is_err());
        // Anisotropic gamma length mismatch.
        assert!(RequestFrame::Signature {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Anisotropic {
                gamma: vec![1.0],
                cutoff: 2.0,
            },
            path: vec![0.0, 0.0, 1.0, 1.0],
        }
        .into_request()
        .is_err());
        // Session handles are canonical.
        let req = RequestFrame::StreamClose { session: 7 }.into_request().unwrap();
        assert_eq!(req.session, "s7");
        assert_eq!(req.op, RequestOp::StreamClose);
    }

    #[test]
    fn gram_into_request_validates_like_v1() {
        let gram = |paths: Vec<Vec<f64>>| RequestFrame::Gram {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Truncated,
            paths,
        };
        // Happy path: batch count recorded, rows flattened in order.
        let req = gram(vec![vec![0.0, 0.0, 1.0, 0.5], vec![0.0, 0.0, -1.0, 2.0]])
            .into_request()
            .unwrap();
        assert_eq!(req.op, RequestOp::Gram);
        assert_eq!(req.batch, 2);
        assert_eq!(req.path, vec![0.0, 0.0, 1.0, 0.5, 0.0, 0.0, -1.0, 2.0]);
        // Empty batch.
        assert!(gram(vec![]).into_request().is_err());
        // Ragged rows.
        assert!(gram(vec![vec![0.0, 0.0, 1.0, 0.5], vec![0.0, 0.0]])
            .into_request()
            .is_err());
        // Row length not divisible by dim.
        assert!(gram(vec![vec![0.0, 0.0, 1.0]]).into_request().is_err());
        // Over the batch cap.
        assert!(gram(vec![vec![0.0, 0.0]; MAX_GRAM_BATCH + 1])
            .into_request()
            .is_err());
        // The decoder rejects an over-cap count before allocating rows.
        let mut p = Vec::new();
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&2u32.to_le_bytes());
        p.push(0); // truncated spec
        p.extend_from_slice(&((MAX_GRAM_BATCH + 1) as u32).to_le_bytes());
        assert!(RequestFrame::decode(verb::GRAM, &p).is_err());
    }

    #[test]
    fn non_finite_coordinates_rejected_like_v1() {
        // The error string must match v1's byte-for-byte (the goldens
        // pin both); the index is into the flattened batch for gram.
        let err = RequestFrame::Signature {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Truncated,
            path: vec![0.0, 0.0, f64::NAN, 1.0],
        }
        .into_request()
        .unwrap_err();
        assert_eq!(err, "non-finite value (NaN or Inf) at index 2 of 'path'");
        let err = RequestFrame::Gram {
            dim: 2,
            depth: 2,
            spec: SpecFrame::Truncated,
            paths: vec![vec![0.0, 0.0, 1.0, 1.0], vec![0.0, f64::NEG_INFINITY, 2.0, 0.0]],
        }
        .into_request()
        .unwrap_err();
        assert_eq!(err, "non-finite value (NaN or Inf) at index 5 of 'paths'");
        let err = RequestFrame::StreamPush {
            session: 1,
            samples: vec![0.5, f64::INFINITY],
        }
        .into_request()
        .unwrap_err();
        assert_eq!(err, "non-finite value (NaN or Inf) at index 1 of 'samples'");
    }

    #[test]
    fn health_is_server_answered_and_validates() {
        // `health` never lowers into a service request…
        assert!(RequestFrame::Health.into_request().is_err());
        // …its empty request payload rejects trailing bytes…
        assert!(RequestFrame::decode(verb::HEALTH, &[0]).is_err());
        // …and a response with a junk degraded byte does not decode.
        let mut p = vec![verb::HEALTH, 1u8, 2u8];
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&0u64.to_le_bytes());
        assert!(ResponseFrame::decode(status::OK, &p).is_err());
    }

    #[test]
    fn backoff_is_seeded_capped_and_honors_hints() {
        let policy = RetryPolicy {
            max_attempts: 6,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(80),
            seed: 42,
        };
        // Deterministic: the same seed replays the same schedule.
        let sched = |seed: u64| -> Vec<Duration> {
            let mut rng = Rng::new(seed);
            (0..6).map(|k| policy.backoff(k, 0, &mut rng)).collect()
        };
        assert_eq!(sched(42), sched(42));
        assert_ne!(sched(42), sched(43));
        // Jitter stays under the exponential cap, which saturates.
        let mut rng = Rng::new(7);
        for k in 0..40 {
            let d = policy.backoff(k, 0, &mut rng);
            let cap = policy
                .base_delay
                .saturating_mul(1u32.checked_shl(k).unwrap_or(u32::MAX))
                .min(policy.max_delay);
            assert!(d <= cap, "attempt {k}: {d:?} > {cap:?}");
        }
        // A server shed hint floors the draw — never retry earlier
        // than asked.
        let mut rng = Rng::new(7);
        for k in 0..8 {
            assert!(policy.backoff(k, 500, &mut rng) >= Duration::from_millis(500));
        }
    }

    #[test]
    fn error_code_mapping() {
        assert_eq!(
            code_for("unknown session 's1' (already closed or evicted)"),
            errcode::UNKNOWN_SESSION
        );
        assert_eq!(code_for("dim must be ≥ 1"), errcode::BAD_REQUEST);
    }

    /// Stub server answering each ping with a scripted response; counts
    /// frames seen. `sheds_before_ok = u32::MAX` sheds forever.
    fn shed_server(sheds_before_ok: u32) -> (String, std::thread::JoinHandle<u32>) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut served = 0u32;
            let mut hdr = [0u8; 6];
            while s.read_exact(&mut hdr).is_ok() {
                let resp = if served < sheds_before_ok {
                    ResponseFrame::Shed {
                        verb: verb::PING,
                        retry_after_ms: 1,
                        message: "overloaded; retry after 1 ms".into(),
                    }
                } else {
                    ResponseFrame::Ok {
                        verb: verb::PING,
                        body: OkBody::Empty,
                    }
                };
                served += 1;
                if s.write_all(&resp.encode()).is_err() {
                    break;
                }
            }
            served
        });
        (addr, h)
    }

    fn fast_policy(max_attempts: u32) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(4),
            seed: 11,
        }
    }

    #[test]
    fn call_retry_rides_out_sheds() {
        let (addr, h) = shed_server(2);
        let mut c = WireClient::connect(&addr).unwrap();
        match c.call_retry(&RequestFrame::Ping, &fast_policy(5)).unwrap() {
            ResponseFrame::Ok {
                body: OkBody::Empty,
                ..
            } => {}
            other => panic!("expected Ok after 2 sheds, got {other:?}"),
        }
        drop(c);
        assert_eq!(h.join().unwrap(), 3, "2 sheds + 1 ok");
    }

    #[test]
    fn call_retry_attempts_are_bounded() {
        let (addr, h) = shed_server(u32::MAX);
        let mut c = WireClient::connect(&addr).unwrap();
        match c.call_retry(&RequestFrame::Ping, &fast_policy(3)).unwrap() {
            ResponseFrame::Shed { retry_after_ms, .. } => assert_eq!(retry_after_ms, 1),
            other => panic!("expected the last shed back, got {other:?}"),
        }
        drop(c);
        assert_eq!(h.join().unwrap(), 3, "exactly max_attempts frames sent");
    }

    #[test]
    fn connect_retry_gives_up_after_bounded_attempts() {
        // Grab a free port, then close the listener: connects are
        // refused fast, so three 1–4 ms backoffs finish well under the
        // deadline that would indicate unbounded retrying.
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        let t0 = std::time::Instant::now();
        assert!(WireClient::connect_retry(&addr, &fast_policy(3)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
        // And against a live server it succeeds on the first try.
        let (addr, h) = shed_server(0);
        let mut c = WireClient::connect_retry(&addr, &fast_policy(3)).unwrap();
        assert!(matches!(
            c.call(&RequestFrame::Ping).unwrap(),
            ResponseFrame::Ok { .. }
        ));
        drop(c);
        h.join().unwrap();
    }
}
