//! TCP JSON-lines front end.
//!
//! One connection = one client; each line is an independent request and
//! receives exactly one response line (requests on a connection are
//! handled sequentially per connection, batched *across* connections by
//! the [`super::Batcher`]). `{"op": "ping"}` health-checks;
//! `{"op": "metrics"}` returns the metrics snapshot.

use super::batcher::{Batcher, BatcherConfig};
use super::protocol::{parse_request, RequestOp, Response};
use super::service::{SigService, StreamReply};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `"127.0.0.1:7717"` (`:0` for an OS port).
    pub addr: String,
    /// Dynamic-batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7717".to_string(),
            batcher: BatcherConfig::default(),
        }
    }
}

/// A running server handle (owned listener + sweeper threads and the
/// shutdown flag).
pub struct ServerHandle {
    /// The address the listener actually bound (resolves `:0`).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    sweep_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and join the accept loop and session sweeper.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sweep_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Start the feature server; returns once the listener is bound.
pub fn serve(service: Arc<SigService>, config: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&config.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let batcher = Arc::new(Batcher::new(Arc::clone(&service), config.batcher));
    // Background session sweeper: streaming sessions must be reclaimed
    // by the idle TTL even when no stream traffic arrives to trigger
    // the in-band sweep (the sweep itself is throttled service-side,
    // so the short poll period costs nothing between real sweeps).
    let sweep_thread = {
        let stop = Arc::clone(&stop);
        let svc = Arc::clone(&service);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(std::time::Duration::from_millis(100));
                svc.evict_idle();
            }
        })
    };
    let accept_thread = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let svc = Arc::clone(&service);
                        let bat = Arc::clone(&batcher);
                        std::thread::spawn(move || handle_connection(stream, svc, bat));
                    }
                    Err(_) => continue,
                }
            }
        })
    };
    Ok(ServerHandle {
        addr,
        stop,
        accept_thread: Some(accept_thread),
        sweep_thread: Some(sweep_thread),
    })
}

fn handle_connection(stream: TcpStream, service: Arc<SigService>, batcher: Arc<Batcher>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let resp = handle_line(&line, &service, &batcher);
        let ok = !matches!(resp, Response::Err { .. });
        service.metrics.record_request(t0.elapsed(), ok);
        let mut out = resp.to_line();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

fn handle_line(line: &str, service: &Arc<SigService>, batcher: &Arc<Batcher>) -> Response {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            return Response::Err {
                id: String::new(),
                error: e,
            }
        }
    };
    let id = req.id.clone();
    match req.op {
        RequestOp::Ping => Response::Json {
            id,
            body: crate::util::json::Json::obj(vec![(
                "pong",
                crate::util::json::Json::Bool(true),
            )]),
        },
        RequestOp::Metrics => Response::Json {
            id,
            body: service.metrics.snapshot(),
        },
        // Stateful session ops: routed straight to the session table
        // (never batched — ordering within a session matters, and a
        // connection's requests are handled sequentially).
        op if op.is_stream() => {
            let t0 = Instant::now();
            match service.execute_stream(&req) {
                Ok(StreamReply::Values { result, shape }) => Response::Ok {
                    id,
                    result,
                    shape,
                    backend: "native",
                    latency_us: t0.elapsed().as_micros() as u64,
                },
                Ok(StreamReply::Opened { session, out_dim }) => Response::Json {
                    id,
                    body: Json::obj(vec![
                        ("session", Json::str(&session)),
                        ("out_dim", Json::Num(out_dim as f64)),
                    ]),
                },
                Ok(StreamReply::Pushed { pushed, seen }) => Response::Json {
                    id,
                    body: Json::obj(vec![
                        ("pushed", Json::Num(pushed as f64)),
                        ("seen", Json::Num(seen as f64)),
                    ]),
                },
                Ok(StreamReply::Closed) => Response::Json {
                    id,
                    body: Json::obj(vec![("closed", Json::Bool(true))]),
                },
                Err(error) => Response::Err { id, error },
            }
        }
        _ => {
            let t0 = Instant::now();
            match batcher.submit(req) {
                Ok((result, shape, backend)) => Response::Ok {
                    id,
                    result,
                    shape,
                    backend,
                    latency_us: t0.elapsed().as_micros() as u64,
                },
                Err(error) => Response::Err { id, error },
            }
        }
    }
}

/// Minimal blocking client (used by tests, examples and the CLI).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Open a TCP connection to a running feature server.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: stream,
        })
    }

    /// Send one JSON line, read one JSON line back.
    pub fn call(&mut self, request: &str) -> std::io::Result<crate::util::json::Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        crate::util::json::Json::parse(&line).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad response: {e}"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_test_server() -> (ServerHandle, String) {
        let service = Arc::new(SigService::new(None));
        let handle = serve(
            service,
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
            },
        )
        .unwrap();
        let addr = handle.addr.to_string();
        (handle, addr)
    }

    #[test]
    fn ping_and_signature_roundtrip() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        let pong = client.call(r#"{"op":"ping","id":"p1"}"#).unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        assert_eq!(pong.get("id").as_str(), Some("p1"));

        let resp = client
            .call(r#"{"op":"signature","id":"s1","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#)
            .unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        let result = resp.f64_vec("result");
        assert_eq!(result.len(), 6);
        assert!((result[0] - 1.0).abs() < 1e-9);
        handle.shutdown();
    }

    #[test]
    fn error_responses_are_json() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.call(r#"{"op":"signature","dim":0}"#).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(resp.get("error").as_str().is_some());
        // Connection still usable afterwards.
        let pong = client.call(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        handle.shutdown();
    }

    #[test]
    fn metrics_reflect_traffic() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        for _ in 0..3 {
            client
                .call(r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#)
                .unwrap();
        }
        let m = client.call(r#"{"op":"metrics"}"#).unwrap();
        let body = m.get("body");
        assert!(body.get("requests_total").as_usize().unwrap() >= 3);
        assert!(body.get("batches_total").as_usize().unwrap() >= 1);
        handle.shutdown();
    }

    #[test]
    fn stream_session_roundtrip_over_tcp() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        let opened = client
            .call(r#"{"op":"stream_open","id":"o1","dim":1,"depth":2,"window":2}"#)
            .unwrap();
        assert_eq!(opened.get("ok").as_bool(), Some(true));
        let session = opened.get("body").get("session").as_str().unwrap().to_string();

        let pushed = client
            .call(&format!(
                r#"{{"op":"stream_push","session":"{session}","samples":[0,1,3,6]}}"#
            ))
            .unwrap();
        assert_eq!(pushed.get("body").get("seen").as_usize(), Some(4));

        let win = client
            .call(&format!(r#"{{"op":"stream_window","session":"{session}"}}"#))
            .unwrap();
        let result = win.f64_vec("result");
        assert!((result[0] - 5.0).abs() < 1e-9, "window level 1: {result:?}");

        let closed = client
            .call(&format!(r#"{{"op":"stream_close","session":"{session}"}}"#))
            .unwrap();
        assert_eq!(closed.get("body").get("closed").as_bool(), Some(true));

        // The session is gone; the error is a JSON response and the
        // connection (and server) stay alive.
        let err = client
            .call(&format!(r#"{{"op":"stream_window","session":"{session}"}}"#))
            .unwrap();
        assert_eq!(err.get("ok").as_bool(), Some(false));
        let pong = client.call(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        handle.shutdown();
    }

    #[test]
    fn idle_sessions_reclaimed_without_stream_traffic() {
        // The background sweeper must enforce the TTL even when no
        // further stream verbs arrive to trigger the in-band sweep.
        let mut service = SigService::new(None);
        service.session_ttl = std::time::Duration::from_millis(200);
        let service = Arc::new(service);
        let handle = serve(
            Arc::clone(&service),
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
            },
        )
        .unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let opened = client
            .call(r#"{"op":"stream_open","dim":1,"depth":2,"window":4}"#)
            .unwrap();
        assert_eq!(opened.get("ok").as_bool(), Some(true));
        assert_eq!(service.session_count(), 1);
        // Silence: only the sweeper thread can reclaim the session.
        std::thread::sleep(std::time::Duration::from_millis(800));
        assert_eq!(service.session_count(), 0, "sweeper did not reclaim idle session");
        assert_eq!(
            service.metrics.sessions_evicted.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients_get_correct_results() {
        let (handle, addr) = start_test_server();
        let mut joins = Vec::new();
        for k in 1..=6u32 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let s = k as f64;
                let req = format!(
                    r#"{{"op":"signature","dim":1,"depth":2,"path":[0,{s}]}}"#
                );
                let resp = c.call(&req).unwrap();
                let out = resp.f64_vec("result");
                assert!((out[0] - s).abs() < 1e-9, "client {k}: {out:?}");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        handle.shutdown();
    }
}
