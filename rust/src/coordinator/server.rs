//! TCP front end speaking both wire protocols.
//!
//! One connection = one client; requests on a connection are handled
//! sequentially (batched *across* connections by the
//! [`super::Batcher`]). The protocol is disambiguated **per message**
//! on the first byte: `0x02` starts a v2 binary frame
//! ([`super::wire`]); anything else (a JSON line starts with `{` =
//! `0x7B`) is a v1 JSON-lines request ([`super::protocol`]) — so
//! deployed v1 clients keep working unchanged against a v2 server.
//!
//! Session TTL enforcement needs no server-side sweeper thread: each
//! shard worker sweeps its own slice on idle ticks (see
//! [`super::shard`]). When a stream op hits a full shard mailbox the
//! server answers a load-shed response (v1: `retry_after_ms` field;
//! v2: a `shed` frame) instead of blocking the connection thread.

use super::batcher::{Batcher, BatcherConfig};
use super::protocol::{parse_request, RequestOp, Response};
use super::service::{SigService, StreamReply};
use super::shard::StreamError;
use super::wire;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `"127.0.0.1:7717"` (`:0` for an OS port).
    pub addr: String,
    /// Dynamic-batching policy.
    pub batcher: BatcherConfig,
    /// Admission cap on concurrently served connections
    /// (`--max-conns`); `0` (the default) = unlimited. A connection
    /// over the cap is answered with a single v1 shed line and closed
    /// — it never gets a thread, so a connect flood cannot exhaust
    /// server threads/fds. (The reject line is v1 JSON because no byte
    /// has been read yet to know the client's protocol; the v2
    /// `WireClient` surfaces it as an IO error and its retry path
    /// reconnects.)
    pub max_conns: usize,
    /// Per-connection IO deadline (`--conn-timeout-s`); `None` (the
    /// default) keeps today's fully blocking behavior. When set, it
    /// bounds **three** things at once: each socket read/write, how
    /// long an idle connection may sit between requests, and — as a
    /// per-frame budget — how long a v2 frame may take *end to end*,
    /// so a client dripping one byte per tick cannot pin a connection
    /// thread (slow-loris).
    pub conn_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7717".to_string(),
            batcher: BatcherConfig::default(),
            max_conns: 0,
            conn_timeout: None,
        }
    }
}

/// A running server handle (owned listener thread and shutdown flag).
pub struct ServerHandle {
    /// The address the listener actually bound (resolves `:0`).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Decrements the live-connection gauge when a connection thread ends
/// — by clean EOF, timeout, IO error, *or panic* (the drop runs during
/// unwind), so an admission slot can never leak.
struct ConnSlot(Arc<super::Metrics>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.conns_active.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Answer an over-cap connection with one v1 shed line and hang up,
/// without ever blocking the acceptor on a slow peer.
fn reject_connection(mut stream: TcpStream, retry_after_ms: u64) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let mut line = Response::Shed {
        id: String::new(),
        error: format!("server at connection capacity; retry after {retry_after_ms} ms"),
        retry_after_ms,
    }
    .to_line();
    line.push('\n');
    let _ = stream.write_all(line.as_bytes());
}

/// Start the feature server; returns once the listener is bound.
pub fn serve(service: Arc<SigService>, config: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&config.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let batcher = Arc::new(Batcher::new(Arc::clone(&service), config.batcher));
    let (max_conns, conn_timeout) = (config.max_conns, config.conn_timeout);
    let accept_thread = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // Admission control: reserve the slot *here* (a
                // compare-and-swap against the gauge) so a burst of
                // accepts cannot overshoot the cap before the
                // connection threads start.
                let metrics = Arc::clone(&service.metrics);
                if max_conns > 0 {
                    let admitted = metrics
                        .conns_active
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                            if (n as usize) < max_conns {
                                Some(n + 1)
                            } else {
                                None
                            }
                        })
                        .is_ok();
                    if !admitted {
                        metrics.conns_rejected.fetch_add(1, Ordering::Relaxed);
                        reject_connection(stream, service.shed_retry_ms);
                        continue;
                    }
                } else {
                    metrics.conns_active.fetch_add(1, Ordering::Relaxed);
                }
                let svc = Arc::clone(&service);
                let bat = Arc::clone(&batcher);
                std::thread::spawn(move || {
                    let _slot = ConnSlot(metrics);
                    handle_connection(stream, svc, bat, conn_timeout);
                });
            }
        })
    };
    Ok(ServerHandle {
        addr,
        stop,
        accept_thread: Some(accept_thread),
    })
}

/// What a v2 frame handler decided about the connection.
enum V2Outcome {
    /// Send these bytes and keep reading.
    Reply(Vec<u8>),
    /// Send these bytes, then close — the byte stream can no longer be
    /// trusted to be frame-aligned (e.g. an oversized length prefix).
    ReplyAndClose(Vec<u8>),
}

/// Whether an IO error is a socket-timeout expiry (Unix reports
/// `WouldBlock`, Windows `TimedOut` — and the slow-frame budget raises
/// `TimedOut` directly).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// `read_exact` against an absolute deadline: each chunk read gets a
/// socket timeout of exactly the time remaining, so a peer dripping
/// one byte per tick exhausts the *frame* budget instead of resetting
/// a per-read one. `None` = no deadline, plain blocking `read_exact`.
fn read_exact_deadline(
    reader: &mut BufReader<TcpStream>,
    buf: &mut [u8],
    deadline: Option<Instant>,
) -> std::io::Result<()> {
    let Some(deadline) = deadline else {
        return reader.read_exact(buf);
    };
    let mut filled = 0usize;
    while filled < buf.len() {
        let now = Instant::now();
        if now >= deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "frame overran its slow-frame budget",
            ));
        }
        let _ = reader.get_ref().set_read_timeout(Some(deadline - now));
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Err(std::io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn handle_connection(
    stream: TcpStream,
    service: Arc<SigService>,
    batcher: Arc<Batcher>,
    timeout: Option<Duration>,
) {
    // The base socket timeouts double as the idle deadline: a
    // connection that sends nothing for `timeout` is closed, freeing
    // its thread. (Reader and writer share one fd, so the settings
    // cover both clones.)
    if let Some(t) = timeout {
        let _ = stream.set_read_timeout(Some(t));
        let _ = stream.set_write_timeout(Some(t));
    }
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    loop {
        // Chaos hook (no-op without the `failpoints` feature): a
        // `server.read` fault models a peer whose socket died.
        if crate::util::failpoint::check("server.read").is_some() {
            break;
        }
        // Peek the first byte of the next message to pick the protocol.
        let first = match reader.fill_buf() {
            Ok([]) => break, // clean EOF
            Ok(buf) => buf[0],
            Err(e) => {
                if is_timeout(&e) {
                    // Idle past the deadline.
                    service
                        .metrics
                        .conn_timeouts
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                break;
            }
        };
        if first == wire::WIRE_V2 {
            // The whole frame — header, payload, however many reads —
            // shares one absolute deadline (the slow-frame budget).
            let deadline = timeout.map(|t| Instant::now() + t);
            let t0 = Instant::now();
            let (outcome, ok) = handle_v2_frame(&mut reader, &service, &batcher, deadline);
            // Restore the base per-read timeout the deadline reads
            // shrank, so the next message's idle clock starts fresh.
            if let Some(t) = timeout {
                let _ = reader.get_ref().set_read_timeout(Some(t));
            }
            service.metrics.record_request(t0.elapsed(), ok);
            if crate::util::failpoint::check("server.write").is_some() {
                break;
            }
            match outcome {
                Some(V2Outcome::Reply(bytes)) => {
                    if let Err(e) = writer.write_all(&bytes) {
                        if is_timeout(&e) {
                            service
                                .metrics
                                .conn_timeouts
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        break;
                    }
                }
                Some(V2Outcome::ReplyAndClose(bytes)) => {
                    let _ = writer.write_all(&bytes);
                    break;
                }
                None => break, // read error mid-frame
            }
        } else {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Err(e) => {
                    if is_timeout(&e) {
                        service
                            .metrics
                            .conn_timeouts
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    break;
                }
                Ok(_) => {}
            }
            if line.trim().is_empty() {
                continue;
            }
            let t0 = Instant::now();
            let resp = handle_line(&line, &service, &batcher);
            let ok = !matches!(resp, Response::Err { .. } | Response::Shed { .. });
            service.metrics.record_request(t0.elapsed(), ok);
            let mut out = resp.to_line();
            out.push('\n');
            if crate::util::failpoint::check("server.write").is_some() {
                break;
            }
            if let Err(e) = writer.write_all(out.as_bytes()) {
                if is_timeout(&e) {
                    service
                        .metrics
                        .conn_timeouts
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                break;
            }
        }
    }
}

/// Read and execute one v2 frame. Returns the outcome plus whether the
/// request succeeded (for the request metrics); `None` means the
/// socket died mid-frame.
fn handle_v2_frame(
    reader: &mut BufReader<TcpStream>,
    service: &Arc<SigService>,
    batcher: &Arc<Batcher>,
    deadline: Option<Instant>,
) -> (Option<V2Outcome>, bool) {
    use wire::{errcode, OkBody, RequestFrame, ResponseFrame};
    let timed_out = |e: &std::io::Error| {
        if is_timeout(e) {
            service
                .metrics
                .conn_timeouts
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    };
    let mut header = [0u8; 6];
    if let Err(e) = read_exact_deadline(reader, &mut header, deadline) {
        timed_out(&e);
        return (None, false);
    }
    let verb = header[1];
    let len = u32::from_le_bytes(header[2..6].try_into().unwrap()) as usize;
    if len > wire::MAX_FRAME_LEN {
        // The declared payload is absurd; draining it would let one
        // client pin a connection thread, and skipping it desyncs the
        // stream. Answer and hang up.
        let resp = ResponseFrame::Err {
            verb,
            code: errcode::BAD_FRAME,
            message: format!("frame length {len} exceeds cap {}", wire::MAX_FRAME_LEN),
        };
        return (Some(V2Outcome::ReplyAndClose(resp.encode())), false);
    }
    let mut payload = vec![0u8; len];
    if let Err(e) = read_exact_deadline(reader, &mut payload, deadline) {
        timed_out(&e);
        return (None, false);
    }
    // From here the stream is frame-aligned again regardless of what
    // the payload contains, so errors keep the connection open.
    let frame = match RequestFrame::decode(verb, &payload) {
        Ok(f) => f,
        Err(e) => {
            let code = if e.starts_with("unknown verb") {
                errcode::UNSUPPORTED
            } else {
                errcode::BAD_FRAME
            };
            let resp = ResponseFrame::Err {
                verb,
                code,
                message: e,
            };
            return (Some(V2Outcome::Reply(resp.encode())), false);
        }
    };
    // `health` is answered straight from the metrics registry — it
    // never lowers into a service request (its body is v2-only; the v1
    // surface for the same facts is the `stats` verb's `degraded` /
    // `journal_strict_rejects` fields).
    if frame == RequestFrame::Health {
        let relaxed = std::sync::atomic::Ordering::Relaxed;
        let m = &service.metrics;
        let resp = ResponseFrame::Ok {
            verb,
            body: OkBody::Health {
                mode: match service.durability {
                    crate::persist::DurabilityMode::Strict => 1,
                    crate::persist::DurabilityMode::Degraded => 0,
                },
                degraded: m.degraded.load(relaxed) != 0,
                journal_errors: m.journal_errors.load(relaxed),
                strict_rejects: m.journal_strict_rejects.load(relaxed),
            },
        };
        return (Some(V2Outcome::Reply(resp.encode())), true);
    }
    let req = match frame.into_request() {
        Ok(r) => r,
        Err(e) => {
            let resp = ResponseFrame::Err {
                verb,
                code: errcode::BAD_REQUEST,
                message: e,
            };
            return (Some(V2Outcome::Reply(resp.encode())), false);
        }
    };
    let resp = match req.op {
        RequestOp::Ping => ResponseFrame::Ok {
            verb,
            body: OkBody::Empty,
        },
        RequestOp::Stats => ResponseFrame::Ok {
            verb,
            body: OkBody::Stats {
                shards: service.shard_set().stats(),
                cache: service.cache_stats(),
            },
        },
        RequestOp::Metrics => ResponseFrame::Err {
            verb,
            code: errcode::UNSUPPORTED,
            message: "metrics is a v1-only verb; use stats".into(),
        },
        op if op.is_stream() => match service.execute_stream(&req) {
            Ok(StreamReply::Opened { session, out_dim }) => {
                // The handle is always canonical "s<id>".
                let id = session
                    .strip_prefix('s')
                    .and_then(|n| n.parse::<u64>().ok())
                    .unwrap_or(0);
                ResponseFrame::Ok {
                    verb,
                    body: OkBody::Opened {
                        session: id,
                        out_dim: out_dim as u32,
                    },
                }
            }
            Ok(StreamReply::Pushed { pushed, seen }) => ResponseFrame::Ok {
                verb,
                body: OkBody::Pushed {
                    pushed: pushed as u64,
                    seen: seen as u64,
                },
            },
            Ok(StreamReply::Values { result, shape }) => ResponseFrame::Ok {
                verb,
                body: OkBody::Values {
                    shape: shape.iter().map(|&s| s as u32).collect(),
                    values: result,
                },
            },
            Ok(StreamReply::Closed) => ResponseFrame::Ok {
                verb,
                body: OkBody::Empty,
            },
            Err(StreamError::Shed { retry_after_ms }) => {
                service
                    .metrics
                    .requests_shed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                ResponseFrame::Shed {
                    verb,
                    retry_after_ms: retry_after_ms as u32,
                    message: format!("overloaded; retry after {retry_after_ms} ms"),
                }
            }
            Err(StreamError::Msg(m)) => ResponseFrame::Err {
                verb,
                code: wire::code_for(&m),
                message: m,
            },
        },
        _ => match batcher.submit(req) {
            Ok((result, shape, _backend)) => ResponseFrame::Ok {
                verb,
                body: OkBody::Values {
                    shape: shape.iter().map(|&s| s as u32).collect(),
                    values: result,
                },
            },
            Err(e) => ResponseFrame::Err {
                verb,
                code: errcode::BAD_REQUEST,
                message: e,
            },
        },
    };
    let ok = matches!(resp, ResponseFrame::Ok { .. });
    (Some(V2Outcome::Reply(resp.encode())), ok)
}

fn handle_line(line: &str, service: &Arc<SigService>, batcher: &Arc<Batcher>) -> Response {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            return Response::Err {
                id: String::new(),
                error: e,
            }
        }
    };
    let id = req.id.clone();
    match req.op {
        RequestOp::Ping => Response::Json {
            id,
            body: crate::util::json::Json::obj(vec![(
                "pong",
                crate::util::json::Json::Bool(true),
            )]),
        },
        RequestOp::Metrics => Response::Json {
            id,
            body: service.metrics.snapshot(),
        },
        RequestOp::Stats => Response::Json {
            id,
            body: service.stats_json(),
        },
        // Stateful session ops: routed straight to the sharded session
        // table (never batched — ordering within a session matters, and
        // a connection's requests are handled sequentially).
        op if op.is_stream() => {
            let t0 = Instant::now();
            match service.execute_stream(&req) {
                Ok(StreamReply::Values { result, shape }) => Response::Ok {
                    id,
                    result,
                    shape,
                    backend: "native",
                    latency_us: t0.elapsed().as_micros() as u64,
                },
                Ok(StreamReply::Opened { session, out_dim }) => Response::Json {
                    id,
                    body: Json::obj(vec![
                        ("session", Json::str(&session)),
                        ("out_dim", Json::Num(out_dim as f64)),
                    ]),
                },
                Ok(StreamReply::Pushed { pushed, seen }) => Response::Json {
                    id,
                    body: Json::obj(vec![
                        ("pushed", Json::Num(pushed as f64)),
                        ("seen", Json::Num(seen as f64)),
                    ]),
                },
                Ok(StreamReply::Closed) => Response::Json {
                    id,
                    body: Json::obj(vec![("closed", Json::Bool(true))]),
                },
                Err(StreamError::Shed { retry_after_ms }) => {
                    service
                        .metrics
                        .requests_shed
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    Response::Shed {
                        id,
                        error: format!("overloaded; retry after {retry_after_ms} ms"),
                        retry_after_ms,
                    }
                }
                Err(StreamError::Msg(error)) => Response::Err { id, error },
            }
        }
        _ => {
            let t0 = Instant::now();
            match batcher.submit(req) {
                Ok((result, shape, backend)) => Response::Ok {
                    id,
                    result,
                    shape,
                    backend,
                    latency_us: t0.elapsed().as_micros() as u64,
                },
                Err(error) => Response::Err { id, error },
            }
        }
    }
}

/// Minimal blocking v1 (JSON-lines) client — used by tests, examples
/// and the CLI. The binary v2 client is [`super::wire::WireClient`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Open a TCP connection to a running feature server.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: stream,
        })
    }

    /// Send one JSON line, read one JSON line back.
    pub fn call(&mut self, request: &str) -> std::io::Result<crate::util::json::Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        crate::util::json::Json::parse(&line).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad response: {e}"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wire::{
        verb, OkBody, RequestFrame, ResponseFrame, SpecFrame, WireClient,
    };
    use crate::persist::CacheStats;

    fn start_test_server() -> (ServerHandle, String) {
        let service = Arc::new(SigService::new(None));
        let handle = serve(
            service,
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let addr = handle.addr.to_string();
        (handle, addr)
    }

    #[test]
    fn ping_and_signature_roundtrip() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        let pong = client.call(r#"{"op":"ping","id":"p1"}"#).unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        assert_eq!(pong.get("id").as_str(), Some("p1"));

        let resp = client
            .call(r#"{"op":"signature","id":"s1","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#)
            .unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        let result = resp.f64_vec("result");
        assert_eq!(result.len(), 6);
        assert!((result[0] - 1.0).abs() < 1e-9);
        handle.shutdown();
    }

    #[test]
    fn error_responses_are_json() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.call(r#"{"op":"signature","dim":0}"#).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(resp.get("error").as_str().is_some());
        // Connection still usable afterwards.
        let pong = client.call(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        handle.shutdown();
    }

    #[test]
    fn metrics_reflect_traffic() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        for _ in 0..3 {
            client
                .call(r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#)
                .unwrap();
        }
        let m = client.call(r#"{"op":"metrics"}"#).unwrap();
        let body = m.get("body");
        assert!(body.get("requests_total").as_usize().unwrap() >= 3);
        assert!(body.get("batches_total").as_usize().unwrap() >= 1);
        handle.shutdown();
    }

    #[test]
    fn stream_session_roundtrip_over_tcp() {
        let (handle, addr) = start_test_server();
        let mut client = Client::connect(&addr).unwrap();
        let opened = client
            .call(r#"{"op":"stream_open","id":"o1","dim":1,"depth":2,"window":2}"#)
            .unwrap();
        assert_eq!(opened.get("ok").as_bool(), Some(true));
        let session = opened.get("body").get("session").as_str().unwrap().to_string();

        let pushed = client
            .call(&format!(
                r#"{{"op":"stream_push","session":"{session}","samples":[0,1,3,6]}}"#
            ))
            .unwrap();
        assert_eq!(pushed.get("body").get("seen").as_usize(), Some(4));

        let win = client
            .call(&format!(r#"{{"op":"stream_window","session":"{session}"}}"#))
            .unwrap();
        let result = win.f64_vec("result");
        assert!((result[0] - 5.0).abs() < 1e-9, "window level 1: {result:?}");

        let closed = client
            .call(&format!(r#"{{"op":"stream_close","session":"{session}"}}"#))
            .unwrap();
        assert_eq!(closed.get("body").get("closed").as_bool(), Some(true));

        // The session is gone; the error is a JSON response and the
        // connection (and server) stay alive.
        let err = client
            .call(&format!(r#"{{"op":"stream_window","session":"{session}"}}"#))
            .unwrap();
        assert_eq!(err.get("ok").as_bool(), Some(false));
        let pong = client.call(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        handle.shutdown();
    }

    #[test]
    fn idle_sessions_reclaimed_without_stream_traffic() {
        // Shard workers must enforce the TTL on their own idle ticks,
        // with no further stream verbs (and no server sweeper thread).
        let mut service = SigService::new(None);
        service.session_ttl = std::time::Duration::from_millis(200);
        let service = Arc::new(service);
        let handle = serve(
            Arc::clone(&service),
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let opened = client
            .call(r#"{"op":"stream_open","dim":1,"depth":2,"window":4}"#)
            .unwrap();
        assert_eq!(opened.get("ok").as_bool(), Some(true));
        assert_eq!(service.session_count(), 1);
        // Silence: only the shard workers can reclaim the session.
        std::thread::sleep(std::time::Duration::from_millis(800));
        assert_eq!(service.session_count(), 0, "workers did not reclaim idle session");
        assert_eq!(
            service.metrics.sessions_evicted.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients_get_correct_results() {
        let (handle, addr) = start_test_server();
        let mut joins = Vec::new();
        for k in 1..=6u32 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let s = k as f64;
                let req = format!(
                    r#"{{"op":"signature","dim":1,"depth":2,"path":[0,{s}]}}"#
                );
                let resp = c.call(&req).unwrap();
                let out = resp.f64_vec("result");
                assert!((out[0] - s).abs() < 1e-9, "client {k}: {out:?}");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        handle.shutdown();
    }

    #[test]
    fn v2_session_lifecycle_over_tcp() {
        let (handle, addr) = start_test_server();
        let mut c = WireClient::connect(&addr).unwrap();
        // ping
        match c.call(&RequestFrame::Ping).unwrap() {
            ResponseFrame::Ok {
                verb: v,
                body: OkBody::Empty,
            } => assert_eq!(v, verb::PING),
            other => panic!("{other:?}"),
        }
        // open
        let session = match c
            .call(&RequestFrame::StreamOpen {
                dim: 1,
                depth: 2,
                window: 2,
                spec: SpecFrame::Truncated,
            })
            .unwrap()
        {
            ResponseFrame::Ok {
                body: OkBody::Opened { session, out_dim },
                ..
            } => {
                assert_eq!(out_dim, 2);
                session
            }
            other => panic!("{other:?}"),
        };
        // push
        match c
            .call(&RequestFrame::StreamPush {
                session,
                samples: vec![0.0, 1.0, 3.0, 6.0],
            })
            .unwrap()
        {
            ResponseFrame::Ok {
                body: OkBody::Pushed { pushed, seen },
                ..
            } => assert_eq!((pushed, seen), (4, 4)),
            other => panic!("{other:?}"),
        }
        // window
        match c
            .call(&RequestFrame::StreamWindow {
                session,
                full: false,
            })
            .unwrap()
        {
            ResponseFrame::Ok {
                body: OkBody::Values { shape, values },
                ..
            } => {
                assert_eq!(shape, vec![2]);
                assert!((values[0] - 5.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
        // stats: one row per shard, at least one session live somewhere
        match c.call(&RequestFrame::Stats).unwrap() {
            ResponseFrame::Ok {
                body: OkBody::Stats { shards: rows, .. },
                ..
            } => {
                assert!(!rows.is_empty());
                assert_eq!(rows.iter().map(|r| r.sessions).sum::<u64>(), 1);
            }
            other => panic!("{other:?}"),
        }
        // stats2 carries the durability fields the frozen stats verb
        // omits; with durability off they must all read zero.
        match c.call(&RequestFrame::Stats2).unwrap() {
            ResponseFrame::Ok {
                body: OkBody::Stats { shards: rows, cache },
                ..
            } => {
                assert_eq!(rows.iter().map(|r| r.sessions).sum::<u64>(), 1);
                assert!(rows.iter().all(|r| r.journal_lag == 0));
                assert_eq!(cache, CacheStats::default());
            }
            other => panic!("{other:?}"),
        }
        // close, then the session is unknown (code 3)
        match c.call(&RequestFrame::StreamClose { session }).unwrap() {
            ResponseFrame::Ok {
                body: OkBody::Empty,
                ..
            } => {}
            other => panic!("{other:?}"),
        }
        match c.call(&RequestFrame::StreamClose { session }).unwrap() {
            ResponseFrame::Err { code, message, .. } => {
                assert_eq!(code, wire::errcode::UNKNOWN_SESSION);
                assert!(message.contains("unknown session"));
            }
            other => panic!("{other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn v2_health_verb_reports_policy_and_counters() {
        let (handle, addr) = start_test_server();
        let mut c = WireClient::connect(&addr).unwrap();
        match c.call(&RequestFrame::Health).unwrap() {
            ResponseFrame::Ok {
                verb: v,
                body:
                    OkBody::Health {
                        mode,
                        degraded,
                        journal_errors,
                        strict_rejects,
                    },
            } => {
                assert_eq!(v, verb::HEALTH);
                // Defaults: degraded policy, healthy, no failures yet.
                assert_eq!(mode, 0);
                assert!(!degraded);
                assert_eq!((journal_errors, strict_rejects), (0, 0));
            }
            other => panic!("{other:?}"),
        }
        // The v1 surface of the same facts lives in `stats`.
        let mut v1 = Client::connect(&addr).unwrap();
        let stats = v1.call(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(stats.get("body").get("degraded").as_bool(), Some(false));
        assert_eq!(
            stats.get("body").get("journal_strict_rejects").as_usize(),
            Some(0)
        );
        handle.shutdown();
    }

    #[test]
    fn v2_signature_matches_v1() {
        let (handle, addr) = start_test_server();
        let mut v1 = Client::connect(&addr).unwrap();
        let mut v2 = WireClient::connect(&addr).unwrap();
        let from_v1 = v1
            .call(r#"{"op":"signature","dim":2,"depth":2,"path":[0,0,1,0,1,1]}"#)
            .unwrap()
            .f64_vec("result");
        let from_v2 = match v2
            .call(&RequestFrame::Signature {
                dim: 2,
                depth: 2,
                spec: SpecFrame::Truncated,
                path: vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0],
            })
            .unwrap()
        {
            ResponseFrame::Ok {
                body: OkBody::Values { values, .. },
                ..
            } => values,
            other => panic!("{other:?}"),
        };
        assert_eq!(from_v1, from_v2);
        handle.shutdown();
    }

    #[test]
    fn v1_and_v2_interleave_on_one_connection() {
        let (handle, addr) = start_test_server();
        // Drive the raw socket by hand: a v1 line, then a v2 frame,
        // then a v1 line again.
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;

        writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");

        writer.write_all(&RequestFrame::Ping.encode()).unwrap();
        match crate::coordinator::wire::read_response(&mut reader).unwrap() {
            ResponseFrame::Ok {
                body: OkBody::Empty,
                ..
            } => {}
            other => panic!("{other:?}"),
        }

        writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        handle.shutdown();
    }

    #[test]
    fn v2_bad_frames_answered_without_killing_server() {
        let (handle, addr) = start_test_server();
        // Unknown verb: connection survives.
        {
            let mut c = WireClient::connect(&addr).unwrap();
            let mut raw = TcpStream::connect(&addr).unwrap();
            raw.write_all(&wire::frame(0x7F, &[])).unwrap();
            let mut reader = BufReader::new(raw.try_clone().unwrap());
            match crate::coordinator::wire::read_response(&mut reader).unwrap() {
                ResponseFrame::Err { code, .. } => {
                    assert_eq!(code, wire::errcode::UNSUPPORTED)
                }
                other => panic!("{other:?}"),
            }
            // Same raw connection still serves a good frame.
            raw.write_all(&RequestFrame::Ping.encode()).unwrap();
            assert!(matches!(
                crate::coordinator::wire::read_response(&mut reader).unwrap(),
                ResponseFrame::Ok { .. }
            ));
            // And an independent client is unaffected.
            assert!(matches!(
                c.call(&RequestFrame::Ping).unwrap(),
                ResponseFrame::Ok { .. }
            ));
        }
        // Oversized length prefix: error frame, then the server closes.
        {
            let mut raw = TcpStream::connect(&addr).unwrap();
            let mut hdr = vec![wire::WIRE_V2, verb::PING];
            hdr.extend_from_slice(&(u32::MAX).to_le_bytes());
            raw.write_all(&hdr).unwrap();
            let mut reader = BufReader::new(raw.try_clone().unwrap());
            match crate::coordinator::wire::read_response(&mut reader).unwrap() {
                ResponseFrame::Err { code, .. } => assert_eq!(code, wire::errcode::BAD_FRAME),
                other => panic!("{other:?}"),
            }
            let mut rest = Vec::new();
            let n = reader.read_to_end(&mut rest).unwrap_or(0);
            assert_eq!(n, 0, "server should close after an oversized prefix");
        }
        // The server is still healthy.
        let mut c = WireClient::connect(&addr).unwrap();
        assert!(matches!(
            c.call(&RequestFrame::Ping).unwrap(),
            ResponseFrame::Ok { .. }
        ));
        handle.shutdown();
    }
}
