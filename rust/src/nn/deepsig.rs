//! The §8 deep-signature model, natively in Rust.
//!
//! `X (B, M+1, dim) → φ_θ (pointwise linear) → lead–lag → π_I(S(·)) →
//! MLP head → Ĥ`, trained end-to-end with Adam. The signature layer
//! backpropagates with the §4 memory-minimal backward
//! ([`crate::sig::sig_backward`]), the lead–lag transform with its exact
//! adjoint, and `φ_θ` as a shared-weights dense layer over time.
//!
//! Three Figure-4 variants are expressible:
//! * FNN baseline — use [`crate::nn::Mlp`] on the flattened path;
//! * truncated — `spec.words = truncated_words(2·dim, N)`;
//! * sparse lead–lag projection —
//!   `spec.words = concat_generated_words(2·dim, N, sparse_leadlag_generators(dim))`.

use super::{adam_update, mse_loss, relu, relu_backward, Linear};
use crate::fbm::lead_lag;
use crate::sig::{sig_backward, signature, SigEngine};
use crate::util::rng::Rng;
use crate::util::threadpool::{parallel_fill_rows, parallel_map};
use crate::words::{Word, WordTable};

/// Model hyper-parameters.
#[derive(Clone, Debug)]
pub struct DeepSigSpec {
    /// Base path channels (before lead–lag).
    pub dim: usize,
    /// Requested signature words over the 2·dim lead–lag alphabet.
    pub words: Vec<Word>,
    /// Head hidden sizes (e.g. `[64]`).
    pub hidden: Vec<usize>,
    /// Learning rate.
    pub lr: f64,
}

/// Deep signature model with learnable channel map and dense head.
pub struct DeepSigModel {
    /// The hyper-parameters the model was built from.
    pub spec: DeepSigSpec,
    /// Pointwise channel map φ_θ: dim → dim.
    pub phi: Linear,
    /// Signature engine over the lead–lag alphabet.
    pub engine: SigEngine,
    /// Dense head on the signature features.
    pub head: Vec<Linear>,
    step: usize,
}

impl DeepSigModel {
    /// Build the model: φ initialised near identity, head He-uniform.
    pub fn new(rng: &mut Rng, spec: DeepSigSpec) -> DeepSigModel {
        let engine = SigEngine::new(WordTable::build(2 * spec.dim, &spec.words));
        let mut phi = Linear::new(rng, spec.dim, spec.dim);
        // Initialise φ near identity so early signatures are informative.
        for i in 0..spec.dim {
            for j in 0..spec.dim {
                phi.w[i * spec.dim + j] = if i == j { 1.0 } else { 0.0 };
            }
            phi.w[i * spec.dim + i] += 0.05 * rng.gaussian();
        }
        let mut sizes = vec![engine.out_dim()];
        sizes.extend_from_slice(&spec.hidden);
        sizes.push(1);
        let head = sizes.windows(2).map(|p| Linear::new(rng, p[0], p[1])).collect();
        DeepSigModel {
            spec,
            phi,
            engine,
            head,
            step: 0,
        }
    }

    /// Number of signature features `|I|`.
    pub fn feature_dim(&self) -> usize {
        self.engine.out_dim()
    }

    /// Total number of trainable parameters (φ + head).
    pub fn n_params(&self) -> usize {
        self.phi.n_params() + self.head.iter().map(|l| l.n_params()).sum::<usize>()
    }

    /// Signature features for a batch of paths (φ + lead–lag + sig).
    /// Feature rows are written in place (no post-join copy).
    pub fn features(&self, paths: &[f64], batch: usize) -> Vec<f64> {
        let per = paths.len() / batch;
        let m1 = per / self.spec.dim;
        let fdim = self.feature_dim();
        let mut out = vec![0.0; batch * fdim];
        parallel_fill_rows(&mut out, fdim, self.engine.threads, |b, row| {
            let path = &paths[b * per..(b + 1) * per];
            let mapped = self.phi.forward(path, m1); // pointwise over time
            let ll = lead_lag(&mapped, self.spec.dim);
            row.copy_from_slice(&signature(&self.engine, &ll));
        });
        out
    }

    /// Predict Ĥ for a batch of paths.
    pub fn predict(&self, paths: &[f64], batch: usize) -> Vec<f64> {
        let feats = self.features(paths, batch);
        self.head_forward(&feats, batch).0
    }

    fn head_forward(&self, feats: &[f64], batch: usize) -> (Vec<f64>, Vec<Vec<f64>>, Vec<Vec<bool>>) {
        let mut inputs = Vec::new();
        let mut masks = Vec::new();
        let mut cur = feats.to_vec();
        for (li, layer) in self.head.iter().enumerate() {
            inputs.push(cur.clone());
            let mut y = layer.forward(&cur, batch);
            if li + 1 < self.head.len() {
                masks.push(relu(&mut y));
            }
            cur = y;
        }
        (cur, inputs, masks)
    }

    /// Validation MSE.
    pub fn mse(&self, paths: &[f64], targets: &[f64], batch: usize) -> f64 {
        let pred = self.predict(paths, batch);
        mse_loss(&pred, targets).0
    }

    /// One end-to-end Adam step; returns the training loss.
    pub fn train_step(&mut self, paths: &[f64], targets: &[f64], batch: usize) -> f64 {
        self.step += 1;
        let per = paths.len() / batch;
        let m1 = per / self.spec.dim;
        let dim = self.spec.dim;

        // Forward with caches (per-path φ outputs + lead–lag paths).
        let mapped: Vec<Vec<f64>> = parallel_map(batch, self.engine.threads, |b| {
            self.phi.forward(&paths[b * per..(b + 1) * per], m1)
        });
        let lls: Vec<Vec<f64>> = parallel_map(batch, self.engine.threads, |b| {
            lead_lag(&mapped[b], dim)
        });
        let feat_dim = self.feature_dim();
        let feats_rows: Vec<Vec<f64>> = parallel_map(batch, self.engine.threads, |b| {
            signature(&self.engine, &lls[b])
        });
        let mut feats = Vec::with_capacity(batch * feat_dim);
        for r in &feats_rows {
            feats.extend_from_slice(r);
        }
        let (pred, inputs, masks) = self.head_forward(&feats, batch);
        let (loss, gpred) = mse_loss(&pred, targets);

        // Head backward.
        let mut grads: Vec<(Vec<f64>, Vec<f64>)> = self
            .head
            .iter()
            .map(|l| (vec![0.0; l.w.len()], vec![0.0; l.b.len()]))
            .collect();
        let mut g = gpred;
        for li in (0..self.head.len()).rev() {
            if li + 1 < self.head.len() {
                relu_backward(&mut g, &masks[li]);
            }
            let (gw, gb) = &mut grads[li];
            g = self.head[li].backward(&inputs[li], &g, batch, gw, gb);
        }
        // g is now ∂L/∂features (B, feat_dim).

        // Signature backward + lead–lag adjoint + φ backward, per path.
        let g_ref = &g;
        let path_grads: Vec<Vec<f64>> = parallel_map(batch, self.engine.threads, |b| {
            let g_ll = sig_backward(
                &self.engine,
                &lls[b],
                &g_ref[b * feat_dim..(b + 1) * feat_dim],
            );
            lead_lag_adjoint(&g_ll, dim, m1)
        });
        // φ backward (shared weights across time and batch).
        let mut g_phi_w = vec![0.0; self.phi.w.len()];
        let mut g_phi_b = vec![0.0; self.phi.b.len()];
        for b in 0..batch {
            self.phi.backward(
                &paths[b * per..(b + 1) * per],
                &path_grads[b],
                m1,
                &mut g_phi_w,
                &mut g_phi_b,
            );
        }

        // Adam updates.
        for (li, (gw, gb)) in grads.iter().enumerate() {
            self.head[li].adam_step(gw, gb, self.spec.lr, self.step);
        }
        let lr = self.spec.lr;
        let st = self.step;
        adam_update(&mut self.phi.w, &mut self.phi.mw, &mut self.phi.vw, &g_phi_w, lr, st);
        adam_update(&mut self.phi.b, &mut self.phi.mb, &mut self.phi.vb, &g_phi_b, lr, st);
        loss
    }
}

/// Adjoint of the lead–lag transform: gradient on the `(2M+1, 2d)`
/// lead–lag path → gradient on the `(M+1, d)` base path.
pub fn lead_lag_adjoint(g_ll: &[f64], d: usize, m1: usize) -> Vec<f64> {
    let m = m1 - 1;
    let d2 = 2 * d;
    debug_assert_eq!(g_ll.len(), (2 * m + 1) * d2);
    let mut g = vec![0.0; m1 * d];
    let mut add = |k: usize, half: usize, row: usize| {
        for i in 0..d {
            g[k * d + i] += g_ll[row * d2 + half * d + i];
        }
    };
    for k in 0..m {
        add(k, 0, 2 * k); // lag half of X̂_{2k}
        add(k, 1, 2 * k); // lead half of X̂_{2k}
        add(k, 0, 2 * k + 1); // lag half of X̂_{2k+1}
        add(k + 1, 1, 2 * k + 1); // lead half of X̂_{2k+1}
    }
    add(m, 0, 2 * m);
    add(m, 1, 2 * m);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fbm::{fbm_dataset, lead_lag};
    use crate::words::generate::{
        concat_generated_words, sparse_leadlag_generators, truncated_words,
    };

    #[test]
    fn lead_lag_adjoint_is_exact_transpose() {
        let mut rng = Rng::new(800);
        let (d, m1) = (3, 6);
        let path: Vec<f64> = (0..m1 * d).map(|_| rng.gaussian()).collect();
        let ll = lead_lag(&path, d);
        let g_ll: Vec<f64> = (0..ll.len()).map(|_| rng.gaussian()).collect();
        // <lead_lag(x), g> must equal <x, adjoint(g)> since lead_lag is
        // linear in x.
        let lhs: f64 = ll.iter().zip(&g_ll).map(|(a, b)| a * b).sum();
        let adj = lead_lag_adjoint(&g_ll, d, m1);
        let rhs: f64 = path.iter().zip(&adj).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-10, "{lhs} vs {rhs}");
    }

    #[test]
    fn model_end_to_end_gradcheck() {
        // FD check of the full pipeline gradient wrt φ weights.
        let mut rng = Rng::new(801);
        let dim = 2;
        let spec = DeepSigSpec {
            dim,
            words: truncated_words(2 * dim, 2),
            hidden: vec![8],
            lr: 1e-3,
        };
        let mut model = DeepSigModel::new(&mut rng, spec);
        let (paths, hs) = fbm_dataset(&mut rng, 4, 8, dim, 0.3, 0.7);
        // Loss as function of φ.w[k]: run predict + mse.
        let loss_of = |m: &DeepSigModel| m.mse(&paths, &hs, 4);
        let base = loss_of(&model);
        assert!(base.is_finite());
        // Analytic gradient via one train step on a clone with lr→0 is
        // impractical; instead FD-check that train_step reduces loss.
        let mut prev = base;
        let mut improved = 0;
        for _ in 0..30 {
            model.train_step(&paths, &hs, 4);
            let cur = loss_of(&model);
            if cur < prev {
                improved += 1;
            }
            prev = cur;
        }
        assert!(improved > 15, "training not descending ({improved}/30)");
        assert!(prev < base, "loss did not improve: {base} → {prev}");
    }

    #[test]
    fn sparse_projection_is_smaller() {
        let dim = 5;
        let trunc = truncated_words(2 * dim, 3);
        let sparse = concat_generated_words(2 * dim, 3, &sparse_leadlag_generators(dim));
        assert!(sparse.len() * 4 < trunc.len(), "{} vs {}", sparse.len(), trunc.len());
    }

    #[test]
    fn features_deterministic_and_shaped() {
        let mut rng = Rng::new(802);
        let dim = 2;
        let spec = DeepSigSpec {
            dim,
            words: truncated_words(2 * dim, 2),
            hidden: vec![4],
            lr: 1e-3,
        };
        let model = DeepSigModel::new(&mut rng, spec);
        let (paths, _) = fbm_dataset(&mut rng, 3, 10, dim, 0.3, 0.7);
        let f1 = model.features(&paths, 3);
        let f2 = model.features(&paths, 3);
        assert_eq!(f1.len(), 3 * model.feature_dim());
        assert_eq!(f1, f2);
    }
}
